//! End-to-end simulator hot path: events/second and request throughput of
//! the full SSDUP+ server loop — the §Perf L3 metric. The simulator *is*
//! the production coordinator here, so its event rate bounds how fast the
//! benchmark harness can sweep the paper's parameter space.

use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::types::DEFAULT_REQ_SECTORS;
use ssdup::util::benchkit::{bb, section, Bench};
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::Workload;

fn workload(kind: IorPattern, sectors: i64) -> Workload {
    ior_spanned(0, kind, 16, sectors, sectors * 8, DEFAULT_REQ_SECTORS, 11)
}

fn main() {
    let mut b = Bench::new().slow();

    section("full simulation (256 MiB workload, 2 nodes)");
    let sectors = 512 * 1024;
    for (name, system) in [
        ("sim/orangefs-contig", SystemKind::OrangeFs),
        ("sim/ssdup+-contig", SystemKind::SsdupPlus),
    ] {
        if Bench::should_run(name) {
            let w = workload(IorPattern::SegmentedContiguous, sectors);
            let reqs = w.total_requests() as f64;
            b.run(name, reqs, || {
                bb(simulate(&SimConfig::new(system).with_seed(1), &w).events)
            });
        }
    }
    for (name, system) in [
        ("sim/orangefs-random", SystemKind::OrangeFs),
        ("sim/ssdup+-random", SystemKind::SsdupPlus),
        ("sim/ssdup+-random-small-ssd", SystemKind::SsdupPlus),
    ] {
        if Bench::should_run(name) {
            let w = workload(IorPattern::SegmentedRandom, sectors);
            let reqs = w.total_requests() as f64;
            let small = name.ends_with("small-ssd");
            b.run(name, reqs, || {
                let mut cfg = SimConfig::new(system).with_seed(1);
                if small {
                    cfg = cfg.with_ssd_mib(64);
                }
                bb(simulate(&cfg, &w).events)
            });
        }
    }

    section("events/second (simulator engine efficiency)");
    if Bench::should_run("sim/event-rate") {
        let w = workload(IorPattern::SegmentedRandom, sectors);
        let r = simulate(&SimConfig::new(SystemKind::SsdupPlus).with_seed(1), &w);
        let t0 = std::time::Instant::now();
        let r2 = simulate(&SimConfig::new(SystemKind::SsdupPlus).with_seed(1), &w);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "sim/event-rate: {:.2} M events/s ({} events in {:.3}s; deterministic: {})",
            r2.events as f64 / dt / 1e6,
            r2.events,
            dt,
            r.events == r2.events
        );
    }
}
