//! Detector hot-path benchmarks: native mirror vs AOT/PJRT backend.
//!
//! The L3 §Perf target: detection must be negligible next to device time
//! (Table 1: <1% of run time). The HLO path amortizes over batches of 16
//! streams per execute call.

use ssdup::detector::native::NativeDetector;
use ssdup::device::SeekModel;
use ssdup::runtime::{ArtifactSet, Runtime};
use ssdup::util::benchkit::{bb, section, Bench};
use ssdup::util::prng::Prng;

fn streams(n: usize, len: usize, seed: u64) -> Vec<Vec<(i32, i32)>> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|_| (0..len).map(|_| (rng.gen_range(1 << 26) as i32, 512)).collect())
        .collect()
}

fn main() {
    let mut b = Bench::new();

    section("native detector (sort + RF + seek cost)");
    let mut det = NativeDetector::new(SeekModel::default());
    for len in [32usize, 128, 512] {
        let name = format!("native/stream-{len}");
        if Bench::should_run(&name) {
            let ss = streams(64, len, 7);
            let mut i = 0;
            b.run(&name, len as f64, || {
                i = (i + 1) % ss.len();
                bb(det.detect(&ss[i]))
            });
        }
    }

    section("PJRT (HLO) detector — compiled JAX/Pallas artifact");
    // ArtifactSet::load_default returns RtResult while Runtime::load is
    // anyhow-based; lift the artifact error into anyhow before chaining
    match ArtifactSet::load_default()
        .map_err(anyhow::Error::from)
        .and_then(Runtime::load)
    {
        Ok(rt) => {
            let exec = rt.detector().expect("compile");
            // single stream padded into a batch (worst amortization)
            if Bench::should_run("hlo/stream-128-single") {
                let ss = streams(1, 128, 9);
                let refs: Vec<&[(i32, i32)]> = ss.iter().map(|v| v.as_slice()).collect();
                b.run("hlo/stream-128-single", 128.0, || bb(exec.run_batch(&refs).unwrap()));
            }
            // full batch of 16 streams (the intended §Perf shape)
            if Bench::should_run("hlo/stream-128-batch16") {
                let ss = streams(16, 128, 11);
                let refs: Vec<&[(i32, i32)]> = ss.iter().map(|v| v.as_slice()).collect();
                b.run("hlo/stream-128-batch16", 16.0 * 128.0, || {
                    bb(exec.run_batch(&refs).unwrap())
                });
            }
            if Bench::should_run("hlo/threshold") {
                let thr = rt.threshold().expect("compile");
                let list: Vec<f32> = (0..64).map(|i| i as f32 / 64.0).collect();
                b.run("hlo/threshold", 1.0, || bb(thr.run(&list).unwrap()));
            }
        }
        Err(e) => eprintln!("skipping HLO benches: {e} (run `make artifacts`)"),
    }
}
