//! Live-engine benchmarks: shard-scaling throughput on `MemBackend` with
//! synthetic device latency (the sleeps model real device service times,
//! so shard parallelism — not memcpy speed — dominates, exactly like a
//! real deployment), plus a `FileBackend` smoke bench.
//!
//! Run: `cargo bench --bench bench_live` (SSDUP_BENCH_FAST=1 to shrink).

use ssdup::live::{self, LiveConfig, LiveEngine, SyntheticLatency};
use ssdup::server::SystemKind;
use ssdup::types::DEFAULT_REQ_SECTORS;
use ssdup::util::benchkit::{bb, section, Bench};
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::rewrite::checkpoint_rewrite;
use ssdup::workload::Workload;

/// The benchmark workload: contiguous x random mix, `mib` MiB total.
fn mixed(mib: i64, seed: u64) -> Workload {
    let sectors = mib * 2048;
    let span = sectors * 8;
    Workload::concurrent(
        "bench-mixed",
        ior_spanned(0, IorPattern::SegmentedContiguous, 4, sectors / 2, span, DEFAULT_REQ_SECTORS, seed),
        ior_spanned(0, IorPattern::SegmentedRandom, 4, sectors / 2, span, DEFAULT_REQ_SECTORS, seed + 1),
    )
}

fn run_mem(shards: usize, w: &Workload) -> f64 {
    let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(shards).with_ssd_mib(32);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ssd(), SyntheticLatency::hdd());
    let report = live::run_load(&engine, w, 8);
    engine.shutdown();
    report.throughput_mbps()
}

fn main() {
    let mut b = Bench::new().slow();
    let w = mixed(64, 11);
    let bytes = w.total_bytes() as f64;

    section("live engine shard scaling (MemBackend, synthetic device latency)");
    let mut mbps: Vec<(usize, f64)> = Vec::new();
    for shards in [1usize, 2, 4] {
        let name = format!("live/mem-shards-{shards}");
        if Bench::should_run(&name) {
            let mut last = 0.0;
            b.run(&name, bytes, || {
                last = run_mem(shards, &w);
                bb(last)
            });
            mbps.push((shards, last));
        }
    }
    if let (Some(one), Some(four)) =
        (mbps.iter().find(|(s, _)| *s == 1), mbps.iter().find(|(s, _)| *s == 4))
    {
        println!(
            "\nshard scaling: 1 shard {:.1} MB/s -> 4 shards {:.1} MB/s  ({:.2}x)",
            one.1,
            four.1,
            four.1 / one.1.max(1e-9)
        );
    }

    section("rewrite-heavy load (ownership map + stale-flush suppression)");
    if Bench::should_run("live/mem-rewrite") {
        // every sector written twice across mixed routes: measures the
        // ownership-map overhead on ingest plus the HDD bandwidth the
        // flusher saves by skipping superseded extents
        let wr = checkpoint_rewrite(4, 32 * 2048, DEFAULT_REQ_SECTORS, 1_000, 17);
        let rbytes = wr.total_bytes() as f64;
        let mut skipped = 0u64;
        b.run("live/mem-rewrite", rbytes, || {
            let mut cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(2).with_ssd_mib(64);
            cfg = cfg.with_stream_len(32);
            let engine = LiveEngine::mem(&cfg, SyntheticLatency::ssd(), SyntheticLatency::hdd());
            let report = live::run_load_with(&engine, &wr, 8, true);
            let stats = engine.shutdown();
            skipped = stats.iter().map(|s| s.superseded_bytes).sum();
            bb(report.throughput_mbps())
        });
        println!("  stale flushes suppressed: {} MiB of HDD writes saved", skipped / (1 << 20));
    }

    section("live engine on real files (FileBackend, page-cached)");
    if Bench::should_run("live/file-shards-4") {
        let dir = std::env::temp_dir().join(format!("ssdup-bench-live-{}", std::process::id()));
        let wf = mixed(32, 13);
        let fbytes = wf.total_bytes() as f64;
        b.run("live/file-shards-4", fbytes, || {
            let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(4).with_ssd_mib(16);
            let engine = LiveEngine::file(&cfg, &dir).expect("file backends");
            let report = live::run_load(&engine, &wf, 8);
            engine.shutdown();
            bb(report.throughput_mbps())
        });
        std::fs::remove_dir_all(&dir).ok();
    }
}
