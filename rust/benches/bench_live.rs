//! Live-engine benchmarks: shard- and client-scaling throughput on
//! `MemBackend` with synthetic device latency (the sleeps model real
//! device service times, so concurrency — not memcpy speed — dominates,
//! exactly like a real deployment), an IO-depth sweep at fixed worker
//! count, mid-burst read latency, a rewrite-heavy section, and a
//! `FileBackend` smoke bench.
//!
//! Run: `cargo bench --bench bench_live` (SSDUP_BENCH_FAST=1 to shrink —
//! that mode also runs as a blocking CI smoke step).
//!
//! Machine-readable results land in `BENCH_live.json` (schema below), so
//! the perf trajectory is trackable across PRs.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use std::sync::Arc;

use ssdup::live::{
    self, payload, Backend, FileBackend, LiveConfig, LiveEngine, MemBackend, MemStore,
    SyntheticLatency,
};
use ssdup::server::metrics::LatencyHistogram;
use ssdup::server::SystemKind;
use ssdup::types::{Request, DEFAULT_REQ_SECTORS, SECTOR_BYTES};
use ssdup::util::benchkit::{bb, section, Bench};
use ssdup::util::json::Json;
use ssdup::util::prng::Prng;
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::rewrite::checkpoint_rewrite;
use ssdup::workload::Workload;

fn fast() -> bool {
    std::env::var("SSDUP_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// The benchmark workload: contiguous x random mix, `mib` MiB total.
fn mixed(mib: i64, seed: u64) -> Workload {
    let sectors = mib * 2048;
    let span = sectors * 8;
    Workload::concurrent(
        "bench-mixed",
        ior_spanned(0, IorPattern::SegmentedContiguous, 4, sectors / 2, span, DEFAULT_REQ_SECTORS, seed),
        ior_spanned(0, IorPattern::SegmentedRandom, 4, sectors / 2, span, DEFAULT_REQ_SECTORS, seed + 1),
    )
}

fn run_mem(shards: usize, w: &Workload) -> f64 {
    let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(shards).with_ssd_mib(32);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ssd(), SyntheticLatency::hdd());
    let report = live::run_load(&engine, w, 8);
    engine.shutdown();
    report.throughput_mbps()
}

/// One ingest run against a single shard from `clients` concurrent
/// closed-loop threads. The SSD budget exceeds the burst (the burst
/// buffer's own premise), so what this measures is pure reserve→publish
/// ingest: with device writes outside the core lock, throughput scales
/// with the number of in-flight clients.
fn run_clients(clients: usize, w: &Workload) -> f64 {
    let cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(1).with_ssd_mib(256);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ssd(), SyntheticLatency::hdd());
    let report = live::run_load(&engine, w, clients);
    engine.shutdown();
    report.throughput_mbps()
}

/// Mid-burst read latency on one shard: preload a buffered range, keep a
/// writer ingesting a disjoint range, and sample reads against the log.
/// Before the pinned-extent read path, every read serialized behind the
/// core lock *across the writer's device I/O*; now it costs about one
/// device read regardless of ingest traffic.
fn read_latency(samples: usize) -> LatencyHistogram {
    let cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(1).with_ssd_mib(256);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ssd(), SyntheticLatency::hdd());
    let s = SECTOR_BYTES as usize;
    // preload 16 MiB into the log
    let preload_reqs = 64;
    let mut buf = vec![0u8; DEFAULT_REQ_SECTORS as usize * s];
    for i in 0..preload_reqs {
        let off = i * DEFAULT_REQ_SECTORS;
        payload::fill(1, off as i64, &mut buf);
        engine
            .submit(Request { app: 0, proc_id: 0, file: 1, offset: off, size: DEFAULT_REQ_SECTORS }, &buf)
            .unwrap();
    }
    let stop = AtomicBool::new(false);
    let mut hist = LatencyHistogram::new();
    std::thread::scope(|sc| {
        let engine = &engine;
        let stop = &stop;
        // background ingest into a disjoint file, closed loop
        sc.spawn(move || {
            let mut wbuf = vec![0u8; DEFAULT_REQ_SECTORS as usize * s];
            let mut off = 0i32;
            while !stop.load(Ordering::Relaxed) {
                payload::fill(2, off as i64, &mut wbuf);
                let req = Request { app: 1, proc_id: 1, file: 2, offset: off, size: DEFAULT_REQ_SECTORS };
                engine.submit(req, &wbuf).unwrap();
                off += DEFAULT_REQ_SECTORS;
            }
        });
        let mut rng = Prng::new(23);
        let read_sectors = 8usize; // 4 KiB reads
        let mut rbuf = vec![0u8; read_sectors * s];
        let span = (preload_reqs * DEFAULT_REQ_SECTORS) as u64 - read_sectors as u64;
        for _ in 0..samples {
            let off = rng.gen_range(span) as i32;
            let t0 = Instant::now();
            engine.read(1, off, &mut rbuf).unwrap();
            hist.record(t0.elapsed().as_micros() as u64);
        }
        stop.store(true, Ordering::Relaxed);
    });
    engine.shutdown();
    hist
}

/// Modeled spindle bandwidth of the shared HDD tier in the
/// flush-scheduling A/B: ~35 MB/s, slow enough that flushing — not
/// ingest — bounds the run.
const PACED_HDD_US_PER_MIB: u64 = 30_000;

/// Shared slow HDD tier for the flush-scheduling A/B: a real file per
/// shard behind ONE pacing gate, so however many flushers run at once
/// they contend for a single spindle's bandwidth. The gate fixes the
/// aggregate flush rate; what coordination can change is how many
/// already-superseded bytes reach the device at all.
struct PacedHdd {
    inner: FileBackend,
    gate: Arc<std::sync::Mutex<()>>,
}

impl PacedHdd {
    /// Take the spindle and dwell for the modeled service time of a
    /// `bytes`-sized transfer; the caller holds the guard across the
    /// real (page-cached, ~free) file write.
    fn pace(&self, bytes: usize) -> std::sync::MutexGuard<'_, ()> {
        let spindle = self.gate.lock().unwrap();
        std::thread::sleep(std::time::Duration::from_micros(
            (bytes as u64 * PACED_HDD_US_PER_MIB) >> 20,
        ));
        spindle
    }
}

impl Backend for PacedHdd {
    fn write_at(&self, offset: u64, data: &[u8]) -> std::io::Result<()> {
        let _spindle = self.pace(data.len());
        self.inner.write_at(offset, data)
    }

    fn write_vectored_at(&self, offset: u64, bufs: &[&[u8]]) -> std::io::Result<()> {
        let _spindle = self.pace(bufs.iter().map(|b| b.len()).sum());
        self.inner.write_vectored_at(offset, bufs)
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
        self.inner.read_at(offset, buf)
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn sync(&self) -> std::io::Result<()> {
        self.inner.sync()
    }

    fn kind(&self) -> &'static str {
        "paced-hdd"
    }
}

/// One run of the flush-scheduling A/B: 4 shards on real files, the HDD
/// tier shared through [`PacedHdd`], SSD budget small enough that sealed
/// regions queue for flush mid-run. `budget = 0` disables the
/// coordinator (and the hot-defer window that rides with it). Returns
/// (drained MB/s, queued-for-flush bytes, superseded-at-flush bytes).
fn run_flush_sched(dir: &std::path::Path, w: &Workload, budget: usize) -> (f64, u64, u64) {
    std::fs::remove_dir_all(dir).ok();
    let mut cfg = LiveConfig::new(SystemKind::OrangeFsBB)
        .with_shards(4)
        .with_ssd_mib(4)
        .with_flush_concurrency(budget);
    if budget > 0 {
        cfg = cfg.with_hot_defer_window(std::time::Duration::from_millis(10));
    }
    let gate = Arc::new(std::sync::Mutex::new(()));
    let base = dir.to_path_buf();
    let engine = LiveEngine::with_backends(&cfg, move |i| {
        let ssd = FileBackend::create(&base.join(format!("ssd-{i}.img"))).expect("ssd image");
        let hdd = FileBackend::create(&base.join(format!("hdd-{i}.img"))).expect("hdd image");
        (
            Box::new(ssd) as Box<dyn Backend>,
            Box::new(PacedHdd { inner: hdd, gate: Arc::clone(&gate) }) as Box<dyn Backend>,
        )
    });
    let report = live::run_load_with(&engine, w, 4, true);
    let stats = engine.shutdown();
    let queued: u64 = stats.iter().map(|s| s.queued_for_flush_bytes).sum();
    let at_flush: u64 = stats.iter().map(|s| s.superseded_at_flush_bytes).sum();
    std::fs::remove_dir_all(dir).ok();
    (report.drained_throughput_mbps(), queued, at_flush)
}

fn main() {
    let mut b = Bench::new().slow();
    let fast = fast();
    let mut out: BTreeMap<String, Json> = BTreeMap::new();
    out.insert("schema".into(), Json::Num(1.0));
    out.insert("bench".into(), Json::Str("bench_live".into()));
    out.insert("fast_mode".into(), Json::Bool(fast));

    section("live engine shard scaling (MemBackend, synthetic device latency)");
    let w = mixed(if fast { 16 } else { 64 }, 11);
    let bytes = w.total_bytes() as f64;
    let mut shard_mbps: Vec<(usize, f64)> = Vec::new();
    for shards in [1usize, 2, 4] {
        let name = format!("live/mem-shards-{shards}");
        if Bench::should_run(&name) {
            let mut last = 0.0;
            b.run(&name, bytes, || {
                last = run_mem(shards, &w);
                bb(last)
            });
            shard_mbps.push((shards, last));
        }
    }
    if let (Some(one), Some(four)) =
        (shard_mbps.iter().find(|(s, _)| *s == 1), shard_mbps.iter().find(|(s, _)| *s == 4))
    {
        println!(
            "\nshard scaling: 1 shard {:.1} MB/s -> 4 shards {:.1} MB/s  ({:.2}x)",
            one.1,
            four.1,
            four.1 / one.1.max(1e-9)
        );
    }
    if !shard_mbps.is_empty() {
        out.insert(
            "shard_scaling".into(),
            Json::Arr(
                shard_mbps
                    .iter()
                    .map(|&(s, m)| Json::obj(vec![("shards", Json::Num(s as f64)), ("mbps", Json::Num(m))]))
                    .collect(),
            ),
        );
    }

    section("clients-per-shard scaling (ONE shard, reserve→publish ingest)");
    // the burst fits the SSD budget: no backpressure, so this isolates
    // the ingest path itself — device writes overlapping outside the
    // core lock. Expected: ≥2x at 4 clients vs 1.
    let wc = {
        let mib: i64 = if fast { 12 } else { 48 };
        let sectors = mib * 2048;
        ior_spanned(0, IorPattern::SegmentedRandom, 8, sectors, sectors * 8, DEFAULT_REQ_SECTORS, 29)
    };
    let cbytes = wc.total_bytes() as f64;
    let mut client_mbps: Vec<(usize, f64)> = Vec::new();
    for clients in [1usize, 2, 4, 8] {
        let name = format!("live/mem-clients-{clients}");
        if Bench::should_run(&name) {
            let mut last = 0.0;
            b.run(&name, cbytes, || {
                last = run_clients(clients, &wc);
                bb(last)
            });
            client_mbps.push((clients, last));
        }
    }
    if let (Some(one), Some(four)) =
        (client_mbps.iter().find(|(c, _)| *c == 1), client_mbps.iter().find(|(c, _)| *c == 4))
    {
        println!(
            "\nclient scaling on one shard: 1 client {:.1} MB/s -> 4 clients {:.1} MB/s  ({:.2}x)",
            one.1,
            four.1,
            four.1 / one.1.max(1e-9)
        );
    }
    if !client_mbps.is_empty() {
        out.insert(
            "clients_per_shard".into(),
            Json::Arr(
                client_mbps
                    .iter()
                    .map(|&(c, m)| Json::obj(vec![("clients", Json::Num(c as f64)), ("mbps", Json::Num(m))]))
                    .collect(),
            ),
        );
    }

    section("group commit: fsync batching, 4 clients on ONE shard (FileBackend)");
    if Bench::should_run("live/group-commit") {
        // A/B the publish-path durability barrier: per-record fsync (the
        // PR-4 baseline) vs group commit, on real files where fsync has a
        // real price. Same burst, same 4 closed-loop clients, one shard;
        // the SSD budget holds the burst so the ingest path dominates.
        // 64 KiB requests keep enough publishes in flight to batch.
        let mib: i64 = if fast { 6 } else { 24 };
        let sectors = mib * 2048;
        let wg = ior_spanned(0, IorPattern::SegmentedRandom, 4, sectors, sectors * 8, 128, 37);
        let gbytes = wg.total_bytes() as f64;
        // (mbps, syncs, writes_per_sync) per mode
        let mut modes: Vec<(&'static str, f64, u64, f64)> = Vec::new();
        for (on, label) in [(false, "off"), (true, "on")] {
            let dir =
                std::env::temp_dir().join(format!("ssdup-bench-gc-{label}-{}", std::process::id()));
            // a modest leader window helps where fsync is cheap (tmpfs)
            let window = std::time::Duration::from_micros(if on { 500 } else { 0 });
            let mut last = (0.0f64, 0u64, 0.0f64);
            b.run(&format!("live/group-commit-{label}"), gbytes, || {
                std::fs::remove_dir_all(&dir).ok();
                let cfg = LiveConfig::new(SystemKind::OrangeFsBB)
                    .with_shards(1)
                    .with_ssd_mib(mib as u64 * 2)
                    .with_group_commit(on)
                    .with_group_commit_window(window);
                let engine = LiveEngine::file(&cfg, &dir).expect("file backends");
                let report = live::run_load(&engine, &wg, 4);
                engine.shutdown();
                last = (report.throughput_mbps(), report.syncs(), report.writes_per_sync());
                bb(last.0)
            });
            std::fs::remove_dir_all(&dir).ok();
            modes.push((label, last.0, last.1, last.2));
        }
        if let (Some(off), Some(on)) =
            (modes.iter().find(|m| m.0 == "off"), modes.iter().find(|m| m.0 == "on"))
        {
            println!(
                "\ngroup commit: off {:.1} MB/s over {} fsyncs -> on {:.1} MB/s over {} fsyncs \
                 ({:.1} writes/sync, {:.2}x fewer fsyncs)",
                off.1,
                off.2,
                on.1,
                on.2,
                on.3,
                off.2 as f64 / (on.2 as f64).max(1.0),
            );
            out.insert("syncs".into(), Json::Num(on.2 as f64));
            out.insert("writes_per_sync".into(), Json::Num(on.3));
            out.insert(
                "group_commit".into(),
                Json::obj(vec![
                    (
                        "off",
                        Json::obj(vec![
                            ("mbps", Json::Num(off.1)),
                            ("syncs", Json::Num(off.2 as f64)),
                            ("writes_per_sync", Json::Num(off.3)),
                        ]),
                    ),
                    (
                        "on",
                        Json::obj(vec![
                            ("mbps", Json::Num(on.1)),
                            ("syncs", Json::Num(on.2 as f64)),
                            ("writes_per_sync", Json::Num(on.3)),
                        ]),
                    ),
                ]),
            );
            // the smoke contract (blocking in CI's SSDUP_BENCH_FAST=1
            // step): 4 concurrent publishers must share barriers
            assert!(
                on.3 > 1.0,
                "group commit failed to batch: {:.2} writes/sync ({} syncs; ungrouped baseline {})",
                on.3,
                on.2,
                off.2
            );
        }
    }

    section("io-depth sweep: in-flight writes per shard, fixed --io-workers (FileBackend)");
    {
        // vary the number of in-flight writes per shard (one closed-loop
        // client = one write in flight) at a CONSTANT worker count: the
        // submission queue decouples depth from thread count, so
        // throughput must scale with depth while the 4 I/O workers and
        // the shared group-commit barrier do the batching. Real files so
        // fsync has a real price.
        let mib: i64 = if fast { 6 } else { 24 };
        let sectors = mib * 2048;
        let wd = ior_spanned(0, IorPattern::SegmentedRandom, 16, sectors, sectors * 8, 128, 43);
        let dbytes = wd.total_bytes() as f64;
        // (depth, mbps, achieved high-water, achieved mean depth)
        let mut depth_mbps: Vec<(usize, f64, u64, f64)> = Vec::new();
        for depth in [1usize, 2, 4, 8, 16] {
            let name = format!("live/io-depth-{depth}");
            if Bench::should_run(&name) {
                let dir = std::env::temp_dir()
                    .join(format!("ssdup-bench-iodepth-{depth}-{}", std::process::id()));
                let mut last = (0.0f64, 0u64, 0.0f64);
                b.run(&name, dbytes, || {
                    std::fs::remove_dir_all(&dir).ok();
                    let cfg = LiveConfig::new(SystemKind::OrangeFsBB)
                        .with_shards(1)
                        .with_ssd_mib(mib as u64 * 2)
                        .with_io_workers(4)
                        .with_group_commit_window(std::time::Duration::from_micros(500));
                    let engine = LiveEngine::file(&cfg, &dir).expect("file backends");
                    let report = live::run_load(&engine, &wd, depth);
                    engine.shutdown();
                    last = (
                        report.throughput_mbps(),
                        report.io_depth_high_water(),
                        report.io_mean_depth(),
                    );
                    bb(last.0)
                });
                std::fs::remove_dir_all(&dir).ok();
                depth_mbps.push((depth, last.0, last.1, last.2));
            }
        }
        if !depth_mbps.is_empty() {
            out.insert(
                "io_depth_sweep".into(),
                Json::Arr(
                    depth_mbps
                        .iter()
                        .map(|&(d, m, hw, mean)| {
                            Json::obj(vec![
                                ("depth", Json::Num(d as f64)),
                                ("mbps", Json::Num(m)),
                                ("achieved_depth_high_water", Json::Num(hw as f64)),
                                ("achieved_mean_depth", Json::Num(mean)),
                            ])
                        })
                        .collect(),
                ),
            );
        }
        if let (Some(one), Some(eight)) = (
            depth_mbps.iter().find(|(d, ..)| *d == 1),
            depth_mbps.iter().find(|(d, ..)| *d == 8),
        ) {
            println!(
                "\nio-depth scaling at 4 workers: depth 1 {:.1} MB/s -> depth 8 {:.1} MB/s \
                 ({:.2}x; achieved depth hw {} mean {:.1})",
                one.1,
                eight.1,
                eight.1 / one.1.max(1e-9),
                eight.2,
                eight.3,
            );
            // the smoke contract (blocking in CI's SSDUP_BENCH_FAST=1
            // step): more in-flight writes at the same thread count must
            // buy throughput, or the queue is not decoupling depth
            assert!(
                eight.1 > one.1,
                "io-depth sweep failed to scale: depth 8 {:.1} MB/s <= depth 1 {:.1} MB/s",
                eight.1,
                one.1
            );
        }
    }

    section("mid-burst read latency (pinned-extent reads vs concurrent ingest)");
    if Bench::should_run("live/read-latency") {
        let hist = read_latency(if fast { 200 } else { 2000 });
        println!(
            "live/read-latency: {} reads, p50 {} us, p95 {} us, p99 {} us, max {} us",
            hist.count(),
            hist.p50(),
            hist.p95(),
            hist.p99(),
            hist.max_us()
        );
        out.insert(
            "read_latency_us".into(),
            Json::obj(vec![
                ("samples", Json::Num(hist.count() as f64)),
                ("p50", Json::Num(hist.p50() as f64)),
                ("p95", Json::Num(hist.p95() as f64)),
                ("p99", Json::Num(hist.p99() as f64)),
                ("max", Json::Num(hist.max_us() as f64)),
            ]),
        );
    }

    section("rewrite-heavy load (ownership map + stale-flush suppression)");
    if Bench::should_run("live/mem-rewrite") {
        // every sector written twice across mixed routes: measures the
        // ownership-map overhead on ingest plus the HDD bandwidth the
        // flusher saves by skipping superseded extents
        let rw_sectors = if fast { 8 * 2048 } else { 32 * 2048 };
        let wr = checkpoint_rewrite(4, rw_sectors, DEFAULT_REQ_SECTORS, 1_000, 17);
        let rbytes = wr.total_bytes() as f64;
        let mut skipped = 0u64;
        let mut last = 0.0;
        b.run("live/mem-rewrite", rbytes, || {
            let mut cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(2).with_ssd_mib(64);
            cfg = cfg.with_stream_len(32);
            let engine = LiveEngine::mem(&cfg, SyntheticLatency::ssd(), SyntheticLatency::hdd());
            let report = live::run_load_with(&engine, &wr, 8, true);
            let stats = engine.shutdown();
            skipped = stats.iter().map(|s| s.superseded_bytes).sum();
            last = report.throughput_mbps();
            bb(last)
        });
        println!("  stale flushes suppressed: {} MiB of HDD writes saved", skipped / (1 << 20));
        out.insert(
            "rewrite".into(),
            Json::obj(vec![
                ("mbps", Json::Num(last)),
                ("superseded_mib", Json::Num((skipped / (1 << 20)) as f64)),
            ]),
        );
    }

    section("flush scheduling: coordinated vs uncoordinated, 4 shards on one shared HDD tier");
    if Bench::should_run("live/flush-sched") {
        // A/B the array-level flush coordinator on the rewrite workload
        // with all four shards' HDD files behind one pacing gate (a
        // single ~35 MB/s spindle). The burst outruns the per-shard SSD
        // budget, so sealed regions queue for flush while the second
        // rewrite pass keeps superseding their extents. The gate fixes
        // aggregate flush bandwidth — running four flushers at once buys
        // nothing — but every byte superseded *while queued* is a byte
        // the spindle never absorbs, and the coordinator's token wait
        // plus the hot-defer window widen exactly that window.
        let fs_sectors = if fast { 8 * 2048 } else { 16 * 2048 };
        let wfs = checkpoint_rewrite(4, fs_sectors, DEFAULT_REQ_SECTORS, 1_000, 59);
        let fs_bytes = wfs.total_bytes() as f64;
        // (drained mbps, queued-for-flush bytes, superseded-at-flush bytes)
        let mut off = (0.0f64, 0u64, 0u64);
        let mut on = (0.0f64, 0u64, 0u64);
        for coordinated in [false, true] {
            let label = if coordinated { "on" } else { "off" };
            let dir = std::env::temp_dir()
                .join(format!("ssdup-bench-flushsched-{label}-{}", std::process::id()));
            let budget = if coordinated { 2 } else { 0 };
            let mut last = (0.0f64, 0u64, 0u64);
            b.run(&format!("live/flush-sched-{label}"), fs_bytes, || {
                last = run_flush_sched(&dir, &wfs, budget);
                bb(last.0)
            });
            if coordinated {
                on = last;
            } else {
                off = last;
            }
        }
        let at_flush_ratio = if on.1 == 0 { 0.0 } else { on.2 as f64 / on.1 as f64 };
        println!(
            "\nflush scheduling: uncoordinated {:.1} MB/s -> coordinated {:.1} MB/s drained \
             ({:.1} MiB superseded while queued, {:.1}% of queued bytes)",
            off.0,
            on.0,
            on.2 as f64 / (1u64 << 20) as f64,
            at_flush_ratio * 100.0,
        );
        out.insert(
            "flush_sched".into(),
            Json::obj(vec![
                ("uncoordinated_mbps", Json::Num(off.0)),
                ("coordinated_mbps", Json::Num(on.0)),
                ("superseded_at_flush", Json::Num(at_flush_ratio)),
                ("queued_for_flush_mib", Json::Num(on.1 as f64 / (1u64 << 20) as f64)),
            ]),
        );
        // the smoke contract (blocking in CI's SSDUP_BENCH_FAST=1 step):
        // staggering flushers on a shared tier must not cost throughput,
        // and the rewrite pass must supersede bytes while they queue
        assert!(
            on.0 >= off.0,
            "coordinated drain slower than uncoordinated on a shared tier: {:.1} vs {:.1} MB/s",
            on.0,
            off.0
        );
        assert!(
            on.2 > 0,
            "rewrite burst superseded nothing while queued for flush (queued {} bytes)",
            on.1
        );
    }

    section("recovery: dirty log replay vs clean reopen (crash-consistent log)");
    if Bench::should_run("live/recovery") {
        // buffer a random burst into snapshot-mode mem stores WITHOUT
        // draining, freeze (the crash), and time LiveEngine::open
        // replaying every framed record; then shut the recovered engine
        // down cleanly and time the superblock short-circuit reopen
        let mib: i64 = if fast { 8 } else { 32 };
        let sectors = mib * 2048;
        let wrk = ior_spanned(
            0,
            IorPattern::SegmentedRandom,
            4,
            sectors,
            sectors * 8,
            DEFAULT_REQ_SECTORS,
            31,
        );
        let shards = 2usize;
        // the SSD budget holds the whole burst: every record is still
        // buffered (unflushed) at the crash, so all of them replay
        let cfg = LiveConfig::new(SystemKind::OrangeFsBB)
            .with_shards(shards)
            .with_ssd_mib(mib as u64 * 2);
        let stores: Vec<(Arc<MemStore>, Arc<MemStore>)> =
            (0..shards).map(|_| (MemStore::new(true), MemStore::new(true))).collect();
        let engine = {
            let stores = stores.clone();
            LiveEngine::with_backends(&cfg, move |i| {
                (
                    Box::new(MemBackend::over(Arc::clone(&stores[i].0), SyntheticLatency::ZERO))
                        as Box<dyn Backend>,
                    Box::new(MemBackend::over(Arc::clone(&stores[i].1), SyntheticLatency::ZERO))
                        as Box<dyn Backend>,
                )
            })
        };
        let mut buf: Vec<u8> = Vec::new();
        let mut ingested = 0u64;
        for proc in &wrk.processes {
            for req in &proc.reqs {
                buf.resize(req.bytes() as usize, 0);
                payload::fill(req.file, req.offset as i64, &mut buf);
                engine.submit(*req, &buf).unwrap();
                ingested += req.bytes();
            }
        }
        let frozen: Vec<(Arc<MemStore>, Arc<MemStore>)> =
            stores.iter().map(|(s, h)| (s.freeze(), h.freeze())).collect();
        drop(engine); // crash: no drain, no clean superblock

        let reopen = |pairs: Vec<(Arc<MemStore>, Arc<MemStore>)>| {
            LiveEngine::open(&cfg, move |i| {
                (
                    Box::new(MemBackend::over(Arc::clone(&pairs[i].0), SyntheticLatency::ZERO))
                        as Box<dyn Backend>,
                    Box::new(MemBackend::over(Arc::clone(&pairs[i].1), SyntheticLatency::ZERO))
                        as Box<dyn Backend>,
                )
            })
            .expect("reopen")
        };
        let t0 = Instant::now();
        let (recovered, report) = reopen(frozen.clone());
        let dirty_s = t0.elapsed().as_secs_f64();
        let replayed = report.records_replayed();
        let rate = replayed as f64 / dirty_s.max(1e-9);
        // settle + clean superblocks on the frozen stores, then time the
        // clean short-circuit reopen of the same image
        recovered.shutdown();
        let t1 = Instant::now();
        let (clean_engine, clean_report) = reopen(frozen);
        let clean_s = t1.elapsed().as_secs_f64();
        clean_engine.shutdown();
        println!(
            "live/recovery: {} records ({} MiB) replayed in {:.1} ms ({:.0} records/s); \
             clean reopen {:.2} ms (scanned {} sectors, clean={})",
            replayed,
            ingested / (1 << 20),
            dirty_s * 1e3,
            rate,
            clean_s * 1e3,
            clean_report.sectors_scanned(),
            clean_report.clean(),
        );
        out.insert(
            "recovery".into(),
            Json::obj(vec![
                ("records_replayed", Json::Num(replayed as f64)),
                ("records_per_sec", Json::Num(rate)),
                ("dirty_reopen_ms", Json::Num(dirty_s * 1e3)),
                ("clean_reopen_ms", Json::Num(clean_s * 1e3)),
                ("bytes_recovered_mib", Json::Num((report.bytes_recovered() / (1 << 20)) as f64)),
            ]),
        );
    }

    section("observability: tracing overhead (same load, collector off vs on)");
    if Bench::should_run("live/obs-overhead") {
        // A/B the trace collector on the mixed load: off is the default
        // (one relaxed atomic load per span — the overhead contract), on
        // records every span into the per-thread rings. The off-mode
        // number doubles as the cross-PR baseline in BENCH_live.json;
        // the assert is a generous non-flaky floor, not a microbenchmark.
        let wo = mixed(if fast { 8 } else { 32 }, 41);
        let obytes = wo.total_bytes() as f64;
        let mut mbps_off = 0.0f64;
        let mut mbps_on = 0.0f64;
        let mut events = 0u64;
        let mut dropped = 0u64;
        let mut stages: Option<Json> = None;
        let mut dominant = String::new();
        for on in [false, true] {
            let label = if on { "on" } else { "off" };
            let mut last = 0.0;
            b.run(&format!("live/obs-{label}"), obytes, || {
                let cfg = LiveConfig::new(SystemKind::SsdupPlus)
                    .with_shards(2)
                    .with_ssd_mib(32)
                    .with_trace(on);
                let engine = LiveEngine::mem(&cfg, SyntheticLatency::ssd(), SyntheticLatency::hdd());
                let report = live::run_load(&engine, &wo, 8);
                let obs = Arc::clone(engine.trace());
                engine.shutdown();
                if on {
                    events = obs.drain().len() as u64;
                    dropped = obs.dropped_events();
                    dominant =
                        report.stages.dominant_ack_stage().map(|s| s.name()).unwrap_or("?").into();
                    stages = Some(report.stages.to_json());
                }
                last = report.throughput_mbps();
                bb(last)
            });
            if on {
                mbps_on = last;
            } else {
                mbps_off = last;
            }
        }
        println!(
            "\nobs overhead: trace off {mbps_off:.1} MB/s -> on {mbps_on:.1} MB/s \
             ({events} events, {dropped} dropped; dominant ack stage: {dominant})"
        );
        out.insert(
            "obs".into(),
            Json::obj(vec![
                ("mbps_off", Json::Num(mbps_off)),
                ("mbps_on", Json::Num(mbps_on)),
                ("events", Json::Num(events as f64)),
                ("dropped", Json::Num(dropped as f64)),
            ]),
        );
        if let Some(s) = stages {
            out.insert("stage_latency_us".into(), s);
        }
        // smoke contract: recording spans must not wreck throughput (the
        // synthetic device latency dominates; a wide margin keeps CI
        // machines from flaking this)
        assert!(
            mbps_on >= mbps_off * 0.5,
            "tracing overhead out of bounds: {mbps_off:.1} MB/s off vs {mbps_on:.1} MB/s on"
        );
    }

    section("fault matrix: 1% transient EIO on both devices, faults off vs on");
    if Bench::should_run("live/fault-matrix") {
        // A/B the fault-retry pipeline on the mixed load: off is the
        // plain engine, on wraps both devices in a seeded 1% transient
        // EIO script (each fault clears after 2 retries). Transients are
        // absorbed below the completion token, so the contract is zero
        // rejected writes and zero degraded shards — the A/B throughput
        // pair tracks what fault absorption costs across PRs.
        let wfm = mixed(if fast { 8 } else { 32 }, 47);
        let fm_bytes = wfm.total_bytes() as f64;
        let spec = live::FaultSpec::parse("ssd:eio:p=0.01:transient=2,hdd:eio:p=0.01:transient=2")
            .expect("fault spec");
        let mut mbps_off = 0.0f64;
        let mut mbps_on = 0.0f64;
        let mut retries = 0u64;
        let mut transients = 0u64;
        for on in [false, true] {
            let label = if on { "on" } else { "off" };
            let run_spec = if on { spec.clone() } else { live::FaultSpec::default() };
            let mut last = 0.0;
            b.run(&format!("live/faults-{label}"), fm_bytes, || {
                let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(2).with_ssd_mib(32);
                let engine = LiveEngine::mem_faulty(
                    &cfg,
                    SyntheticLatency::ssd(),
                    SyntheticLatency::hdd(),
                    &run_spec,
                    53,
                );
                let report = live::run_load(&engine, &wfm, 8);
                engine.shutdown();
                if on {
                    retries = report.io_retries();
                    transients = report.transient_faults();
                    assert_eq!(report.rejected, 0, "transient faults must not reject writes");
                    assert_eq!(report.degraded_shards(), 0, "transient faults must not degrade shards");
                }
                last = report.throughput_mbps();
                bb(last)
            });
            if on {
                mbps_on = last;
            } else {
                mbps_off = last;
            }
        }
        println!(
            "\nfault matrix: faults off {mbps_off:.1} MB/s -> 1% EIO {mbps_on:.1} MB/s \
             ({retries} retries absorbed, {transients} transient faults)"
        );
        out.insert(
            "fault_matrix".into(),
            Json::obj(vec![
                ("mbps_off", Json::Num(mbps_off)),
                ("mbps_on", Json::Num(mbps_on)),
                ("io_retries", Json::Num(retries as f64)),
                ("transient_faults", Json::Num(transients as f64)),
            ]),
        );
        // smoke contract (blocking in CI's SSDUP_BENCH_FAST=1 step): the
        // script must actually fire, and every fault must be retried to
        // success rather than surfacing to a client
        assert!(retries > 0, "fault script never fired: 0 retries under 1% transient EIO");
    }

    section("live engine on real files (FileBackend, page-cached)");
    if Bench::should_run("live/file-shards-4") {
        let dir = std::env::temp_dir().join(format!("ssdup-bench-live-{}", std::process::id()));
        let wf = mixed(if fast { 8 } else { 32 }, 13);
        let fbytes = wf.total_bytes() as f64;
        let mut last = 0.0;
        b.run("live/file-shards-4", fbytes, || {
            let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(4).with_ssd_mib(16);
            let engine = LiveEngine::file(&cfg, &dir).expect("file backends");
            let report = live::run_load(&engine, &wf, 8);
            engine.shutdown();
            last = report.throughput_mbps();
            bb(last)
        });
        std::fs::remove_dir_all(&dir).ok();
        out.insert("file_shards_4".into(), Json::obj(vec![("mbps", Json::Num(last))]));
    }

    let json = Json::Obj(out);
    match std::fs::write("BENCH_live.json", format!("{json}\n")) {
        Ok(()) => println!("\nwrote BENCH_live.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_live.json: {e}"),
    }
}
