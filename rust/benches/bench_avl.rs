//! AVL buffer-metadata benchmarks (paper §2.5 / Table 1 "AVL cost").
//!
//! Compares the arena AVL against the hash-map alternative the paper
//! rejects (O(1) insert but needs an O(n log n) sort at flush time).

use std::collections::HashMap;

use ssdup::buffer::AvlTree;
use ssdup::util::benchkit::{bb, section, Bench};
use ssdup::util::prng::Prng;

fn keys(n: usize, seed: u64) -> Vec<i64> {
    let mut rng = Prng::new(seed);
    (0..n).map(|_| rng.gen_range(1 << 40) as i64).collect()
}

fn main() {
    let mut b = Bench::new();

    section("insert (random keys)");
    for n in [1_000usize, 16_384, 163_840] {
        // 163840 nodes = the paper's 40 GB / 256 KB accounting
        let name = format!("avl/insert-{n}");
        if Bench::should_run(&name) {
            let ks = keys(n, 3);
            b.run(&name, n as f64, || {
                let mut t = AvlTree::with_capacity(n);
                for &k in &ks {
                    t.insert(k, (k, 512i32));
                }
                bb(t.len())
            });
        }
    }

    section("flush-order traversal: AVL in-order vs hash + sort");
    let n = 65_536;
    let ks = keys(n, 5);
    if Bench::should_run("avl/in-order-traversal") {
        let mut t = AvlTree::with_capacity(n);
        for &k in &ks {
            t.insert(k, (k, 512i32));
        }
        b.run("avl/in-order-traversal", n as f64, || bb(t.in_order().count()));
    }
    if Bench::should_run("hashmap/collect-and-sort") {
        let mut m = HashMap::with_capacity(n);
        for &k in &ks {
            m.insert(k, (k, 512i32));
        }
        b.run("hashmap/collect-and-sort", n as f64, || {
            let mut v: Vec<_> = m.keys().copied().collect();
            v.sort_unstable();
            bb(v.len())
        });
    }

    section("mixed lookup");
    if Bench::should_run("avl/get") {
        let mut t = AvlTree::with_capacity(n);
        for &k in &ks {
            t.insert(k, k);
        }
        let mut i = 0;
        b.run("avl/get", 1.0, || {
            i = (i + 1) % ks.len();
            bb(t.get(ks[i]))
        });
    }
}
