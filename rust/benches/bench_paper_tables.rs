//! Regenerate every paper table/figure as a benchmark run: each experiment
//! is timed end-to-end at the default scale. This is the `cargo bench`
//! entry point for deliverable (d) — the printed tables are the paper's
//! rows/series (see EXPERIMENTS.md for the paper-vs-measured comparison).

use ssdup::experiments::{all_ids, run, Scale};
use ssdup::util::benchkit::section;

fn main() {
    let scale = if std::env::var("SSDUP_BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
        Scale::quick()
    } else {
        Scale::default()
    };
    let as_mib = (scale.gb16() * 512) >> 20;
    println!("experiment suite at scale 1/{} (16 GB file simulates as {as_mib} MiB)\n", scale.factor);
    let mut total = 0.0;
    for id in all_ids() {
        if !ssdup::util::benchkit::Bench::should_run(id) {
            continue;
        }
        section(id);
        let t0 = std::time::Instant::now();
        let rep = run(id, scale).expect("registered experiment");
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        rep.print();
        println!("[{id} regenerated in {dt:.2}s]");
    }
    println!("\nfull paper evaluation regenerated in {total:.1}s");
}
