//! Fault-injection scenario matrix: the seeded [`FaultSpec`] scripts from
//! `--fault-spec` driven through the full engine, asserting the PR's
//! robustness contract end to end — no panic under any script, every
//! acknowledged write byte-exact (including across a crash and
//! recovery), and a shard whose SSD dies or fills keeps accepting
//! writes in sticky degraded mode.
//!
//! Scenarios: transient-EIO storm, slow device, SSD death, device full,
//! crash + recovery under a storm, and the degraded flag surviving a
//! crash via the superblock.

use std::sync::Arc;

use ssdup::live::{
    self, payload, Backend, FaultSpec, LiveConfig, LiveEngine, MemBackend, MemStore, SyntheticLatency,
};
use ssdup::server::SystemKind;
use ssdup::types::{Request, DEFAULT_REQ_SECTORS, SECTOR_BYTES};
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::Workload;

/// A segmented-random burst (disjoint per-process segments, random order
/// inside each), the shape SSDUP+ routes through the SSD buffer.
fn random_burst(mib: i64, procs: u32, seed: u64) -> Workload {
    let sectors = mib * 2048;
    ior_spanned(0, IorPattern::SegmentedRandom, procs, sectors, sectors * 8, DEFAULT_REQ_SECTORS, seed)
}

/// Byte length of one shard's SSD log (both halves): offsets below this
/// are record frames, offsets at or above it are the superblock slots.
/// `dead`/`enospc` clauses scoped with `max_off=<this>` kill the log but
/// spare the superblock, modeling a device whose data blocks fail while
/// the metadata sectors survive.
fn log_bytes(cfg: &LiveConfig) -> u64 {
    2 * (cfg.ssd_capacity_sectors / 2) as u64 * SECTOR_BYTES
}

/// Transient EIO on both tiers: every fault must be retried to success
/// below the completion token — zero rejected writes, zero degraded
/// shards, and the drained data byte-exact.
#[test]
fn transient_eio_storm_absorbed_below_ack() {
    let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(2).with_ssd_mib(32);
    let spec = FaultSpec::parse("ssd:eio:p=0.05:transient=2,hdd:eio:p=0.02:transient=2").unwrap();
    let engine = LiveEngine::mem_faulty(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO, &spec, 7);
    let w = random_burst(8, 4, 11);
    let report = live::run_load(&engine, &w, 4);
    assert_eq!(report.rejected, 0, "transient faults must never reject a write");
    assert!(report.io_retries() > 0, "a 5% EIO script must force retries");
    assert!(report.transient_faults() > 0, "injected transients must be counted");
    assert_eq!(report.degraded_shards(), 0, "transient faults must not degrade a shard");
    let verify = engine.verify_workload(&w);
    assert!(
        verify.is_ok(),
        "acked writes must drain byte-exact under the storm: {} mismatched, {} unreadable",
        verify.mismatched_sectors,
        verify.read_errors
    );
    // reads retry transients inline too: a write/read roundtrip under
    // the same script returns the exact bytes
    let mut buf = vec![0u8; 64 * SECTOR_BYTES as usize];
    payload::fill(90, 0, &mut buf);
    engine.submit(Request { app: 0, proc_id: 0, file: 90, offset: 0, size: 64 }, &buf).unwrap();
    let mut got = vec![0u8; buf.len()];
    engine.read(90, 0, &mut got).unwrap();
    assert_eq!(got, buf, "read under transient EIO must return the acked bytes");
    engine.shutdown();
}

/// `slow` clauses stall, never error: the run completes with no
/// rejections, no degradation, and byte-exact data.
#[test]
fn slow_device_faults_only_add_latency() {
    let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(1).with_ssd_mib(16);
    let spec = FaultSpec::parse("ssd:slow:p=0.05:delay_us=200,hdd:slow:p=0.05:delay_us=200").unwrap();
    let engine = LiveEngine::mem_faulty(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO, &spec, 13);
    let w = random_burst(4, 4, 17);
    let report = live::run_load(&engine, &w, 4);
    assert_eq!(report.rejected, 0, "latency spikes must not reject writes");
    assert_eq!(report.degraded_shards(), 0, "latency spikes must not degrade shards");
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "slow-device run must still drain byte-exact");
    engine.shutdown();
}

/// SSD log dead from the first op (superblock sectors spared): every
/// shard flips into sticky degraded mode on its first buffered write,
/// re-routes direct to the HDD, and still acknowledges everything.
#[test]
fn ssd_death_degrades_and_keeps_accepting_writes() {
    let cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(2).with_ssd_mib(8);
    let spec = FaultSpec::parse(&format!("ssd:dead:max_off={}", log_bytes(&cfg))).unwrap();
    let engine = LiveEngine::mem_faulty(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO, &spec, 19);
    let w = random_burst(4, 4, 23);
    let report = live::run_load(&engine, &w, 4);
    assert_eq!(report.rejected, 0, "degraded shards must keep acking via the HDD");
    assert_eq!(report.degraded_shards(), 2, "a dead SSD must flip every shard it serves");
    let verify = engine.verify_workload(&w);
    assert!(
        verify.is_ok(),
        "degraded-mode writes must land byte-exact on the HDD: {} mismatched, {} unreadable",
        verify.mismatched_sectors,
        verify.read_errors
    );
    let stats = engine.shutdown();
    assert!(stats.iter().all(|s| s.degraded), "degraded flag must be sticky in the stats");
    assert!(stats.iter().any(|s| s.hdd_direct_bytes > 0), "rerouted writes must hit the HDD");
}

/// ENOSPC on every SSD log write: same sticky degraded contract as
/// device death, through the `DeviceFull` classification instead.
#[test]
fn device_full_degrades_to_hdd() {
    let cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(1).with_ssd_mib(8);
    let spec = FaultSpec::parse(&format!("ssd:enospc:max_off={}", log_bytes(&cfg))).unwrap();
    let engine = LiveEngine::mem_faulty(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO, &spec, 29);
    let w = random_burst(4, 2, 31);
    let report = live::run_load(&engine, &w, 2);
    assert_eq!(report.rejected, 0, "a full SSD must degrade, not reject");
    assert_eq!(report.degraded_shards(), 1, "ENOSPC must flip the shard into degraded mode");
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "device-full run must still drain byte-exact");
    engine.shutdown();
}

/// Crash mid-burst under a transient-EIO storm, then recover *with the
/// storm still raging*: every write acknowledged before the crash must
/// verify byte-exact after replay + drain (recovery reads retry
/// transients just like the live path).
#[test]
fn acked_writes_survive_crash_and_recovery_under_storm() {
    let shards = 2usize;
    let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(shards).with_ssd_mib(32);
    let spec = FaultSpec::parse("ssd:eio:p=0.05:transient=2,hdd:eio:p=0.05:transient=2").unwrap();
    let stores: Vec<(Arc<MemStore>, Arc<MemStore>)> =
        (0..shards).map(|_| (MemStore::new(true), MemStore::new(true))).collect();
    let engine = {
        let stores = stores.clone();
        let spec = spec.clone();
        LiveEngine::with_backends(&cfg, move |i| {
            let seed = 0xBEEF + i as u64;
            let ssd = Box::new(MemBackend::over(Arc::clone(&stores[i].0), SyntheticLatency::ZERO))
                as Box<dyn Backend>;
            let hdd = Box::new(MemBackend::over(Arc::clone(&stores[i].1), SyntheticLatency::ZERO))
                as Box<dyn Backend>;
            (spec.wrap_ssd(ssd, seed), spec.wrap_hdd(hdd, seed))
        })
    };
    let w = random_burst(6, 4, 37);
    let mut buf: Vec<u8> = Vec::new();
    for proc in &w.processes {
        for req in &proc.reqs {
            buf.resize(req.bytes() as usize, 0);
            payload::fill(req.file, req.offset as i64, &mut buf);
            engine.submit(*req, &buf).unwrap();
        }
    }
    let frozen: Vec<(Arc<MemStore>, Arc<MemStore>)> =
        stores.iter().map(|(s, h)| (s.freeze(), h.freeze())).collect();
    drop(engine); // crash: no drain, no clean superblock

    let (recovered, report) = LiveEngine::open(&cfg, move |i| {
        let seed = 0xFACE + i as u64;
        let ssd = Box::new(MemBackend::over(Arc::clone(&frozen[i].0), SyntheticLatency::ZERO))
            as Box<dyn Backend>;
        let hdd = Box::new(MemBackend::over(Arc::clone(&frozen[i].1), SyntheticLatency::ZERO))
            as Box<dyn Backend>;
        (spec.wrap_ssd(ssd, seed), spec.wrap_hdd(hdd, seed))
    })
    .expect("recovery must succeed under transient faults");
    assert!(!report.clean(), "a crash without shutdown must be a dirty reopen");
    recovered.drain();
    let verify = recovered.verify_workload(&w);
    assert!(
        verify.is_ok(),
        "every pre-crash ack must survive recovery under the storm: {} mismatched, {} unreadable",
        verify.mismatched_sectors,
        verify.read_errors
    );
    recovered.shutdown();
}

/// The degraded flag is persisted in the superblock when the SSD dies
/// and restored on recovery: a reopened shard does not trust the dead
/// tier again, its pre-crash HDD data reads back exactly, and it keeps
/// accepting new writes.
#[test]
fn degraded_flag_survives_crash_and_recovery() {
    let cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(1).with_ssd_mib(8);
    let spec = FaultSpec::parse(&format!("ssd:dead:max_off={}", log_bytes(&cfg))).unwrap();
    let ssd_store = MemStore::new(true);
    let hdd_store = MemStore::new(true);
    let engine = {
        let (ssd_store, hdd_store) = (Arc::clone(&ssd_store), Arc::clone(&hdd_store));
        let spec = spec.clone();
        LiveEngine::with_backends(&cfg, move |_| {
            let ssd = Box::new(MemBackend::over(Arc::clone(&ssd_store), SyntheticLatency::ZERO))
                as Box<dyn Backend>;
            let hdd = Box::new(MemBackend::over(Arc::clone(&hdd_store), SyntheticLatency::ZERO))
                as Box<dyn Backend>;
            (spec.wrap_ssd(ssd, 41), hdd)
        })
    };
    let reqs = 32i32;
    let mut buf = vec![0u8; 64 * SECTOR_BYTES as usize];
    for i in 0..reqs {
        let off = i * 64;
        payload::fill(1, off as i64, &mut buf);
        engine.submit(Request { app: 0, proc_id: 0, file: 1, offset: off, size: 64 }, &buf).unwrap();
    }
    assert!(engine.stats()[0].degraded, "the dead SSD must degrade the shard before the crash");
    let (ssd_img, hdd_img) = (ssd_store.freeze(), hdd_store.freeze());
    drop(engine); // crash

    // reopen over a healthy device: the superblock flag, not a live
    // probe, must keep the shard off the SSD tier
    let (recovered, _report) = LiveEngine::open(&cfg, move |_| {
        (
            Box::new(MemBackend::over(Arc::clone(&ssd_img), SyntheticLatency::ZERO)) as Box<dyn Backend>,
            Box::new(MemBackend::over(Arc::clone(&hdd_img), SyntheticLatency::ZERO)) as Box<dyn Backend>,
        )
    })
    .expect("reopen of a degraded shard");
    assert!(recovered.stats()[0].degraded, "degraded flag must survive via the superblock");
    let mut got = vec![0u8; buf.len()];
    for i in 0..reqs {
        let off = i * 64;
        payload::fill(1, off as i64, &mut buf);
        recovered.read(1, off, &mut got).unwrap();
        assert_eq!(got, buf, "pre-crash degraded write at sector {off} must read back exactly");
    }
    // the recovered shard keeps accepting writes (still via the HDD)
    let off = reqs * 64;
    payload::fill(1, off as i64, &mut buf);
    recovered.submit(Request { app: 0, proc_id: 0, file: 1, offset: off, size: 64 }, &buf).unwrap();
    recovered.read(1, off, &mut got).unwrap();
    assert_eq!(got, buf, "post-recovery write must ack and read back");
    let stats = recovered.shutdown();
    assert!(stats[0].degraded, "degraded mode stays sticky across the whole recovered run");
}
