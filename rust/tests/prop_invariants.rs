//! Property-based invariants (in-tree quickcheck substrate): coordinator
//! routing, batching, buffering and detection state machines, plus the
//! live engine's overwrite-safety guarantee under random interleaved
//! cross-route rewrites.

use std::time::Duration;

use ssdup::buffer::{AvlTree, BufferOutcome, Pipeline};
use ssdup::detector::native::detect_stream;
use ssdup::device::{Hdd, HddConfig};
use ssdup::fs::StripeLayout;
use ssdup::live::{payload, LiveConfig, LiveEngine, OwnershipMap, SyntheticLatency, Tier};
use ssdup::redirector::{AdaptivePolicy, PercentList, RoutePolicy};
use ssdup::server::metrics::LatencyHistogram;
use ssdup::server::SystemKind;
use ssdup::types::{Detection, Request, SECTOR_BYTES};
use ssdup::util::prng::Prng;
use ssdup::util::quickcheck::forall;

#[test]
fn prop_avl_in_order_is_sorted_and_complete() {
    forall(1, 300, "avl sorted+complete", |rng: &mut Prng, size| {
        let n = rng.range(1, 2 + size * 8);
        (0..n).map(|_| rng.gen_range(1 << 30) as i64).collect::<Vec<i64>>()
    }, |keys| {
        let mut t = AvlTree::new();
        for &k in keys {
            t.insert(k, ());
        }
        if t.check_invariants().is_err() {
            return false;
        }
        let got: Vec<i64> = t.in_order().map(|(k, _)| k).collect();
        let mut want = keys.clone();
        want.sort_unstable();
        want.dedup();
        got == want
    });
}

#[test]
fn prop_avl_random_insert_remove_matches_btreemap() {
    forall(8, 200, "avl remove model", |rng: &mut Prng, size| {
        let ops = rng.range(1, 2 + size * 8);
        let seed = rng.next_u64();
        (ops, seed)
    }, |&(ops, seed)| {
        let mut rng = Prng::new(seed);
        let mut t = AvlTree::new();
        let mut model = std::collections::BTreeMap::new();
        for i in 0..ops {
            let k = rng.gen_range(64) as i64;
            if rng.chance(0.45) {
                if t.remove(k) != model.remove(&k) {
                    return false;
                }
            } else {
                t.insert(k, i);
                model.insert(k, i);
            }
        }
        t.check_invariants().is_ok()
            && t.in_order().map(|(k, v)| (k, *v)).eq(model.into_iter())
    });
}

#[test]
fn prop_live_cross_route_rewrites_stay_byte_exact() {
    // The tentpole property: random interleaved overwrites across routes
    // (SSD-buffered checkpoint, then sequential rewrites the redirector
    // sends to HDD) must leave the HDD byte-exact with the *newest* copy
    // of every sector once drained. Without the sector-ownership map the
    // drain resurrects the stale buffered copies over the rewrites.
    forall(9, 10, "cross-route rewrites", |rng: &mut Prng, size| {
        let slots = 32 + rng.range(0, 1 + size * 4) as i64; // dense slot space
        let rewrites = rng.range(16, 1 + slots.max(17) as usize);
        let seed = rng.next_u64();
        (slots, rewrites, seed)
    }, |&(slots, rewrites, seed)| {
        let mut rng = Prng::new(seed);
        let req_sectors = 16i32;
        let mut cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(1).with_ssd_mib(16);
        cfg.stream_len = 8;
        cfg.flush_check = Duration::from_millis(1);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        let mut latest = vec![1u64; slots as usize];
        let mut buf = vec![0u8; req_sectors as usize * SECTOR_BYTES as usize];
        // phase 1: write every slot once in random order (random traffic
        // -> SSD log after the bootstrap window)
        let mut order: Vec<i64> = (0..slots).collect();
        rng.shuffle(&mut order);
        for &s in &order {
            let offset = (s * req_sectors as i64) as i32;
            payload::fill_gen(1, offset as i64, 1, &mut buf);
            engine
                .submit(Request { app: 0, proc_id: 0, file: 1, offset, size: req_sectors }, &buf)
                .unwrap();
        }
        // phase 2: rewrite a contiguous prefix in ascending order —
        // sequential traffic the redirector reliably sends to HDD, i.e.
        // direct writes over sectors whose stale copies sit in the log
        for s in 0..rewrites.min(slots as usize) as i64 {
            let offset = (s * req_sectors as i64) as i32;
            payload::fill_gen(1, offset as i64, 2, &mut buf);
            engine
                .submit(Request { app: 0, proc_id: 0, file: 1, offset, size: req_sectors }, &buf)
                .unwrap();
            latest[s as usize] = 2;
        }
        engine.drain();
        // every sector must hold its newest generation
        let mut got = vec![0u8; req_sectors as usize * SECTOR_BYTES as usize];
        let mut ok = true;
        for s in 0..slots {
            let offset = (s * req_sectors as i64) as i32;
            engine.read(1, offset, &mut got).unwrap();
            for k in 0..req_sectors as i64 {
                let sector = offset as i64 + k;
                let sb = &got[k as usize * SECTOR_BYTES as usize..(k as usize + 1) * SECTOR_BYTES as usize];
                ok &= payload::sector_matches(1, sector, latest[s as usize], sb);
            }
        }
        // and the stats conserve bytes end to end
        let stats = engine.shutdown();
        let buffered: u64 = stats.iter().map(|st| st.ssd_bytes_buffered).sum();
        let flushed: u64 = stats.iter().map(|st| st.flushed_bytes).sum();
        let superseded: u64 = stats.iter().map(|st| st.superseded_bytes).sum();
        ok && flushed + superseded == buffered
    });
}

#[test]
fn prop_detection_bounds_and_permutation_invariance() {
    forall(2, 300, "detection invariants", |rng: &mut Prng, size| {
        let n = rng.range(2, 2 + size * 8);
        let reqs: Vec<(i32, i32)> = (0..n)
            .map(|_| (rng.gen_range(1 << 24) as i32, 1 + rng.gen_range(2048) as i32))
            .collect();
        let mut perm = reqs.clone();
        rng.shuffle(&mut perm);
        (reqs, perm)
    }, |(a, b)| {
        let da = detect_stream(a);
        let db = detect_stream(b);
        da.s == db.s
            && (0.0..=1.0).contains(&da.percentage)
            && da.s <= a.len() as i32 - 1
            && da.seek_cost_us >= 0.0
    });
}

#[test]
fn prop_percentlist_threshold_is_member_and_order_free() {
    forall(3, 300, "threshold member", |rng: &mut Prng, size| {
        let n = rng.range(1, 2 + size);
        (0..n).map(|_| rng.f64() as f32).collect::<Vec<f32>>()
    }, |ps| {
        let mut l = PercentList::new(256);
        for &p in ps {
            l.insert(p);
        }
        let t = match l.threshold() {
            Some(t) => t,
            None => return false,
        };
        // member of the list and within [min, max]
        l.values().contains(&t)
            && t >= l.values()[0]
            && t <= *l.values().last().unwrap()
            && l.values().windows(2).all(|w| w[0] <= w[1])
    });
}

#[test]
fn prop_adaptive_policy_monotone_response() {
    // a policy that saw only high percentages must route a max-random
    // stream to SSD; one that saw only low percentages must route a
    // zero-random stream to HDD
    forall(4, 200, "adaptive extremes", |rng: &mut Prng, size| {
        let n = rng.range(2, 2 + size);
        let base = 0.2 + 0.6 * rng.f64() as f32;
        (0..n).map(|_| (base + 0.1 * (rng.f64() as f32 - 0.5)).clamp(0.0, 1.0)).collect::<Vec<f32>>()
    }, |ps| {
        let mut policy = AdaptivePolicy::default();
        for &p in ps {
            policy.on_stream(&Detection { s: 0, percentage: p, seek_cost_us: 0.0 });
        }
        let hi = {
            let mut p2 = policy.clone();
            p2.on_stream(&Detection { s: 127, percentage: 1.0, seek_cost_us: 0.0 })
        };
        let lo = {
            let mut p2 = policy.clone();
            p2.on_stream(&Detection { s: 0, percentage: 0.0, seek_cost_us: 0.0 })
        };
        // a fully-random probe must not be routed worse than a fully-
        // sequential probe from the same state
        !(hi == ssdup::types::Route::Hdd && lo == ssdup::types::Route::Ssd)
    });
}

#[test]
fn prop_pipeline_conservation_under_random_ops() {
    forall(5, 150, "pipeline conservation", |rng: &mut Prng, size| {
        let cap = 2 * (64 + rng.gen_range(1 + size as u64 * 64) as i64);
        let ops = rng.range(1, 2 + size * 16);
        let seed = rng.next_u64();
        (cap, ops, seed)
    }, |&(cap, ops, seed)| {
        let mut rng = Prng::new(seed);
        let mut p = Pipeline::new(cap);
        let mut buffered: i64 = 0;
        let mut flushed: i64 = 0;
        for i in 0..ops {
            let size = 1 + rng.gen_range((cap as u64 / 4).max(1)) as i64;
            match p.buffer(0, i as i64 * 10_000, size) {
                BufferOutcome::Buffered { .. } | BufferOutcome::BufferedAndFull { .. } => {
                    buffered += size;
                }
                BufferOutcome::Blocked => {
                    if p.next_flush().is_some() {
                        flushed += p.drain_flushing().iter().map(|e| e.size).sum::<i64>();
                        p.flush_done();
                    }
                }
            }
        }
        loop {
            p.enqueue_residual_flush();
            if p.next_flush().is_none() {
                break;
            }
            flushed += p.drain_flushing().iter().map(|e| e.size).sum::<i64>();
            p.flush_done();
        }
        !p.dirty() && buffered == flushed
    });
}

#[test]
fn prop_striping_conserves_and_localizes() {
    forall(6, 300, "striping", |rng: &mut Prng, size| {
        let nodes = rng.range(1, 5);
        let stripe = 1 + rng.gen_range(256) as i32;
        let off = rng.gen_range(1 << 20) as i32;
        let len = 1 + rng.gen_range(1 + (size as u64) * 64) as i32;
        (nodes, stripe, off, len)
    }, |&(nodes, stripe, off, len)| {
        let layout = StripeLayout { stripe_sectors: stripe, n_nodes: nodes };
        let req = Request { app: 0, proc_id: 0, file: 1, offset: off, size: len };
        let subs = layout.split(req);
        let total: i32 = subs.iter().map(|s| s.size).sum();
        total == len
            && subs.iter().all(|s| s.node < nodes && s.size > 0 && s.local_offset >= 0)
    });
}

#[test]
fn prop_recovered_ownership_matches_btreemap_model_at_any_crash_point() {
    // the crash-recovery replay invariant: truncate a record stream at a
    // random crash point (recovery never sees records past the torn
    // tail), replay the survivors in sequence order through
    // `OwnershipMap::rebuild_from_replay`, and the result must equal a
    // per-sector BTreeMap model of "last writer wins"
    forall(13, 200, "ownership replay model", |rng: &mut Prng, size| {
        let records = rng.range(1, 2 + size * 4);
        let seed = rng.next_u64();
        (records, seed)
    }, |&(records, seed)| {
        let mut rng = Prng::new(seed);
        const SPAN: i64 = 800;
        // generate the full record stream the way a shard would: seqs
        // strictly monotone, per-region log slots allocated densely
        let mut next_slot = [0i64; 2];
        let full: Vec<(u64, i64, i64, usize, i64)> = (0..records)
            .map(|i| {
                let lba = rng.gen_range(SPAN as u64) as i64;
                let sz = 1 + rng.gen_range(48) as i64;
                let region = rng.gen_range(2) as usize;
                let slot = next_slot[region];
                next_slot[region] += sz;
                (i as u64 + 1, lba, sz, region, slot)
            })
            .collect();
        // crash: only a prefix of the stream survives
        let survive = rng.gen_range(records as u64 + 1) as usize;
        let stream = &full[..survive];
        let (map, _superseded) = OwnershipMap::rebuild_from_replay(stream.iter().copied());
        // model: per-sector last writer
        let mut model: std::collections::BTreeMap<i64, (usize, i64)> =
            std::collections::BTreeMap::new();
        for &(_, lba, sz, region, slot) in stream {
            for s in 0..sz {
                model.insert(lba + s, (region, slot + s));
            }
        }
        // compare sector by sector over the whole span
        for (seg_lba, seg_size, tier) in map.resolve(0, SPAN + 64) {
            for s in 0..seg_size {
                let sector = seg_lba + s;
                let expect = model.get(&sector).copied();
                let got = match tier {
                    Tier::Hdd => None,
                    Tier::Ssd { region, ssd_offset } => Some((region, ssd_offset + s)),
                };
                if got != expect {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_histogram_quantile_within_one_bucket_of_sorted_reference() {
    // the accuracy contract stage attribution relies on: for any value
    // mix and any quantile, the histogram's answer lands in the same
    // log-bucket as the exact order-statistic (off by at most one
    // bucket), even though only 512 counters are kept. The exact
    // reference uses the same rank definition as `quantile`:
    // ceil(q * n), clamped to at least the first sample.
    forall(21, 200, "histogram quantile accuracy", |rng: &mut Prng, size| {
        let n = rng.range(1, 2 + size * 8);
        let seed = rng.next_u64();
        (n, seed)
    }, |&(n, seed)| {
        let mut rng = Prng::new(seed);
        let mut h = LatencyHistogram::new();
        let mut values: Vec<u64> = (0..n)
            .map(|_| {
                // span the interesting scales: exact sub-16us values,
                // mid-range, and huge outliers (bounded below 2^50 so
                // the histogram's exact running sum cannot overflow)
                let shift = 14 + rng.gen_range(50) as u32;
                rng.next_u64() >> shift
            })
            .collect();
        for &v in &values {
            h.record(v);
        }
        values.sort_unstable();
        for q in [0.0, 0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let rank = ((q * n as f64).ceil() as usize).max(1).min(n);
            let exact = values[rank - 1];
            let got = h.quantile(q);
            let be = LatencyHistogram::bucket_of(exact) as i64;
            let bg = LatencyHistogram::bucket_of(got) as i64;
            if (be - bg).abs() > 1 {
                return false;
            }
            // and the bucket lower bound never overshoots the exact value
            if got > exact {
                return false;
            }
        }
        h.count() == n as u64 && h.sum_us() == values.iter().sum::<u64>()
    });
}

#[test]
fn prop_histogram_merge_is_associative_and_order_free() {
    // per-thread histograms fold into per-shard sets which fold into the
    // run report: the result must not depend on fold shape or order
    forall(22, 200, "histogram merge associativity", |rng: &mut Prng, size| {
        let n = rng.range(3, 3 + size * 6);
        let seed = rng.next_u64();
        (n, seed)
    }, |&(n, seed)| {
        let mut rng = Prng::new(seed);
        let mut parts = [LatencyHistogram::new(), LatencyHistogram::new(), LatencyHistogram::new()];
        let mut all = LatencyHistogram::new();
        for _ in 0..n {
            let v = rng.next_u64() >> (14 + rng.gen_range(50) as u32);
            parts[rng.gen_range(3) as usize].record(v);
            all.record(v);
        }
        let [a, b, c] = parts;
        // (a ∪ b) ∪ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ∪ (b ∪ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        // c ∪ b ∪ a (commuted)
        let mut rev = c.clone();
        rev.merge(&b);
        rev.merge(&a);
        left == right && left == rev && left == all
    });
}

#[test]
fn prop_hdd_serves_everything_exactly_once() {
    forall(7, 150, "hdd completeness", |rng: &mut Prng, size| {
        let n = rng.range(1, 2 + size * 8);
        let seed = rng.next_u64();
        (n, seed)
    }, |&(n, seed)| {
        let mut rng = Prng::new(seed);
        let mut h: Hdd<u32> = Hdd::new(HddConfig::default());
        for i in 0..n {
            h.enqueue(
                rng.gen_range(1 << 30) as i64,
                1 + rng.gen_range(1024) as i64,
                rng.gen_range(8) as u32,
                i as u32,
            );
        }
        let mut served = Vec::new();
        let mut now = 0;
        loop {
            if let Some(d) = h.try_dispatch(now) {
                served.extend(d.tags);
                now = d.done_at;
                h.complete();
            } else if let Some(dl) = h.idle_deadline() {
                now = dl;
            } else {
                break;
            }
        }
        served.sort_unstable();
        served == (0..n as u32).collect::<Vec<_>>()
    });
}
