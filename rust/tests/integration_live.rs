//! Live-engine integration: the real-time sharded runtime must (a) make
//! the same traffic-detection routing decisions as the discrete-event
//! simulator on matched workloads, (b) land every byte, verifiably, on
//! the HDD backends — including through real files — and (c) survive
//! region-blocking backpressure under a too-small SSD. Rewrite-heavy
//! workloads additionally prove the overwrite-safety tentpole: byte-exact
//! multi-version contents and stale-flush suppression.

use std::time::Duration;

use ssdup::live::{self, LiveConfig, LiveEngine, SyntheticLatency};
use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::types::{DEFAULT_REQ_SECTORS, SECTOR_BYTES};
use ssdup::workload::ior::{ior, ior_spanned, IorPattern};
use ssdup::workload::rewrite::checkpoint_rewrite;
use ssdup::workload::Workload;

fn live_cfg(system: SystemKind, shards: usize, ssd_mib: u64) -> LiveConfig {
    let mut c = LiveConfig::new(system).with_shards(shards).with_ssd_mib(ssd_mib);
    c.flush_check = Duration::from_millis(2); // keep test turnaround fast
    c
}

fn run_live(cfg: &LiveConfig, w: &Workload, clients: usize) -> (f64, LiveEngine) {
    let engine = LiveEngine::mem(cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let report = live::run_load(&engine, w, clients);
    (report.ssd_ratio(), engine)
}

#[test]
fn parity_with_sim_contiguous_load_bypasses_ssd() {
    // 64 MiB segmented-contiguous IOR, 8 procs
    let w = ior(0, IorPattern::SegmentedContiguous, 8, 131_072, DEFAULT_REQ_SECTORS, 9);
    let sim = simulate(&SimConfig::new(SystemKind::SsdupPlus).with_seed(42), &w);
    let (live_ratio, engine) = run_live(&live_cfg(SystemKind::SsdupPlus, 2, 1024), &w, 4);
    assert!(
        sim.ssd_ratio < 0.3,
        "sim: contiguous load should mostly bypass SSD, got {}",
        sim.ssd_ratio
    );
    assert!(
        live_ratio < 0.3,
        "live: contiguous load should mostly bypass SSD, got {live_ratio}"
    );
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "{verify:?}");
    engine.shutdown();
}

#[test]
fn parity_with_sim_random_load_is_buffered() {
    // 128 MiB segmented-random IOR with paper-sparse offsets, 16 procs
    let w = ior_spanned(
        0,
        IorPattern::SegmentedRandom,
        16,
        262_144,
        262_144 * 16,
        DEFAULT_REQ_SECTORS,
        9,
    );
    let sim = simulate(&SimConfig::new(SystemKind::SsdupPlus).with_seed(42), &w);
    let (live_ratio, engine) = run_live(&live_cfg(SystemKind::SsdupPlus, 2, 1024), &w, 4);
    assert!(
        sim.ssd_ratio > 0.5,
        "sim: random load should be buffered, got {}",
        sim.ssd_ratio
    );
    assert!(live_ratio > 0.5, "live: random load should be buffered, got {live_ratio}");
    // same detection + policy code, same striping: the two substrates must
    // agree on the routing split up to arrival-order effects
    assert!(
        (live_ratio - sim.ssd_ratio).abs() < 0.3,
        "live ssd_ratio {live_ratio} vs sim {}",
        sim.ssd_ratio
    );
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "{verify:?}");
    engine.shutdown();
}

#[test]
fn file_backend_drains_and_verifies_in_tempdir() {
    let dir = std::env::temp_dir().join(format!("ssdup-live-it-{}", std::process::id()));
    // 64 MiB sparse-random load over 4 shards with 8 MiB SSD per shard:
    // after the first detection window everything is buffered, so each
    // shard cycles through multiple region flushes on real files
    let sectors = 131_072;
    let w = ior_spanned(0, IorPattern::SegmentedRandom, 8, sectors, sectors * 16, DEFAULT_REQ_SECTORS, 3);
    let mut cfg = live_cfg(SystemKind::SsdupPlus, 4, 8);
    cfg = cfg.with_stream_len(64);
    let engine = LiveEngine::file(&cfg, &dir).expect("create file backends");
    let report = live::run_load(&engine, &w, 8);
    assert_eq!(report.total_bytes, w.total_bytes());
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "file backend verification failed: {verify:?}");
    assert_eq!(verify.checked_bytes, w.total_bytes());
    let stats = engine.shutdown();
    let buffered: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    let flushed: u64 = stats.iter().map(|s| s.flushed_bytes).sum();
    assert!(buffered > w.total_bytes() / 2, "random load must hit the SSD log");
    assert_eq!(flushed, buffered, "every buffered byte must reach HDD by drain");
    assert!(
        stats.iter().map(|s| s.flushes).sum::<u64>() >= 4,
        "small SSD must force multiple flush cycles"
    );
    // the backends are real files on disk
    for i in 0..4 {
        assert!(dir.join(format!("shard{i}-ssd.log")).exists());
        assert!(dir.join(format!("shard{i}-hdd.img")).exists());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn blocked_ingest_backpressure_resolves_and_verifies() {
    // OrangeFS-BB policy (everything to SSD) with a 4 MiB SSD per shard
    // and a deliberately slow HDD flush target: regions fill faster than
    // they drain, so clients must block on the "wait until a region
    // becomes empty" path and be woken again
    let w = ior(0, IorPattern::SegmentedContiguous, 4, 65_536, DEFAULT_REQ_SECTORS, 5);
    let cfg = live_cfg(SystemKind::OrangeFsBB, 2, 4);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::hdd());
    let report = live::run_load(&engine, &w, 4);
    assert!(report.ssd_ratio() > 0.99, "BB routes everything via SSD");
    let stats = engine.stats();
    assert!(
        stats.iter().map(|s| s.blocked_waits).sum::<u64>() > 0,
        "32 MiB through 2x4 MiB SSDs must block at least once"
    );
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "{verify:?}");
    engine.shutdown();
}

#[test]
fn rewrite_workload_is_byte_exact_and_skips_stale_flushes() {
    // every sector written twice: a random checkpoint pass (SSD log)
    // rewritten by a sequential pass (HDD route, absorbed into the log
    // where it overlaps live buffered data). 32 MiB per pass over 2
    // shards; the 64 MiB per-shard SSD keeps the checkpoint resident so
    // the rewrites supersede buffered copies
    let w = checkpoint_rewrite(4, 65_536, 64, 1_000, 7);
    let mut cfg = live_cfg(SystemKind::SsdupPlus, 2, 64);
    cfg = cfg.with_stream_len(32);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let report = live::run_load_with(&engine, &w, 4, true);
    assert_eq!(report.requests, w.total_requests() as u64);

    // byte-exact: every sector holds its *final* writer's generation
    let verify = engine.verify_workload_versioned(&w);
    assert!(verify.is_ok(), "rewrite workload must verify byte-exact: {verify:?}");
    assert_eq!(
        verify.checked_bytes,
        w.total_bytes() / 2,
        "exactly the final copies are checked (each sector written twice)"
    );

    let stats = engine.shutdown();
    let buffered: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    let flushed: u64 = stats.iter().map(|s| s.flushed_bytes).sum();
    let superseded: u64 = stats.iter().map(|s| s.superseded_bytes).sum();
    let rerouted: u64 = stats.iter().map(|s| s.rerouted_writes).sum();
    assert!(buffered > 0, "checkpoint pass must hit the SSD log");
    assert!(
        flushed < buffered,
        "the flusher must skip superseded extents (flushed {flushed} vs buffered {buffered})"
    );
    assert_eq!(
        flushed + superseded,
        buffered,
        "conservation: every buffered byte is either flushed or superseded"
    );
    assert!(rerouted > 0, "cross-route rewrites over live data must be absorbed into the log");
}

#[test]
fn rewrite_workload_verifies_on_real_files() {
    // the same overwrite-safety guarantees through the FileBackend, with
    // a small SSD so superseded extents span multiple region flush cycles
    let dir = std::env::temp_dir().join(format!("ssdup-live-rw-{}", std::process::id()));
    let w = checkpoint_rewrite(4, 65_536, 64, 1_000, 11);
    let mut cfg = live_cfg(SystemKind::SsdupPlus, 2, 8);
    cfg = cfg.with_stream_len(32);
    let engine = LiveEngine::file(&cfg, &dir).expect("create file backends");
    live::run_load_with(&engine, &w, 8, true);
    let verify = engine.verify_workload_versioned(&w);
    assert!(verify.is_ok(), "file-backend rewrite verification failed: {verify:?}");
    let stats = engine.shutdown();
    let buffered: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    let flushed: u64 = stats.iter().map(|s| s.flushed_bytes).sum();
    let superseded: u64 = stats.iter().map(|s| s.superseded_bytes).sum();
    assert_eq!(flushed + superseded, buffered, "conservation under region churn");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mid_burst_reads_see_writes_before_any_drain() {
    // closed-loop read-after-write through LiveEngine::read, before any
    // drain: SSDUP+ bootstraps to the direct HDD route, so this covers
    // the direct path (the SSD-hit and superseded cases live in the
    // engine unit tests)
    let cfg = live_cfg(SystemKind::SsdupPlus, 2, 64);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let mut buf = vec![0u8; DEFAULT_REQ_SECTORS as usize * SECTOR_BYTES as usize];
    ssdup::live::payload::fill(9, 0, &mut buf);
    engine.submit(
        ssdup::types::Request { app: 0, proc_id: 0, file: 9, offset: 0, size: DEFAULT_REQ_SECTORS },
        &buf,
    );
    let mut got = vec![0u8; buf.len()];
    engine.read(9, 0, &mut got);
    assert_eq!(got, buf, "read-your-write before drain");
    // unwritten neighbors read as zeros (sparse HDD hole semantics)
    let mut hole = vec![0xAAu8; 2 * SECTOR_BYTES as usize];
    engine.read(9, 2 * DEFAULT_REQ_SECTORS, &mut hole);
    assert!(hole.iter().all(|&b| b == 0), "holes read as zeros");
    // and the same bytes survive the drain
    engine.drain();
    engine.read(9, 0, &mut got);
    assert_eq!(got, buf, "post-drain read matches");
    engine.shutdown();
}

#[test]
fn per_request_latency_is_recorded() {
    let w = ior(0, IorPattern::SegmentedContiguous, 4, 16_384, DEFAULT_REQ_SECTORS, 5);
    let cfg = live_cfg(SystemKind::SsdupPlus, 2, 64);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let report = live::run_load(&engine, &w, 2);
    assert_eq!(report.latency.count(), w.total_requests() as u64);
    assert!(report.latency.p50() <= report.latency.p95());
    assert!(report.latency.p95() <= report.latency.p99());
    assert!(report.latency.p99() <= report.latency.max_us());
    engine.shutdown();
}
