//! Live-engine integration: the real-time sharded runtime must (a) make
//! the same traffic-detection routing decisions as the discrete-event
//! simulator on matched workloads, (b) land every byte, verifiably, on
//! the HDD backends — including through real files — and (c) survive
//! region-blocking backpressure under a too-small SSD. Rewrite-heavy
//! workloads additionally prove the overwrite-safety tentpole: byte-exact
//! multi-version contents and stale-flush suppression.

use std::time::Duration;

use ssdup::live::{self, LiveConfig, LiveEngine, SyntheticLatency};
use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::types::{DEFAULT_REQ_SECTORS, SECTOR_BYTES};
use ssdup::workload::ior::{ior, ior_spanned, IorPattern};
use ssdup::workload::rewrite::checkpoint_rewrite;
use ssdup::workload::Workload;

fn live_cfg(system: SystemKind, shards: usize, ssd_mib: u64) -> LiveConfig {
    let mut c = LiveConfig::new(system).with_shards(shards).with_ssd_mib(ssd_mib);
    c.flush_check = Duration::from_millis(2); // keep test turnaround fast
    c
}

fn run_live(cfg: &LiveConfig, w: &Workload, clients: usize) -> (f64, LiveEngine) {
    let engine = LiveEngine::mem(cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let report = live::run_load(&engine, w, clients);
    (report.ssd_ratio(), engine)
}

#[test]
fn parity_with_sim_contiguous_load_bypasses_ssd() {
    // 64 MiB segmented-contiguous IOR, 8 procs
    let w = ior(0, IorPattern::SegmentedContiguous, 8, 131_072, DEFAULT_REQ_SECTORS, 9);
    let sim = simulate(&SimConfig::new(SystemKind::SsdupPlus).with_seed(42), &w);
    let (live_ratio, engine) = run_live(&live_cfg(SystemKind::SsdupPlus, 2, 1024), &w, 4);
    assert!(
        sim.ssd_ratio < 0.3,
        "sim: contiguous load should mostly bypass SSD, got {}",
        sim.ssd_ratio
    );
    assert!(
        live_ratio < 0.3,
        "live: contiguous load should mostly bypass SSD, got {live_ratio}"
    );
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "{verify:?}");
    engine.shutdown();
}

#[test]
fn parity_with_sim_random_load_is_buffered() {
    // 128 MiB segmented-random IOR with paper-sparse offsets, 16 procs
    let w = ior_spanned(
        0,
        IorPattern::SegmentedRandom,
        16,
        262_144,
        262_144 * 16,
        DEFAULT_REQ_SECTORS,
        9,
    );
    let sim = simulate(&SimConfig::new(SystemKind::SsdupPlus).with_seed(42), &w);
    let (live_ratio, engine) = run_live(&live_cfg(SystemKind::SsdupPlus, 2, 1024), &w, 4);
    assert!(
        sim.ssd_ratio > 0.5,
        "sim: random load should be buffered, got {}",
        sim.ssd_ratio
    );
    assert!(live_ratio > 0.5, "live: random load should be buffered, got {live_ratio}");
    // same detection + policy code, same striping: the two substrates must
    // agree on the routing split up to arrival-order effects
    assert!(
        (live_ratio - sim.ssd_ratio).abs() < 0.3,
        "live ssd_ratio {live_ratio} vs sim {}",
        sim.ssd_ratio
    );
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "{verify:?}");
    engine.shutdown();
}

#[test]
fn file_backend_drains_and_verifies_in_tempdir() {
    let dir = std::env::temp_dir().join(format!("ssdup-live-it-{}", std::process::id()));
    // 64 MiB sparse-random load over 4 shards with 8 MiB SSD per shard:
    // after the first detection window everything is buffered, so each
    // shard cycles through multiple region flushes on real files
    let sectors = 131_072;
    let w = ior_spanned(0, IorPattern::SegmentedRandom, 8, sectors, sectors * 16, DEFAULT_REQ_SECTORS, 3);
    let mut cfg = live_cfg(SystemKind::SsdupPlus, 4, 8);
    cfg = cfg.with_stream_len(64);
    let engine = LiveEngine::file(&cfg, &dir).expect("create file backends");
    let report = live::run_load(&engine, &w, 8);
    assert_eq!(report.total_bytes, w.total_bytes());
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "file backend verification failed: {verify:?}");
    assert_eq!(verify.checked_bytes, w.total_bytes());
    let stats = engine.shutdown();
    let buffered: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    let flushed: u64 = stats.iter().map(|s| s.flushed_bytes).sum();
    assert!(buffered > w.total_bytes() / 2, "random load must hit the SSD log");
    assert_eq!(flushed, buffered, "every buffered byte must reach HDD by drain");
    assert!(
        stats.iter().map(|s| s.flushes).sum::<u64>() >= 4,
        "small SSD must force multiple flush cycles"
    );
    // the flusher accounts its copy time (companion of flush_pause_us,
    // making the duty cycle computable)
    let run_us: u64 = stats.iter().map(|s| s.flush_run_us).sum();
    assert!(run_us > 0, "flush cycles must book SSD→HDD copy time");
    for s in &stats {
        let duty = s.flush_duty_cycle();
        assert!(
            (0.0..=1.0).contains(&duty),
            "duty cycle must be a fraction, got {duty} (run {} us, pause {} us)",
            s.flush_run_us,
            s.flush_pause_us
        );
    }
    // the backends are real files on disk
    for i in 0..4 {
        assert!(dir.join(format!("shard{i}-ssd.log")).exists());
        assert!(dir.join(format!("shard{i}-hdd.img")).exists());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn blocked_ingest_backpressure_resolves_and_verifies() {
    // OrangeFS-BB policy (everything to SSD) with a 4 MiB SSD per shard
    // and a deliberately slow HDD flush target: regions fill faster than
    // they drain, so clients must block on the "wait until a region
    // becomes empty" path and be woken again
    let w = ior(0, IorPattern::SegmentedContiguous, 4, 65_536, DEFAULT_REQ_SECTORS, 5);
    let cfg = live_cfg(SystemKind::OrangeFsBB, 2, 4);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::hdd());
    let report = live::run_load(&engine, &w, 4);
    assert!(report.ssd_ratio() > 0.99, "BB routes everything via SSD");
    let stats = engine.stats();
    assert!(
        stats.iter().map(|s| s.blocked_waits).sum::<u64>() > 0,
        "32 MiB through 2x4 MiB SSDs must block at least once"
    );
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "{verify:?}");
    engine.shutdown();
}

#[test]
fn rewrite_workload_is_byte_exact_and_skips_stale_flushes() {
    // every sector written twice: a random checkpoint pass (SSD log)
    // rewritten by a sequential pass (HDD route, absorbed into the log
    // where it overlaps live buffered data). 32 MiB per pass over 2
    // shards; the 64 MiB per-shard SSD keeps the checkpoint resident so
    // the rewrites supersede buffered copies
    let w = checkpoint_rewrite(4, 65_536, 64, 1_000, 7);
    let mut cfg = live_cfg(SystemKind::SsdupPlus, 2, 64);
    cfg = cfg.with_stream_len(32);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let report = live::run_load_with(&engine, &w, 4, true);
    assert_eq!(report.requests, w.total_requests() as u64);

    // byte-exact: every sector holds its *final* writer's generation
    let verify = engine.verify_workload_versioned(&w);
    assert!(verify.is_ok(), "rewrite workload must verify byte-exact: {verify:?}");
    assert_eq!(
        verify.checked_bytes,
        w.total_bytes() / 2,
        "exactly the final copies are checked (each sector written twice)"
    );

    let stats = engine.shutdown();
    let buffered: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    let flushed: u64 = stats.iter().map(|s| s.flushed_bytes).sum();
    let superseded: u64 = stats.iter().map(|s| s.superseded_bytes).sum();
    let rerouted: u64 = stats.iter().map(|s| s.rerouted_writes).sum();
    assert!(buffered > 0, "checkpoint pass must hit the SSD log");
    assert!(
        flushed < buffered,
        "the flusher must skip superseded extents (flushed {flushed} vs buffered {buffered})"
    );
    assert_eq!(
        flushed + superseded,
        buffered,
        "conservation: every buffered byte is either flushed or superseded"
    );
    assert!(rerouted > 0, "cross-route rewrites over live data must be absorbed into the log");
}

#[test]
fn rewrite_workload_verifies_on_real_files() {
    // the same overwrite-safety guarantees through the FileBackend, with
    // a small SSD so superseded extents span multiple region flush cycles
    let dir = std::env::temp_dir().join(format!("ssdup-live-rw-{}", std::process::id()));
    let w = checkpoint_rewrite(4, 65_536, 64, 1_000, 11);
    let mut cfg = live_cfg(SystemKind::SsdupPlus, 2, 8);
    cfg = cfg.with_stream_len(32);
    let engine = LiveEngine::file(&cfg, &dir).expect("create file backends");
    live::run_load_with(&engine, &w, 8, true);
    let verify = engine.verify_workload_versioned(&w);
    assert!(verify.is_ok(), "file-backend rewrite verification failed: {verify:?}");
    let stats = engine.shutdown();
    let buffered: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    let flushed: u64 = stats.iter().map(|s| s.flushed_bytes).sum();
    let superseded: u64 = stats.iter().map(|s| s.superseded_bytes).sum();
    assert_eq!(flushed + superseded, buffered, "conservation under region churn");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mid_burst_reads_see_writes_before_any_drain() {
    // closed-loop read-after-write through LiveEngine::read, before any
    // drain: SSDUP+ bootstraps to the direct HDD route, so this covers
    // the direct path (the SSD-hit and superseded cases live in the
    // engine unit tests)
    let cfg = live_cfg(SystemKind::SsdupPlus, 2, 64);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let mut buf = vec![0u8; DEFAULT_REQ_SECTORS as usize * SECTOR_BYTES as usize];
    ssdup::live::payload::fill(9, 0, &mut buf);
    engine
        .submit(
            ssdup::types::Request {
                app: 0,
                proc_id: 0,
                file: 9,
                offset: 0,
                size: DEFAULT_REQ_SECTORS,
            },
            &buf,
        )
        .unwrap();
    let mut got = vec![0u8; buf.len()];
    engine.read(9, 0, &mut got).unwrap();
    assert_eq!(got, buf, "read-your-write before drain");
    // unwritten neighbors read as zeros (sparse HDD hole semantics)
    let mut hole = vec![0xAAu8; 2 * SECTOR_BYTES as usize];
    engine.read(9, 2 * DEFAULT_REQ_SECTORS, &mut hole).unwrap();
    assert!(hole.iter().all(|&b| b == 0), "holes read as zeros");
    // and the same bytes survive the drain
    engine.drain();
    engine.read(9, 0, &mut got).unwrap();
    assert_eq!(got, buf, "post-drain read matches");
    engine.shutdown();
}

#[test]
fn stage_decomposition_reconciles_with_ack_latency() {
    use ssdup::obs::Stage;
    // mixed contiguous + random load so both device routes contribute
    let w = Workload::concurrent(
        "stage-mix",
        ior(0, IorPattern::SegmentedContiguous, 2, 16_384, DEFAULT_REQ_SECTORS, 5),
        ior_spanned(0, IorPattern::SegmentedRandom, 2, 16_384, 16_384 * 16, DEFAULT_REQ_SECTORS, 6),
    );
    let cfg = live_cfg(SystemKind::SsdupPlus, 2, 64);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let report = live::run_load(&engine, &w, 4);
    engine.shutdown();

    let stages = &report.stages;
    assert_eq!(stages.get(Stage::Submit).count(), report.requests);
    assert_eq!(stages.get(Stage::Route).count(), report.requests);
    assert_eq!(stages.get(Stage::Publish).count(), report.requests);
    assert_eq!(
        stages.get(Stage::SsdWrite).count() + stages.get(Stage::HddWrite).count(),
        report.requests,
        "every ack took exactly one device route"
    );
    for s in Stage::ALL {
        let h = stages.get(s);
        assert!(h.p50() <= h.p95() && h.p95() <= h.p99(), "{} quantiles ordered", s.name());
    }
    // the ack components are adjacent spans sharing their boundary
    // timestamps, so their sums reconstruct the total submit latency up
    // to one microsecond of truncation per span (6 spans per ack)
    let total = stages.get(Stage::Submit).sum_us();
    let parts = stages.ack_component_sum_us();
    let slack = 8 * report.requests + 16;
    assert!(
        parts <= total + slack && total <= parts + slack,
        "stage sums must reconcile with ack latency: parts {parts} us vs total {total} us \
         (slack {slack} us over {} requests)",
        report.requests
    );
    assert!(stages.dominant_ack_stage().is_some());
    let summary = report.stage_summary();
    assert!(summary.contains("submit"), "{summary}");
    assert!(summary.contains("dominant ack stage"), "{summary}");
}

#[test]
fn trace_export_covers_every_pipeline_stage() {
    use ssdup::obs::{chrome_trace_json, Stage};
    // one shard, tracing on, small SSD + short streams: the random load
    // bootstraps through the direct HDD route, flips to the SSD log once
    // detection kicks in, and cycles the flusher; a read afterwards
    // covers the read path. That pins down every stage but flush_pause
    // (deterministically exercised in the shard unit tests) and replay
    // (the crash-recovery path, exercised in CI's recover smoke run).
    let sectors = 32_768; // 16 MiB
    let w = ior_spanned(0, IorPattern::SegmentedRandom, 4, sectors, sectors * 16, DEFAULT_REQ_SECTORS, 3);
    let mut cfg = live_cfg(SystemKind::SsdupPlus, 1, 8).with_trace(true);
    cfg = cfg.with_stream_len(16);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let report = live::run_load(&engine, &w, 4);
    assert_eq!(report.requests, w.total_requests() as u64);
    // read back one request's range through the engine (read stages)
    let req = w.processes[0].reqs[0];
    let mut buf = vec![0u8; req.bytes() as usize];
    engine.read(req.file, req.offset, &mut buf).unwrap();

    let obs = std::sync::Arc::clone(engine.trace());
    engine.shutdown(); // the final drain's flush + superblock spans land too
    let events = obs.drain();
    assert!(!events.is_empty());

    let count = |stage: Stage| events.iter().filter(|e| e.stage == stage).count();
    for stage in [
        Stage::Submit,
        Stage::Route,
        Stage::Reserve,
        Stage::SsdWrite,
        Stage::HddWrite,
        Stage::BarrierWait,
        Stage::Publish,
        Stage::ReadResolve,
        Stage::ReadDevice,
        Stage::FlushRun,
        Stage::SbWrite,
    ] {
        assert!(count(stage) > 0, "trace must carry at least one {} span", stage.name());
    }
    assert_eq!(count(Stage::Submit) as u64, report.requests, "one submit span per ack");

    // the export is loadable chrome://tracing JSON
    let doc = chrome_trace_json(&events, obs.dropped_events());
    let parsed = ssdup::util::json::Json::parse(&doc.to_string()).expect("trace JSON re-parses");
    let evs = parsed.get("traceEvents").and_then(|j| j.as_arr()).expect("traceEvents array");
    assert_eq!(evs.len(), events.len());
}

#[test]
fn per_request_latency_is_recorded() {
    let w = ior(0, IorPattern::SegmentedContiguous, 4, 16_384, DEFAULT_REQ_SECTORS, 5);
    let cfg = live_cfg(SystemKind::SsdupPlus, 2, 64);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
    let report = live::run_load(&engine, &w, 2);
    assert_eq!(report.latency.count(), w.total_requests() as u64);
    assert!(report.latency.p50() <= report.latency.p95());
    assert!(report.latency.p95() <= report.latency.p99());
    assert!(report.latency.p99() <= report.latency.max_us());
    engine.shutdown();
}
