//! Full-server integration: the four systems on shared workloads, checking
//! the paper's *ordering* claims end to end.

use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::types::DEFAULT_REQ_SECTORS;
use ssdup::workload::hpio::paper_mixed;
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::mpitileio::paper_pair;
use ssdup::workload::Workload;

fn cfg(system: SystemKind) -> SimConfig {
    SimConfig::new(system).with_seed(77)
}

fn random_ior(sectors: i64, procs: u32, seed: u64) -> Workload {
    ior_spanned(0, IorPattern::SegmentedRandom, procs, sectors, sectors * 8, DEFAULT_REQ_SECTORS, seed)
}

#[test]
fn ssd_systems_beat_native_on_random_loads() {
    let w = random_ior(512 * 1024, 16, 1);
    let native = simulate(&cfg(SystemKind::OrangeFs), &w);
    let bb = simulate(&cfg(SystemKind::OrangeFsBB), &w);
    let plus = simulate(&cfg(SystemKind::SsdupPlus), &w);
    assert!(
        bb.throughput_mbps() > native.throughput_mbps() * 1.2,
        "BB {} vs native {}",
        bb.throughput_mbps(),
        native.throughput_mbps()
    );
    assert!(
        plus.throughput_mbps() > native.throughput_mbps() * 1.2,
        "SSDUP+ {} vs native {}",
        plus.throughput_mbps(),
        native.throughput_mbps()
    );
}

#[test]
fn ssdup_plus_within_bb_envelope_using_less_ssd() {
    // the Fig 11 headline: comparable throughput, less SSD
    let w = Workload::concurrent(
        "mixed",
        ior_spanned(0, IorPattern::SegmentedContiguous, 8, 262_144, 262_144 * 8, DEFAULT_REQ_SECTORS, 2),
        random_ior(262_144, 8, 3),
    );
    let bb = simulate(&cfg(SystemKind::OrangeFsBB), &w);
    let plus = simulate(&cfg(SystemKind::SsdupPlus), &w);
    assert!(
        plus.throughput_mbps() > bb.throughput_mbps() * 0.75,
        "SSDUP+ {:.1} should be within 25% of BB {:.1}",
        plus.throughput_mbps(),
        bb.throughput_mbps()
    );
    assert!(
        plus.ssd_bytes() < bb.ssd_bytes() * 8 / 10,
        "SSDUP+ must save >20% SSD bytes: {} vs {}",
        plus.ssd_bytes(),
        bb.ssd_bytes()
    );
}

#[test]
fn ssdup_plus_saves_ssd_vs_ssdup_on_mixed_loads() {
    let w = Workload::concurrent(
        "mixed",
        ior_spanned(0, IorPattern::SegmentedContiguous, 8, 262_144, 262_144 * 8, DEFAULT_REQ_SECTORS, 4),
        random_ior(262_144, 8, 5),
    );
    let ssdup = simulate(&cfg(SystemKind::Ssdup), &w);
    let plus = simulate(&cfg(SystemKind::SsdupPlus), &w);
    assert!(
        plus.ssd_bytes() <= ssdup.ssd_bytes(),
        "adaptive threshold must not buffer more than static: {} vs {}",
        plus.ssd_bytes(),
        ssdup.ssd_bytes()
    );
}

#[test]
fn hpio_and_tileio_workloads_run_on_all_systems() {
    let hpio = paper_mixed(256, 8, 131_072);
    let tile = paper_pair(16, 131_072);
    for system in SystemKind::ALL {
        for w in [&hpio, &tile] {
            let r = simulate(&cfg(system), w);
            assert_eq!(r.total_bytes, w.total_bytes(), "{}/{}", system.name(), w.name);
            assert!(r.throughput_mbps() > 0.0);
            assert!(r.drained_us >= r.makespan_us);
        }
    }
}

#[test]
fn per_app_stats_are_consistent() {
    let w = Workload::concurrent(
        "two-apps",
        random_ior(131_072, 4, 6),
        random_ior(131_072, 4, 7),
    );
    let r = simulate(&cfg(SystemKind::SsdupPlus), &w);
    assert_eq!(r.per_app.len(), 2);
    let bytes: u64 = r.per_app.iter().map(|a| a.bytes).sum();
    assert_eq!(bytes, r.total_bytes);
    for a in &r.per_app {
        assert!(a.end_us > a.start_us);
        assert!(a.end_us <= r.makespan_us);
    }
}

#[test]
fn queue_size_sweep_changes_stream_len_and_results() {
    let w = ior_spanned(0, IorPattern::Strided, 16, 262_144, 262_144 * 8, DEFAULT_REQ_SECTORS, 8);
    let r32 = simulate(&cfg(SystemKind::OrangeFs).with_queue_size(32), &w);
    let r512 = simulate(&cfg(SystemKind::OrangeFs).with_queue_size(512), &w);
    // allow jitter-level noise; the claim is "no substantial regression"
    assert!(
        r512.throughput_mbps() >= r32.throughput_mbps() * 0.95,
        "bigger CFQ queue must not hurt: {} vs {}",
        r512.throughput_mbps(),
        r32.throughput_mbps()
    );
}

#[test]
fn detection_is_deterministic_across_backends_config() {
    // same seed, same workload -> identical stream statistics
    let w = random_ior(131_072, 8, 9);
    let a = simulate(&cfg(SystemKind::SsdupPlus), &w);
    let b = simulate(&cfg(SystemKind::SsdupPlus), &w);
    assert_eq!(a.mean_percentage, b.mean_percentage);
    assert_eq!(a.ssd_bytes(), b.ssd_bytes());
    assert_eq!(a.makespan_us, b.makespan_us);
}
