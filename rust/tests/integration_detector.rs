//! Detector + redirector integration over realistic arrival traces.

use ssdup::detector::native::detect_stream;
use ssdup::detector::stream::StreamGrouper;
use ssdup::redirector::{AdaptivePolicy, RoutePolicy, WatermarkPolicy};
use ssdup::types::{Request, Route};
use ssdup::util::prng::Prng;

fn push_all(g: &mut StreamGrouper, reqs: &[(i32, i32)]) -> Vec<Vec<(i32, i32)>> {
    let mut out = Vec::new();
    for &(off, size) in reqs {
        let r = Request { app: 0, proc_id: 0, file: 0, offset: off, size };
        if let Some(s) = g.push(&r) {
            out.push(s.reqs);
        }
    }
    out
}

#[test]
fn grouping_plus_detection_classifies_phases() {
    // 4 phases: contiguous, random, contiguous, random — the detector
    // must flag exactly the random phases
    let mut rng = Prng::new(42);
    let mut trace: Vec<(i32, i32)> = Vec::new();
    let phase = 256usize;
    for p in 0..4 {
        if p % 2 == 0 {
            let base = p as i32 * 1_000_000;
            trace.extend((0..phase).map(|i| (base + i as i32 * 512, 512)));
        } else {
            trace.extend((0..phase).map(|_| (rng.gen_range(1 << 25) as i32 * 8, 512)));
        }
    }
    let mut g = StreamGrouper::new(128);
    let streams = push_all(&mut g, &trace);
    assert_eq!(streams.len(), 8);
    let dets: Vec<f32> = streams.iter().map(|s| detect_stream(s).percentage).collect();
    // phases of 256 = 2 streams each; even phases sequential, odd random
    for (i, d) in dets.iter().enumerate() {
        if (i / 2) % 2 == 0 {
            assert!(*d < 0.2, "stream {i} should be sequential, got {d}");
        } else {
            assert!(*d > 0.8, "stream {i} should be random, got {d}");
        }
    }
}

#[test]
fn adaptive_tracks_phase_changes_faster_with_clear() {
    // the §2.3.2 rationale for clearing PercentList on workload change
    let mut policy_cleared = AdaptivePolicy::default();
    let mut policy_stale = AdaptivePolicy::default();
    let high = ssdup::types::Detection { s: 120, percentage: 0.94, seek_cost_us: 0.0 };
    let low = ssdup::types::Detection { s: 5, percentage: 0.04, seek_cost_us: 0.0 };
    for _ in 0..40 {
        policy_cleared.on_stream(&high);
        policy_stale.on_stream(&high);
    }
    // workload changes to sequential
    policy_cleared.on_workload_change();
    let mut cleared_switch = None;
    let mut stale_switch = None;
    for i in 0..40 {
        if policy_cleared.on_stream(&low) == Route::Hdd && cleared_switch.is_none() {
            cleared_switch = Some(i);
        }
        if policy_stale.on_stream(&low) == Route::Hdd && stale_switch.is_none() {
            stale_switch = Some(i);
        }
    }
    let c = cleared_switch.expect("cleared policy must switch");
    let s = stale_switch.unwrap_or(40);
    assert!(c <= s, "cleared history switches no later: {c} vs {s}");
}

#[test]
fn watermark_vs_adaptive_ssd_volume() {
    // moderately-random load: static 45% watermark buffers everything,
    // the adaptive threshold buffers only the upper part (the paper's
    // SSD-savings mechanism)
    let mut rng = Prng::new(7);
    let dets: Vec<ssdup::types::Detection> = (0..400)
        .map(|_| {
            let p = 0.5 + 0.3 * (rng.f64() as f32 - 0.5); // 0.35..0.65
            ssdup::types::Detection { s: 0, percentage: p, seek_cost_us: 0.0 }
        })
        .collect();
    let mut wm = WatermarkPolicy::default();
    let mut ad = AdaptivePolicy::default();
    let wm_ssd = dets.iter().filter(|d| wm.on_stream(d) == Route::Ssd).count();
    let ad_ssd = dets.iter().filter(|d| ad.on_stream(d) == Route::Ssd).count();
    assert!(
        ad_ssd < wm_ssd,
        "adaptive must buffer fewer streams than the static watermark ({ad_ssd} vs {wm_ssd})"
    );
    assert!(ad_ssd > 0, "but not zero — the random share still gets buffered");
}

#[test]
fn stream_length_reconfiguration() {
    // Fig 12: stream length follows the CFQ queue size
    for len in [32usize, 128, 512] {
        let mut g = StreamGrouper::new(len);
        let trace: Vec<(i32, i32)> = (0..len * 2).map(|i| (i as i32 * 512, 512)).collect();
        let streams = push_all(&mut g, &trace);
        assert_eq!(streams.len(), 2);
        assert!(streams.iter().all(|s| s.len() == len));
    }
}
