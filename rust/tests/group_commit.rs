//! Zero-dependency property tests for the group-commit sequencer
//! ([`GroupSync`]) against a counting mock backend, driven through the
//! public API only. The properties under test are the two that make
//! group commit *correct* and *worth having*:
//!
//! 1. **No early release.** A waiter leaves `barrier()` only after a
//!    device sync that **started after its writes completed** has
//!    **finished**. The mock models exactly what a real fsync promises:
//!    at sync *start* it snapshots the offsets written so far, at sync
//!    *end* it marks that snapshot durable — so every publisher can
//!    assert its own offset is durable the instant its barrier returns,
//!    under any interleaving.
//! 2. **Bounded sync count.** Every sync has exactly one leader, and a
//!    leader leads at most once per barrier, so total device syncs can
//!    never exceed total barriers — the ungrouped per-record-sync count
//!    is the worst case, never exceeded.
//!
//! The deterministic leader/follower choreography (exact sync counts,
//! lone-writer latency, sticky failures) lives in `live/commit.rs`'s
//! unit tests; this file shakes the same invariants under scheduler
//! noise: many writers, mixed batching windows, seeded think-time
//! jitter, and a sync that dwells long enough for real pile-ups.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ssdup::live::{Backend, GroupSync};
use ssdup::util::prng::Prng;

/// Mock device with exact fsync semantics (snapshot at sync start,
/// durable at sync end) plus a dwell so concurrent barriers pile up
/// behind a running sync.
struct MockDevice {
    state: Mutex<MockState>,
    syncs_started: AtomicU64,
    dwell: Duration,
}

struct MockState {
    /// offsets written but not yet covered by a finished sync
    pending: Vec<u64>,
    durable: HashSet<u64>,
    writes: u64,
}

impl MockDevice {
    fn new(dwell: Duration) -> Self {
        Self {
            state: Mutex::new(MockState { pending: Vec::new(), durable: HashSet::new(), writes: 0 }),
            syncs_started: AtomicU64::new(0),
            dwell,
        }
    }

    fn is_durable(&self, offset: u64) -> bool {
        self.state.lock().unwrap().durable.contains(&offset)
    }
}

impl Backend for MockDevice {
    fn write_at(&self, offset: u64, _data: &[u8]) -> std::io::Result<()> {
        let mut st = self.state.lock().unwrap();
        st.writes += 1;
        st.pending.push(offset);
        Ok(())
    }

    fn read_at(&self, _offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
        buf.fill(0);
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        self.state.lock().unwrap().writes
    }

    fn sync(&self) -> std::io::Result<()> {
        self.syncs_started.fetch_add(1, Ordering::SeqCst);
        // snapshot at start: writes landing during the dwell are NOT
        // covered by this sync — exactly a real device barrier
        let snap: Vec<u64> = {
            let mut st = self.state.lock().unwrap();
            st.pending.drain(..).collect()
        };
        if !self.dwell.is_zero() {
            std::thread::sleep(self.dwell);
        }
        self.state.lock().unwrap().durable.extend(snap);
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "mock"
    }
}

/// One property run: `threads` ticketed writers, each doing `rounds`
/// write→barrier cycles at globally unique offsets with seeded
/// think-time jitter. `Arc<MockDevice>` is itself a `Backend` (blanket
/// impl), so the sequencer owns one handle while the test keeps another.
fn run_property(threads: u64, rounds: u64, window: Duration, seed: u64) {
    let mock = Arc::new(MockDevice::new(Duration::from_micros(300)));
    let gs = GroupSync::new(Box::new(Arc::clone(&mock)), true, window);
    std::thread::scope(|s| {
        for t in 0..threads {
            let gs = &gs;
            let mock = &mock;
            s.spawn(move || {
                let mut rng = Prng::new(seed * 1000 + t);
                for r in 0..rounds {
                    let offset = t * rounds + r; // globally unique
                    gs.write_at(offset, b"payload").unwrap();
                    gs.barrier().unwrap();
                    // property 1: released only after a sync that started
                    // after this write completed has finished
                    assert!(
                        mock.is_durable(offset),
                        "writer {t} round {r}: released before a covering sync finished"
                    );
                    if rng.gen_range(4) == 0 {
                        std::thread::sleep(Duration::from_micros(rng.gen_range(200)));
                    }
                }
            });
        }
    });
    // property 2: never more device syncs than barriers (the ungrouped
    // worst case), and the sequencer agrees with the device's count
    let barriers = threads * rounds;
    assert_eq!(gs.barriers(), barriers);
    assert!(
        gs.syncs() <= barriers,
        "window {window:?}: {} syncs exceed {} barriers",
        gs.syncs(),
        barriers
    );
    assert_eq!(
        gs.syncs(),
        mock.syncs_started.load(Ordering::SeqCst),
        "sequencer sync count must match the device's"
    );
    assert!(gs.syncs() >= 1, "at least one device sync must have happened");
}

#[test]
fn no_waiter_releases_early_and_syncs_never_exceed_writers() {
    for seed in 0..3 {
        run_property(8, 16, Duration::ZERO, seed);
    }
}

#[test]
fn batching_window_preserves_both_properties() {
    for seed in 0..3 {
        run_property(8, 16, Duration::from_micros(400), seed);
    }
}

#[test]
fn single_writer_many_rounds_is_exact() {
    // with one writer there is nothing to batch: every barrier leads its
    // own sync immediately (the window must not delay it), durability in
    // lockstep
    let mock = Arc::new(MockDevice::new(Duration::ZERO));
    let gs = GroupSync::new(Box::new(Arc::clone(&mock)), true, Duration::from_millis(50));
    for r in 0..32u64 {
        gs.write_at(r * 512, b"x").unwrap();
        gs.barrier().unwrap();
        assert!(mock.is_durable(r * 512));
    }
    assert_eq!(gs.syncs(), 32, "a lone writer's barriers cannot share syncs");
    assert_eq!(gs.barriers(), 32);
}
