//! Zero-dependency concurrency stress test for the live engine's
//! reserve→publish ingest, pinned-extent reads, and lock-free-device
//! flusher: N writer threads + M reader threads hammer **one shard**
//! (`shards = 1`, so every claim, pin, and flush contends on the same
//! core lock) with seeded-RNG overwrites while the flusher cycles
//! regions underneath them.
//!
//! Invariants checked:
//!
//! * **mid-burst sector validity** — every sector a reader observes is
//!   either all-zero (never written) or byte-exactly one of the
//!   generations its owning writer ever produced; sector-granular
//!   tearing, slot recycling under a pinned reader, or a resurrected
//!   stale copy would all fail this;
//! * **final byte-exactness** — after the drain, every slot holds its
//!   *last* written generation (per-writer program order), proving the
//!   ownership map's claim order survived concurrent publishes, valve
//!   writes, and flushes;
//! * **conservation** — `ssd_bytes_buffered == flushed_bytes +
//!   superseded_bytes` once drained, plus exact `bytes_in` accounting.
//!
//! Writers alternate random and sequential slot sweeps (so SSDUP+
//! detection flips routes mid-run, exercising direct writes and the
//! absorb path), and each issues one region-oversized valve write over
//! its live buffered slots — the hardest ordering case the shard
//! supports.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ssdup::live::{payload, LiveConfig, LiveEngine, SyntheticLatency};
use ssdup::server::SystemKind;
use ssdup::types::{Request, SECTOR_BYTES};
use ssdup::util::prng::Prng;

/// writer threads (each owns one file, so writer ranges are disjoint)
const WRITERS: usize = 4;
/// reader threads
const READERS: usize = 3;
/// request-sized slots per writer; rewrites hit the same slots repeatedly
const SLOTS: usize = 24;
/// sectors per slot write
const SLOT_SECTORS: i32 = 8;
/// slot writes per writer
const WRITES: usize = 192;
/// the valve write: larger than one pipeline region (half of the 1 MiB
/// SSD budget = 1024 sectors), over the writer's live buffered slots
const VALVE_SECTORS: i32 = 1040;

fn file_of(writer: usize) -> u32 {
    writer as u32 + 1
}

fn slot_offset(slot: usize) -> i32 {
    slot as i32 * SLOT_SECTORS
}

/// Does `sector_buf` hold a content this writer could legitimately have
/// produced for `(file, sector)` at any point — zero (never written) or
/// any generation the writer ever wrote?
fn sector_is_valid(writer: usize, file: u32, sector: i64, sector_buf: &[u8]) -> bool {
    if sector_buf.iter().all(|&b| b == 0) {
        return true;
    }
    (0..=WRITES as u32)
        .any(|i| payload::sector_matches(file, sector, payload::write_gen(writer as u32, i), sector_buf))
}

#[test]
fn concurrent_writers_readers_and_flusher_preserve_every_byte() {
    // a liveness bug would otherwise hang CI forever: abort loudly instead
    let done = Arc::new(AtomicBool::new(false));
    {
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            for _ in 0..180 {
                std::thread::sleep(Duration::from_secs(1));
                if done.load(Ordering::Relaxed) {
                    return;
                }
            }
            eprintln!("stress_concurrency: deadlock suspected (180 s timeout), aborting");
            std::process::abort();
        });
    }

    let mut cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(1).with_ssd_mib(1);
    cfg.stream_len = 16; // short detection windows: routes flip mid-run
    cfg.flush_check = Duration::from_millis(2);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);

    let stop = AtomicBool::new(false);
    let sector = SECTOR_BYTES as usize;

    // last generation written per (writer, slot), plus the valve gen
    let mut last_gen: Vec<Vec<Option<u64>>> = Vec::new();
    let mut valve_gen: Vec<Option<u64>> = Vec::new();

    std::thread::scope(|s| {
        let engine = &engine;
        let stop = &stop;

        let writer_handles: Vec<_> = (0..WRITERS)
            .map(|w| {
                s.spawn(move || {
                    let mut rng = Prng::new(0xC0FFEE + w as u64);
                    let mut last: Vec<Option<u64>> = vec![None; SLOTS];
                    let mut valve: Option<u64> = None;
                    let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
                    for i in 0..WRITES {
                        // alternate randomly-ordered and sequential slot
                        // sweeps in blocks of 16, so the detector sees
                        // both random and contiguous streams
                        let slot = if (i / 16) % 2 == 0 {
                            rng.gen_range(SLOTS as u64) as usize
                        } else {
                            i % SLOTS
                        };
                        let gen = payload::write_gen(w as u32, i as u32);
                        let off = slot_offset(slot);
                        payload::fill_gen(file_of(w), off as i64, gen, &mut buf);
                        let req = Request {
                            app: w as u16,
                            proc_id: w as u32,
                            file: file_of(w),
                            offset: off,
                            size: SLOT_SECTORS,
                        };
                        engine.submit(req, &buf).unwrap();
                        last[slot] = Some(gen);
                        // mid-run, once: a valve write larger than a
                        // region, straight over the live buffered slots —
                        // it must force the overlap out through the
                        // flusher and then land direct, never resurrecting
                        // anything
                        if i == WRITES / 2 {
                            let gen = payload::write_gen(w as u32, WRITES as u32);
                            let mut big = vec![0u8; VALVE_SECTORS as usize * sector];
                            payload::fill_gen(file_of(w), 0, gen, &mut big);
                            let req = Request {
                                app: w as u16,
                                proc_id: w as u32,
                                file: file_of(w),
                                offset: 0,
                                size: VALVE_SECTORS,
                            };
                            engine.submit(req, &big).unwrap();
                            valve = Some(gen);
                            // the valve covered every slot: it is now the
                            // newest copy everywhere until rewritten
                            last.fill(Some(gen));
                        }
                    }
                    (last, valve)
                })
            })
            .collect();

        let reader_handles: Vec<_> = (0..READERS)
            .map(|r| {
                s.spawn(move || {
                    let mut rng = Prng::new(0xBEEF + r as u64);
                    let mut checked = 0u64;
                    let mut buf = vec![0u8; 4 * SLOT_SECTORS as usize * sector];
                    while !stop.load(Ordering::Relaxed) {
                        let w = rng.gen_range(WRITERS as u64) as usize;
                        // read 1–4 adjacent slots (multi-extent resolves),
                        // or occasionally a range beyond the slot area
                        // (valve-written or never-written territory)
                        let (off, sectors) = if rng.chance(0.15) {
                            (SLOTS as i32 * SLOT_SECTORS, 4 * SLOT_SECTORS)
                        } else {
                            let slots = 1 + rng.gen_range(4) as usize;
                            let first = rng.gen_range((SLOTS - slots + 1) as u64) as usize;
                            (slot_offset(first), slots as i32 * SLOT_SECTORS)
                        };
                        let len = sectors as usize * sector;
                        buf[..len].fill(0xA5);
                        engine.read(file_of(w), off, &mut buf[..len]).unwrap();
                        for k in 0..sectors as i64 {
                            let sec = &buf[k as usize * sector..(k as usize + 1) * sector];
                            assert!(
                                sector_is_valid(w, file_of(w), off as i64 + k, sec),
                                "reader {r}: writer {w} sector {} holds bytes no \
                                 generation ever produced (torn read, recycled slot, \
                                 or stale copy)",
                                off as i64 + k,
                            );
                        }
                        checked += sectors as u64;
                    }
                    checked
                })
            })
            .collect();

        for h in writer_handles {
            let (last, valve) = h.join().expect("writer thread panicked");
            last_gen.push(last);
            valve_gen.push(valve);
        }
        // drain while the readers are still hammering: flush completions
        // must keep waiting out reader pins to the very end
        engine.drain();
        stop.store(true, Ordering::Relaxed);
        let mut checked = 0u64;
        for h in reader_handles {
            checked += h.join().expect("reader thread panicked");
        }
        assert!(checked > 0, "readers must have observed the burst");
    });

    // ---- final byte-exactness: every slot holds its last generation ----
    let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
    let mut expect = vec![0u8; SLOT_SECTORS as usize * sector];
    for w in 0..WRITERS {
        assert!(valve_gen[w].is_some(), "writer {w} issued its valve write");
        for slot in 0..SLOTS {
            let gen = last_gen[w][slot].expect("valve write covered every slot");
            engine.read(file_of(w), slot_offset(slot), &mut buf).unwrap();
            payload::fill_gen(file_of(w), slot_offset(slot) as i64, gen, &mut expect);
            assert_eq!(
                buf, expect,
                "writer {w} slot {slot}: post-drain contents must be generation {gen}"
            );
        }
        // beyond the slots, the valve write's tail is the newest copy
        let tail_off = SLOTS as i32 * SLOT_SECTORS;
        let tail_sectors = VALVE_SECTORS - tail_off;
        let mut tail = vec![0u8; tail_sectors as usize * sector];
        let mut tail_expect = vec![0u8; tail_sectors as usize * sector];
        engine.read(file_of(w), tail_off, &mut tail).unwrap();
        payload::fill_gen(file_of(w), tail_off as i64, valve_gen[w].unwrap(), &mut tail_expect);
        assert_eq!(tail, tail_expect, "writer {w}: valve tail survives byte-exactly");
    }

    // ---- conservation ----
    let stats = engine.shutdown();
    let st = &stats[0];
    let submitted =
        WRITERS as u64 * (WRITES as u64 * SLOT_SECTORS as u64 + VALVE_SECTORS as u64) * SECTOR_BYTES;
    assert_eq!(st.bytes_in, submitted, "every submitted byte was accounted");
    assert_eq!(
        st.ssd_bytes_buffered,
        st.flushed_bytes + st.superseded_bytes,
        "conservation after drain: buffered == flushed + superseded"
    );
    assert!(st.flushes > 1, "the flusher cycled regions under the burst");
    done.store(true, Ordering::Relaxed);
}

/// Clients ≫ I/O workers: 12 closed-loop writers funnel through a
/// **single** submission-queue worker per device. Queue depth must
/// decouple from thread count (many batches resident behind the lone
/// worker), byte-adjacent coalescing must merge every record's
/// header+payload pair into one device write, and after the drain every
/// slot still holds its last written generation.
#[test]
fn many_clients_through_one_io_worker_preserve_every_byte() {
    const CLIENTS: usize = 12;
    const C_SLOTS: usize = 8;
    const C_WRITES: usize = 96;

    let done = Arc::new(AtomicBool::new(false));
    {
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            for _ in 0..180 {
                std::thread::sleep(Duration::from_secs(1));
                if done.load(Ordering::Relaxed) {
                    return;
                }
            }
            eprintln!("stress_concurrency: clients>>workers deadlock suspected, aborting");
            std::process::abort();
        });
    }

    let mut cfg = LiveConfig::new(SystemKind::OrangeFsBB) // everything → SSD log
        .with_shards(1)
        .with_ssd_mib(1)
        .with_io_workers(1)
        .with_io_depth(16);
    cfg.flush_check = Duration::from_millis(2);
    // a little SSD dwell (with a bounded-concurrency knee) keeps batches
    // queued behind the lone worker so real depth builds up
    let engine = LiveEngine::mem(
        &cfg,
        SyntheticLatency { per_op_us: 30, us_per_mib: 0, max_inflight: 4 },
        SyntheticLatency::ZERO,
    );

    let sector = SECTOR_BYTES as usize;
    let mut last_gen: Vec<Vec<Option<u64>>> = Vec::new();
    std::thread::scope(|s| {
        let engine = &engine;
        let handles: Vec<_> = (0..CLIENTS)
            .map(|w| {
                s.spawn(move || {
                    let mut last: Vec<Option<u64>> = vec![None; C_SLOTS];
                    let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
                    for i in 0..C_WRITES {
                        let slot = i % C_SLOTS;
                        let gen = payload::write_gen(w as u32, i as u32);
                        let off = slot_offset(slot);
                        payload::fill_gen(file_of(w), off as i64, gen, &mut buf);
                        let req = Request {
                            app: w as u16,
                            proc_id: w as u32,
                            file: file_of(w),
                            offset: off,
                            size: SLOT_SECTORS,
                        };
                        engine.submit(req, &buf).unwrap();
                        last[slot] = Some(gen);
                    }
                    last
                })
            })
            .collect();
        for h in handles {
            last_gen.push(h.join().expect("writer thread panicked"));
        }
    });
    engine.drain();

    let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
    let mut expect = vec![0u8; SLOT_SECTORS as usize * sector];
    for w in 0..CLIENTS {
        for slot in 0..C_SLOTS {
            let gen = last_gen[w][slot].expect("every slot was rewritten");
            engine.read(file_of(w), slot_offset(slot), &mut buf).unwrap();
            payload::fill_gen(file_of(w), slot_offset(slot) as i64, gen, &mut expect);
            assert_eq!(
                buf, expect,
                "writer {w} slot {slot}: post-drain contents must be generation {gen}"
            );
        }
    }

    let stats = engine.shutdown();
    let st = &stats[0];
    let records = (CLIENTS * C_WRITES) as u64;
    assert_eq!(
        st.bytes_in,
        records * SLOT_SECTORS as u64 * SECTOR_BYTES,
        "every submitted byte was accounted"
    );
    assert!(
        st.io_depth_high_water > 1,
        "12 clients behind one worker must queue deeper than the worker count, \
         got high water {}",
        st.io_depth_high_water
    );
    // every SSD record enqueues header+payload as two byte-adjacent
    // requests that coalesce into one vectored device write, so at
    // least `records` device writes were saved queue-wide
    assert!(
        st.io_reqs - st.io_device_writes >= records,
        "coalescing must merge each record's header+payload pair: \
         {} reqs vs {} device writes for {records} records",
        st.io_reqs,
        st.io_device_writes
    );
    assert!(st.flushes > 1, "the flusher cycled regions under the burst");
    done.store(true, Ordering::Relaxed);
}
