//! Self-test for `ssdup check` (`ssdup::analysis`): five known-bad
//! fixtures — one per lint — each pinned to its expected diagnostic
//! (file, line, context, callee), plus the meta-assertion that the real
//! tree is clean. The fixtures are the lint's contract: if a refactor
//! of the analyzer stops flagging one of these, this test is the tripwire.

use std::collections::BTreeSet;
use std::path::Path;

use ssdup::analysis::diag::Diagnostic;
use ssdup::analysis::lexer::lex_source;
use ssdup::analysis::{atomics, lock_io, panic_free, stages_lint, stats_wiring};

/// (lint, line, context, callee) projection for compact assertions.
fn keys(diags: &[Diagnostic]) -> Vec<(String, u32, String, String)> {
    diags
        .iter()
        .map(|d| (d.lint.to_string(), d.line, d.context.clone(), d.callee.clone()))
        .collect()
}

#[test]
fn lock_io_flags_device_write_under_a_live_core_guard() {
    let src = "impl Shard {\n\
               \x20   fn submit_locked(&self, buf: &[u8]) -> io::Result<()> {\n\
               \x20       let core = self.core.lock().unwrap();\n\
               \x20       self.backend.write_at(0, buf)?;\n\
               \x20       drop(core);\n\
               \x20       Ok(())\n\
               \x20   }\n\
               }\n";
    let files = vec![lex_source("selftest/live/shard.rs", src)];
    let diags = lock_io::check(&files);
    assert_eq!(
        keys(&diags),
        vec![(
            "lock-io".to_string(),
            4,
            "submit_locked".to_string(),
            "write_at".to_string()
        )],
        "exactly the guarded write_at on line 4: {diags:?}"
    );
    assert!(diags[0].message.contains("core lock"), "message names the invariant");
}

#[test]
fn lock_io_stays_quiet_once_the_guard_is_dropped() {
    let src = "impl Shard {\n\
               \x20   fn submit_unlocked(&self, buf: &[u8]) -> io::Result<()> {\n\
               \x20       let core = self.core.lock().unwrap();\n\
               \x20       drop(core);\n\
               \x20       self.backend.write_at(0, buf)\n\
               \x20   }\n\
               }\n";
    let files = vec![lex_source("selftest/live/shard.rs", src)];
    let diags = lock_io::check(&files);
    assert!(diags.is_empty(), "dropped guard means no diagnostic: {diags:?}");
}

#[test]
fn stats_wiring_flags_a_counter_missing_from_every_path() {
    let src = "pub struct ShardStats {\n\
               \x20   pub orphan_counter: u64,\n\
               }\n";
    let files = vec![lex_source("selftest/live/shard.rs", src)];
    let diags = stats_wiring::check(&files);
    let expect: Vec<(String, u32, String, String)> = ["fold", "report", "emit"]
        .iter()
        .map(|c| {
            ("stats-wiring".to_string(), 2, format!("orphan_counter.{c}"), String::new())
        })
        .collect();
    assert_eq!(keys(&diags), expect, "one diagnostic per unwired path: {diags:?}");
}

#[test]
fn stage_taxonomy_flags_unbooked_and_unrequired_variants() {
    let stages = "pub enum Stage {\n\
                  \x20   Submit = 0,\n\
                  \x20   Orphan = 1,\n\
                  }\n\
                  impl Stage {\n\
                  \x20   pub fn name(self) -> &'static str {\n\
                  \x20       match self {\n\
                  \x20           Stage::Submit => \"submit\",\n\
                  \x20           Stage::Orphan => \"orphan\",\n\
                  \x20       }\n\
                  \x20   }\n\
                  }\n";
    let booking = "fn ingest() {\n\
                   \x20   book(Stage::Submit);\n\
                   }\n";
    let files = vec![
        lex_source("selftest/obs/stages.rs", stages),
        lex_source("selftest/live/book.rs", booking),
    ];
    let required: BTreeSet<String> = ["submit".to_string()].into_iter().collect();
    let diags = stages_lint::check(&files, &required);
    assert_eq!(
        keys(&diags),
        vec![
            ("stage-taxonomy".to_string(), 3, "Orphan.booked".to_string(), String::new()),
            ("stage-taxonomy".to_string(), 3, "orphan.require".to_string(), String::new()),
        ],
        "Submit is booked and required; Orphan is neither: {diags:?}"
    );
}

#[test]
fn atomic_ordering_requires_an_adjacent_justification_comment() {
    let src = "fn bump(x: &AtomicU64) {\n\
               \x20   x.fetch_add(1, Ordering::Relaxed);\n\
               }\n\
               fn bump_noted(x: &AtomicU64) {\n\
               \x20   // Relaxed: stats counter, no synchronization implied\n\
               \x20   x.fetch_add(1, Ordering::Relaxed);\n\
               }\n";
    let files = vec![lex_source("selftest/live/counters.rs", src)];
    let diags = atomics::check(&files);
    assert_eq!(
        keys(&diags),
        vec![(
            "atomic-ordering".to_string(),
            2,
            "bump".to_string(),
            "Ordering::Relaxed".to_string()
        )],
        "only the uncommented use fires; the noted one is covered: {diags:?}"
    );
}

#[test]
fn panic_free_bans_unwrap_but_exempts_poison_propagation() {
    let src = "fn classify(e: Option<u32>) -> u32 {\n\
               \x20   let m = std::sync::Mutex::new(0);\n\
               \x20   let _g = m.lock().unwrap();\n\
               \x20   e.unwrap()\n\
               }\n";
    let files = vec![lex_source("selftest/live/fault.rs", src)];
    let diags = panic_free::check(&files);
    assert_eq!(
        keys(&diags),
        vec![("panic-free".to_string(), 4, "classify".to_string(), "unwrap".to_string())],
        "lock().unwrap() is poison propagation; e.unwrap() is the violation: {diags:?}"
    );
}

/// The real tree must be clean: every deliberate exception is either
/// fixed or documented in allow.toml, and no allow entry is stale.
/// This is the same invocation CI blocks on (`ssdup check`).
#[test]
fn the_checked_in_tree_passes_its_own_analyzer() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let outcome = ssdup::analysis::run_check(root).expect("tree is scannable");
    assert!(
        outcome.diags.is_empty(),
        "ssdup check must be clean on the checked-in tree:\n{}",
        outcome
            .diags
            .iter()
            .map(|d| d.render(true))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        outcome.files_scanned > 50,
        "the scan saw the whole tree ({} files)",
        outcome.files_scanned
    );
}
