//! Array-level flush coordination stress test: 4 shards drain into a
//! shared HDD tier while the coordinator's token budget of 2 staggers
//! their flushers.
//!
//! The 4 per-shard HDD backends share one in-flight counter (they model
//! one array tier) and dwell ~1 ms inside every write, so flush runs
//! that *did* overlap would be observed overlapping. Invariants:
//!
//! * **budget** — the shared tier never sees more concurrent flush
//!   writers than `flush_concurrency`, and no starvation-hatch grant
//!   fired (the run never legitimately needed one);
//! * **final byte-exactness** — after the drain every slot holds its
//!   last written generation, coordinator or no coordinator;
//! * **conservation** — `ssd_bytes_buffered == flushed_bytes +
//!   superseded_bytes` per shard, with hot/cold deferral enabled.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ssdup::live::{payload, Backend, LiveConfig, LiveEngine, MemBackend, SyntheticLatency};
use ssdup::server::SystemKind;
use ssdup::types::{Request, SECTOR_BYTES};

/// writer threads; each owns one file
const WRITERS: usize = 4;
/// slots per file; one slot = one 128-sector stripe, so consecutive
/// slots land on consecutive shards
const SLOTS: usize = 16;
/// sectors per slot write (exactly the stripe width)
const SLOT_SECTORS: i32 = 128;
/// full passes over the slots; every pass rewrites every slot
const PASSES: usize = 4;

const FLUSH_BUDGET: usize = 2;

/// HDD wrapper: all four shards' HDD backends share one in-flight
/// counter (they model a single array tier) and dwell inside the write
/// so concurrent flush runs are reliably observed as concurrent.
struct SharedHddProbe {
    inner: MemBackend,
    in_flight: Arc<AtomicU64>,
    high_water: Arc<AtomicU64>,
}

impl SharedHddProbe {
    fn enter(&self) {
        let now = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        self.high_water.fetch_max(now, Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(1));
    }

    fn exit(&self) {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Backend for SharedHddProbe {
    fn write_at(&self, offset: u64, data: &[u8]) -> std::io::Result<()> {
        self.enter();
        let r = self.inner.write_at(offset, data);
        self.exit();
        r
    }

    fn write_vectored_at(&self, offset: u64, bufs: &[&[u8]]) -> std::io::Result<()> {
        self.enter();
        let r = self.inner.write_vectored_at(offset, bufs);
        self.exit();
        r
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
        self.inner.read_at(offset, buf)
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn sync(&self) -> std::io::Result<()> {
        self.inner.sync()
    }

    fn kind(&self) -> &'static str {
        "probe-hdd"
    }
}

fn file_of(writer: usize) -> u32 {
    writer as u32 + 1
}

#[test]
fn coordinated_drain_stays_within_the_flush_budget_and_preserves_every_byte() {
    // a liveness bug would otherwise hang CI forever: abort loudly instead
    let done = Arc::new(AtomicBool::new(false));
    {
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            for _ in 0..180 {
                std::thread::sleep(Duration::from_secs(1));
                if done.load(Ordering::Relaxed) {
                    return;
                }
            }
            eprintln!("flush_coordination: deadlock suspected (180 s timeout), aborting");
            std::process::abort();
        });
    }

    // OrangeFS-BB buffers every write, and a roomy SSD keeps all
    // flushing in the drain — so the drain is the moment all four
    // flushers hit the shared tier at once and the budget must hold.
    let mut cfg = LiveConfig::new(SystemKind::OrangeFsBB)
        .with_shards(WRITERS)
        .with_ssd_mib(16)
        .with_flush_concurrency(FLUSH_BUDGET)
        .with_hot_defer_window(Duration::from_millis(25));
    cfg.flush_check = Duration::from_millis(2);

    let in_flight = Arc::new(AtomicU64::new(0));
    let high_water = Arc::new(AtomicU64::new(0));
    let engine = LiveEngine::with_backends(&cfg, |_| {
        (
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(SharedHddProbe {
                inner: MemBackend::new(SyntheticLatency::ZERO),
                in_flight: Arc::clone(&in_flight),
                high_water: Arc::clone(&high_water),
            }),
        )
    });

    // 4 concurrent writers, PASSES rewrite sweeps each: every slot's
    // earlier copies are superseded in the buffer
    let sector = SECTOR_BYTES as usize;
    std::thread::scope(|s| {
        let engine = &engine;
        for w in 0..WRITERS {
            s.spawn(move || {
                let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
                for i in 0..PASSES * SLOTS {
                    let slot = i % SLOTS;
                    let off = slot as i32 * SLOT_SECTORS;
                    let gen = payload::write_gen(w as u32, i as u32);
                    payload::fill_gen(file_of(w), off as i64, gen, &mut buf);
                    let req = Request {
                        app: w as u16,
                        proc_id: w as u32,
                        file: file_of(w),
                        offset: off,
                        size: SLOT_SECTORS,
                    };
                    engine.submit(req, &buf).unwrap();
                }
            });
        }
    });
    engine.drain();

    // ---- budget: the shared tier never saw more than 2 flush writers ----
    let hw = high_water.load(Ordering::SeqCst);
    assert!(hw >= 1, "the drain moved data through the shared HDD tier");
    assert!(
        hw <= FLUSH_BUDGET as u64,
        "coordinator budget violated: {hw} concurrent flush writers on the shared tier \
         (budget {FLUSH_BUDGET})"
    );
    let co = engine.flush_coordinator().expect("flush_concurrency > 0 builds a coordinator");
    assert_eq!(
        co.beyond_budget_grants(),
        0,
        "a short, low-occupancy drain must never trip the starvation hatch"
    );
    assert_eq!(co.holder_count(), 0, "every token was released");

    // ---- byte-exactness: every slot holds its final generation ----
    let mut got = vec![0u8; SLOT_SECTORS as usize * sector];
    let mut expect = vec![0u8; SLOT_SECTORS as usize * sector];
    for w in 0..WRITERS {
        for slot in 0..SLOTS {
            let off = slot as i32 * SLOT_SECTORS;
            let gen = payload::write_gen(w as u32, ((PASSES - 1) * SLOTS + slot) as u32);
            engine.read(file_of(w), off, &mut got).unwrap();
            payload::fill_gen(file_of(w), off as i64, gen, &mut expect);
            assert_eq!(
                got, expect,
                "writer {w} slot {slot}: post-drain contents must be the last generation"
            );
        }
    }

    // ---- conservation, with deferral enabled ----
    let stats = engine.shutdown();
    let per_writer = (PASSES * SLOTS * SLOT_SECTORS as usize) as u64 * SECTOR_BYTES;
    let rewritten = per_writer - per_writer / PASSES as u64;
    for (i, st) in stats.iter().enumerate() {
        assert_eq!(
            st.ssd_bytes_buffered,
            st.flushed_bytes + st.superseded_bytes,
            "shard {i}: conservation after drain (buffered == flushed + superseded)"
        );
        assert!(st.flush_token_waits >= 1, "shard {i}: every flush cycle takes a token");
        assert_eq!(
            st.superseded_at_flush_bytes, 0,
            "shard {i}: nothing superseded while queued — supersession all preceded the drain"
        );
    }
    // the slots are dealt round-robin onto the shards, so the totals are
    // exact even though the per-shard split depends on the stripe map
    let buffered: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    let superseded: u64 = stats.iter().map(|s| s.superseded_bytes).sum();
    assert_eq!(buffered, WRITERS as u64 * per_writer, "everything routed through the log");
    assert_eq!(superseded, WRITERS as u64 * rewritten, "every earlier pass was superseded");
    done.store(true, Ordering::Relaxed);
}
