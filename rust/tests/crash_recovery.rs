//! Crash-injection tests for the crash-consistent SSD log: kill the
//! engine mid-burst — no drain, no shutdown, flushers mid-flight — then
//! reopen via `LiveEngine::open` and hold it to the durability contract:
//!
//! * **every acknowledged write is byte-exact** after recovery (an ack
//!   happens only after the framed record and its sync barrier hit the
//!   backend, so acknowledged ⇒ durable ⇒ replayed);
//! * **torn tails are discarded whole**: a write in flight at the crash
//!   either recovers completely (its frame validated) or disappears at
//!   record granularity — never as garbage or a half-old half-new
//!   sector;
//! * **clean shutdowns short-circuit**: reopening after
//!   `LiveEngine::shutdown` scans zero log sectors.
//!
//! The in-memory crash rig uses `MemStore`'s snapshot mode: writes land
//! in a volatile overlay, the publish-path `sync` merges them durable,
//! and `freeze()` clones the durable pages *while writer threads are
//! mid-write* — a genuine power-loss image with torn in-flight records,
//! zero external dependencies. The file rig kills by abandoning the
//! engine (drop without shutdown) and reopening the images from disk.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ssdup::live::{
    payload, LiveConfig, LiveEngine, MemBackend, MemStore, SyntheticLatency,
};
use ssdup::server::SystemKind;
use ssdup::types::{Request, SECTOR_BYTES};
use ssdup::util::prng::Prng;
use ssdup::workload::ior::{ior_spanned, IorPattern};

/// writer lanes (one file each, so lanes never interact)
const LANES: usize = 3;
/// rewrite slots per lane
const SLOTS: usize = 16;
/// sectors per slot write (stripes split each across both shards)
const SLOT_SECTORS: i32 = 8;
/// hard cap on writes per lane (the crash usually fires much earlier)
const MAX_WRITES: usize = 300;

fn lane_file(lane: usize) -> u32 {
    lane as u32 + 1
}

/// Per-lane write log. The lane's writer is single-threaded, so acks
/// happen in issue order: `issued[..acked]` is exactly the acknowledged
/// prefix, and `issued[acked..]` the (at most one) write in flight.
#[derive(Default)]
struct LaneLog {
    issued: Vec<(usize, u64)>, // (slot, gen)
    acked: usize,
}

fn crash_cfg(ssd_sectors: i64) -> LiveConfig {
    // everything routes to the SSD log (frames + flush churn on a tiny
    // SSD); 4-sector stripes split every slot write across both shards,
    // so sub-records can tear independently
    let mut cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(2);
    cfg.ssd_capacity_sectors = ssd_sectors;
    cfg.stripe_sectors = 4;
    cfg.flush_check = Duration::from_millis(1);
    cfg
}

/// One seeded crash point: run concurrent rewrite lanes over
/// snapshot-mode stores, freeze mid-flight, reopen from the frozen
/// image, and check the contract lane by lane, sector by sector.
fn crash_and_recover_mem(seed: u64) {
    let cfg = crash_cfg(if seed % 2 == 0 { 256 } else { 1 << 16 });
    let stores: Vec<(Arc<MemStore>, Arc<MemStore>)> =
        (0..cfg.shards).map(|_| (MemStore::new(true), MemStore::new(true))).collect();
    let engine = {
        let stores = stores.clone();
        LiveEngine::with_backends(&cfg, move |i| {
            (
                // a little SSD dwell keeps writes in flight long enough
                // for the freeze to catch them mid-record
                Box::new(MemBackend::over(
                    Arc::clone(&stores[i].0),
                    SyntheticLatency { per_op_us: 150, us_per_mib: 0 },
                )) as Box<dyn ssdup::live::Backend>,
                Box::new(MemBackend::over(Arc::clone(&stores[i].1), SyntheticLatency::ZERO))
                    as Box<dyn ssdup::live::Backend>,
            )
        })
    };

    let logs: Vec<Mutex<LaneLog>> = (0..LANES).map(|_| Mutex::new(LaneLog::default())).collect();
    let stop = AtomicBool::new(false);
    let sector = SECTOR_BYTES as usize;
    let crash_threshold = 24 + (seed * 7) % 40; // seeded mid-burst point

    // `snapshot` is each lane's log as of *just before* the freeze — its
    // `acked` prefix is the set recovery must restore. The final `logs`
    // (read after the writers join) hold every generation ever issued,
    // which is the candidate set for sectors that kept moving between
    // the snapshot and the freeze.
    type LaneSnapshot = (Vec<(usize, u64)>, usize);
    let (snapshot, frozen): (Vec<LaneSnapshot>, Vec<(Arc<MemStore>, Arc<MemStore>)>) =
        std::thread::scope(|s| {
        let engine = &engine;
        let stop = &stop;
        let logs = &logs;
        for lane in 0..LANES {
            s.spawn(move || {
                let mut rng = Prng::new(seed * 1000 + lane as u64);
                let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
                for i in 0..MAX_WRITES {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let slot = rng.gen_range(SLOTS as u64) as usize;
                    let gen = payload::write_gen(lane as u32, i as u32);
                    let off = slot as i32 * SLOT_SECTORS;
                    payload::fill_gen(lane_file(lane), off as i64, gen, &mut buf);
                    logs[lane].lock().unwrap().issued.push((slot, gen));
                    engine.submit(
                        Request {
                            app: lane as u16,
                            proc_id: lane as u32,
                            file: lane_file(lane),
                            offset: off,
                            size: SLOT_SECTORS,
                        },
                        &buf,
                    );
                    logs[lane].lock().unwrap().acked += 1;
                }
            });
        }
        // wait for the seeded number of acknowledged writes, then crash
        loop {
            let total: usize = logs.iter().map(|l| l.lock().unwrap().acked).sum();
            if total as u64 >= crash_threshold || stop.load(Ordering::Relaxed) {
                break;
            }
            std::thread::sleep(Duration::from_micros(300));
        }
            // ---- the crash. Order matters: snapshot the ack logs
            // FIRST, then freeze the stores — anything acked before the
            // log snapshot finished its sync barrier before the freeze,
            // so it must be in the frozen image ----
            let snapshot: Vec<LaneSnapshot> = logs
                .iter()
                .map(|l| {
                    let log = l.lock().unwrap();
                    (log.issued.clone(), log.acked)
                })
                .collect();
            let frozen: Vec<(Arc<MemStore>, Arc<MemStore>)> =
                stores.iter().map(|(ssd, hdd)| (ssd.freeze(), hdd.freeze())).collect();
            stop.store(true, Ordering::Relaxed);
            (snapshot, frozen) // writer threads join at scope end
        });
    drop(engine); // the old engine dies; the frozen image is the truth

    // ---- reopen from the power-loss image ----
    let pairs = frozen.clone();
    let (recovered, report) = LiveEngine::open(&cfg, move |i| {
        (
            Box::new(MemBackend::over(Arc::clone(&pairs[i].0), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
            Box::new(MemBackend::over(Arc::clone(&pairs[i].1), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
        )
    })
    .expect("recovery must succeed");
    assert!(!report.clean(), "seed {seed}: a crash is never a clean shutdown");
    assert!(report.sectors_scanned() > 0, "seed {seed}: dirty reopen must scan the logs");

    // ---- the contract, sector by sector ----
    let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
    for lane in 0..LANES {
        let log = logs[lane].lock().unwrap(); // complete issue history (writers joined)
        let (snap_issued, snap_acked) = &snapshot[lane];
        for slot in 0..SLOTS {
            // candidate generations: everything the lane *ever* issued
            // for this slot (writes between the snapshot and the freeze
            // may have become durable too — they are newer, not wrong).
            // The floor is the newest generation acknowledged before the
            // snapshot: monotone gens, so the last acked occurrence is
            // the max, and recovery may never fall below it.
            let candidates: Vec<u64> =
                log.issued.iter().filter(|(s, _)| *s == slot).map(|&(_, g)| g).collect();
            let last_acked: Option<u64> = snap_issued[..*snap_acked]
                .iter()
                .filter(|(s, _)| *s == slot)
                .map(|&(_, g)| g)
                .last();
            let off = slot as i32 * SLOT_SECTORS;
            recovered.read(lane_file(lane), off, &mut buf);
            for k in 0..SLOT_SECTORS as usize {
                let sec = &buf[k * sector..(k + 1) * sector];
                let sec_idx = off as i64 + k as i64;
                let floor = last_acked.unwrap_or(0);
                let ok = (last_acked.is_none() && sec.iter().all(|&b| b == 0))
                    || candidates.iter().any(|&g| {
                        g >= floor && payload::sector_matches(lane_file(lane), sec_idx, g, sec)
                    });
                assert!(
                    ok,
                    "seed {seed}: lane {lane} slot {slot} sector {sec_idx} recovered to bytes \
                     that are neither the last acknowledged generation ({last_acked:?}) nor a \
                     newer issued one — acknowledged data was lost or a torn record leaked"
                );
            }
        }
    }

    // the recovered data must also drain through the normal flush path
    // and settle identically on the HDD
    let mut before = vec![0u8; SLOT_SECTORS as usize * sector];
    recovered.read(lane_file(0), 0, &mut before);
    recovered.drain();
    recovered.read(lane_file(0), 0, &mut buf);
    assert_eq!(buf, before, "seed {seed}: the drain must not change recovered contents");
    recovered.shutdown();
}

#[test]
fn mem_snapshot_crashes_at_eight_seeded_points_recover_acknowledged_writes() {
    for seed in 0..8 {
        crash_and_recover_mem(seed);
    }
}

#[test]
fn file_backend_killed_mid_burst_recovers_and_verifies() {
    let dir = std::env::temp_dir().join(format!("ssdup-crash-{}", std::process::id()));
    // sparse random burst, small SSD: several flush cycles happen before
    // the kill, so recovery sees settled regions (watermark skips),
    // still-buffered records (replay), and a dirty superblock
    let sectors = 16_384; // 8 MiB
    let w = ior_spanned(0, IorPattern::SegmentedRandom, 4, sectors, sectors * 16, 128, 21);
    let mut cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(2).with_ssd_mib(1);
    cfg.flush_check = Duration::from_millis(1);
    {
        let engine = LiveEngine::file(&cfg, &dir).expect("create file backends");
        // submit everything but never drain: at the "kill" below, some
        // regions have flushed (their watermarks persisted), the rest of
        // the burst is still buffered in the log
        let mut buf: Vec<u8> = Vec::new();
        for proc in &w.processes {
            for req in &proc.reqs {
                buf.resize(req.bytes() as usize, 0);
                payload::fill(req.file, req.offset as i64, &mut buf);
                engine.submit(*req, &buf);
            }
        }
        // CRASH: drop without drain or shutdown — the flushers die
        // wherever they are, the superblock stays dirty
    }
    let (engine, report) = LiveEngine::open_file(&cfg, &dir).expect("reopen images");
    assert!(!report.clean(), "an abandoned engine must reopen dirty");
    // every write was acknowledged, so every byte must be served — from
    // the replayed log or the HDD — before any new drain
    let sector = SECTOR_BYTES as usize;
    let mut got = vec![0u8; 128 * sector];
    let mut expect = vec![0u8; 128 * sector];
    for proc in &w.processes {
        for req in &proc.reqs {
            payload::fill(req.file, req.offset as i64, &mut expect);
            engine.read(req.file, req.offset, &mut got);
            assert_eq!(
                got, expect,
                "acknowledged write at offset {} lost or corrupted by the crash",
                req.offset
            );
        }
    }
    // and after draining, the standard whole-workload verifier agrees
    engine.drain();
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "post-recovery drain verification failed: {verify:?}");
    engine.shutdown();

    // a clean shutdown happened above: the next reopen short-circuits
    let (engine, report) = LiveEngine::open_file(&cfg, &dir).expect("clean reopen");
    assert!(report.clean(), "orderly shutdown must leave clean superblocks");
    assert_eq!(report.sectors_scanned(), 0, "clean reopen must not scan any log");
    assert_eq!(report.records_replayed(), 0);
    // the data is still there, through the recovered file table
    let req = w.processes[0].reqs[0];
    payload::fill(req.file, req.offset as i64, &mut expect);
    engine.read(req.file, req.offset, &mut got);
    assert_eq!(got, expect, "clean reopen must still serve the settled data");
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recovery_rejects_a_foreign_shard_log() {
    // shard ids are stamped into records and superblocks: reopening a
    // log with the wrong topology must not silently replay garbage.
    // Here shard 1's image is fed to a 1-shard engine (which expects
    // shard id 0 everywhere): nothing validates, nothing is replayed.
    let store = MemStore::new(false);
    let hdd = MemStore::new(false);
    let cfg_two = crash_cfg(4096);
    {
        let stores = vec![
            (MemStore::new(false), MemStore::new(false)),
            (Arc::clone(&store), Arc::clone(&hdd)),
        ];
        let engine = LiveEngine::with_backends(&cfg_two, move |i| {
            (
                Box::new(MemBackend::over(Arc::clone(&stores[i].0), SyntheticLatency::ZERO))
                    as Box<dyn ssdup::live::Backend>,
                Box::new(MemBackend::over(Arc::clone(&stores[i].1), SyntheticLatency::ZERO))
                    as Box<dyn ssdup::live::Backend>,
            )
        });
        let mut buf = vec![0u8; 8 * SECTOR_BYTES as usize];
        payload::fill(1, 0, &mut buf);
        engine.submit(Request { app: 0, proc_id: 0, file: 1, offset: 0, size: 8 }, &buf);
        // crash without shutdown
    }
    let mut cfg_one = crash_cfg(4096);
    cfg_one.shards = 1;
    let (engine, report) = LiveEngine::open(&cfg_one, |_| {
        (
            Box::new(MemBackend::over(Arc::clone(&store), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
            Box::new(MemBackend::over(Arc::clone(&hdd), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
        )
    })
    .expect("open succeeds");
    assert_eq!(report.records_replayed(), 0, "foreign-shard records must not replay");
    engine.shutdown();
}
