//! Crash-injection tests for the crash-consistent SSD log: kill the
//! engine mid-burst — no drain, no shutdown, flushers mid-flight — then
//! reopen via `LiveEngine::open` and hold it to the durability contract:
//!
//! * **every acknowledged write is byte-exact** after recovery (an ack
//!   happens only after the framed record and its sync barrier hit the
//!   backend, so acknowledged ⇒ durable ⇒ replayed);
//! * **torn tails are discarded whole**: a write in flight at the crash
//!   either recovers completely (its frame validated) or disappears at
//!   record granularity — never as garbage or a half-old half-new
//!   sector;
//! * **a write frozen anywhere inside the submission/completion queue is
//!   still unacknowledged** and is allowed to vanish — the deterministic
//!   `PausePoint` rig parks the I/O worker at exactly the chosen instant
//!   (before the device write: the request sits in the submission queue
//!   with nothing on the device; after it: the bytes landed but the
//!   completion was never processed, so no barrier covers them) while
//!   the client stays parked on its completion token, and freezes the
//!   power-loss image around the stall;
//! * **clean shutdowns short-circuit**: reopening after
//!   `LiveEngine::shutdown` scans zero log sectors.
//!
//! The in-memory crash rig uses `MemStore`'s snapshot mode: writes land
//! in a volatile overlay, the publish-path `sync` merges them durable,
//! and `freeze()` clones the durable pages *while writer threads are
//! mid-write* — a genuine power-loss image with torn in-flight records,
//! zero external dependencies. The file rig kills by abandoning the
//! engine (drop without shutdown) and reopening the images from disk.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use ssdup::live::{
    payload, LiveConfig, LiveEngine, MemBackend, MemStore, SyntheticLatency,
};
use ssdup::server::SystemKind;
use ssdup::types::{Request, SECTOR_BYTES};
use ssdup::util::prng::Prng;
use ssdup::workload::ior::{ior_spanned, IorPattern};

/// writer lanes (one file each, so lanes never interact)
const LANES: usize = 3;
/// rewrite slots per lane
const SLOTS: usize = 16;
/// sectors per slot write (stripes split each across both shards)
const SLOT_SECTORS: i32 = 8;
/// hard cap on writes per lane (the crash usually fires much earlier)
const MAX_WRITES: usize = 300;

fn lane_file(lane: usize) -> u32 {
    lane as u32 + 1
}

/// Per-lane write log. The lane's writer is single-threaded, so acks
/// happen in issue order: `issued[..acked]` is exactly the acknowledged
/// prefix, and `issued[acked..]` the (at most one) write in flight.
#[derive(Default)]
struct LaneLog {
    issued: Vec<(usize, u64)>, // (slot, gen)
    acked: usize,
}

fn crash_cfg(ssd_sectors: i64) -> LiveConfig {
    // everything routes to the SSD log (frames + flush churn on a tiny
    // SSD); 4-sector stripes split every slot write across both shards,
    // so sub-records can tear independently
    let mut cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(2);
    cfg.ssd_capacity_sectors = ssd_sectors;
    cfg.stripe_sectors = 4;
    cfg.flush_check = Duration::from_millis(1);
    cfg
}

/// One seeded crash point: run concurrent rewrite lanes over
/// snapshot-mode stores, freeze mid-flight, reopen from the frozen
/// image, and check the contract lane by lane, sector by sector.
fn crash_and_recover_mem(seed: u64) {
    let cfg = crash_cfg(if seed % 2 == 0 { 256 } else { 1 << 16 });
    let stores: Vec<(Arc<MemStore>, Arc<MemStore>)> =
        (0..cfg.shards).map(|_| (MemStore::new(true), MemStore::new(true))).collect();
    let engine = {
        let stores = stores.clone();
        LiveEngine::with_backends(&cfg, move |i| {
            (
                // a little SSD dwell keeps writes in flight long enough
                // for the freeze to catch them mid-record
                Box::new(MemBackend::over(
                    Arc::clone(&stores[i].0),
                    SyntheticLatency { per_op_us: 150, us_per_mib: 0, max_inflight: 0 },
                )) as Box<dyn ssdup::live::Backend>,
                Box::new(MemBackend::over(Arc::clone(&stores[i].1), SyntheticLatency::ZERO))
                    as Box<dyn ssdup::live::Backend>,
            )
        })
    };

    let logs: Vec<Mutex<LaneLog>> = (0..LANES).map(|_| Mutex::new(LaneLog::default())).collect();
    let stop = AtomicBool::new(false);
    let sector = SECTOR_BYTES as usize;
    let crash_threshold = 24 + (seed * 7) % 40; // seeded mid-burst point

    // `snapshot` is each lane's log as of *just before* the freeze — its
    // `acked` prefix is the set recovery must restore. The final `logs`
    // (read after the writers join) hold every generation ever issued,
    // which is the candidate set for sectors that kept moving between
    // the snapshot and the freeze.
    type LaneSnapshot = (Vec<(usize, u64)>, usize);
    let (snapshot, frozen): (Vec<LaneSnapshot>, Vec<(Arc<MemStore>, Arc<MemStore>)>) =
        std::thread::scope(|s| {
        let engine = &engine;
        let stop = &stop;
        let logs = &logs;
        for lane in 0..LANES {
            s.spawn(move || {
                let mut rng = Prng::new(seed * 1000 + lane as u64);
                let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
                for i in 0..MAX_WRITES {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let slot = rng.gen_range(SLOTS as u64) as usize;
                    let gen = payload::write_gen(lane as u32, i as u32);
                    let off = slot as i32 * SLOT_SECTORS;
                    payload::fill_gen(lane_file(lane), off as i64, gen, &mut buf);
                    logs[lane].lock().unwrap().issued.push((slot, gen));
                    engine
                        .submit(
                            Request {
                                app: lane as u16,
                                proc_id: lane as u32,
                                file: lane_file(lane),
                                offset: off,
                                size: SLOT_SECTORS,
                            },
                            &buf,
                        )
                        .unwrap();
                    logs[lane].lock().unwrap().acked += 1;
                }
            });
        }
        // wait for the seeded number of acknowledged writes, then crash
        loop {
            let total: usize = logs.iter().map(|l| l.lock().unwrap().acked).sum();
            if total as u64 >= crash_threshold || stop.load(Ordering::Relaxed) {
                break;
            }
            std::thread::sleep(Duration::from_micros(300));
        }
            // ---- the crash. Order matters: snapshot the ack logs
            // FIRST, then freeze the stores — anything acked before the
            // log snapshot finished its sync barrier before the freeze,
            // so it must be in the frozen image ----
            let snapshot: Vec<LaneSnapshot> = logs
                .iter()
                .map(|l| {
                    let log = l.lock().unwrap();
                    (log.issued.clone(), log.acked)
                })
                .collect();
            let frozen: Vec<(Arc<MemStore>, Arc<MemStore>)> =
                stores.iter().map(|(ssd, hdd)| (ssd.freeze(), hdd.freeze())).collect();
            stop.store(true, Ordering::Relaxed);
            (snapshot, frozen) // writer threads join at scope end
        });
    drop(engine); // the old engine dies; the frozen image is the truth

    // ---- reopen from the power-loss image ----
    let pairs = frozen.clone();
    let (recovered, report) = LiveEngine::open(&cfg, move |i| {
        (
            Box::new(MemBackend::over(Arc::clone(&pairs[i].0), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
            Box::new(MemBackend::over(Arc::clone(&pairs[i].1), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
        )
    })
    .expect("recovery must succeed");
    assert!(!report.clean(), "seed {seed}: a crash is never a clean shutdown");
    assert!(report.sectors_scanned() > 0, "seed {seed}: dirty reopen must scan the logs");

    // ---- the contract, sector by sector ----
    let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
    for lane in 0..LANES {
        let log = logs[lane].lock().unwrap(); // complete issue history (writers joined)
        let (snap_issued, snap_acked) = &snapshot[lane];
        for slot in 0..SLOTS {
            // candidate generations: everything the lane *ever* issued
            // for this slot (writes between the snapshot and the freeze
            // may have become durable too — they are newer, not wrong).
            // The floor is the newest generation acknowledged before the
            // snapshot: monotone gens, so the last acked occurrence is
            // the max, and recovery may never fall below it.
            let candidates: Vec<u64> =
                log.issued.iter().filter(|(s, _)| *s == slot).map(|&(_, g)| g).collect();
            let last_acked: Option<u64> = snap_issued[..*snap_acked]
                .iter()
                .filter(|(s, _)| *s == slot)
                .map(|&(_, g)| g)
                .last();
            let off = slot as i32 * SLOT_SECTORS;
            recovered.read(lane_file(lane), off, &mut buf).unwrap();
            for k in 0..SLOT_SECTORS as usize {
                let sec = &buf[k * sector..(k + 1) * sector];
                let sec_idx = off as i64 + k as i64;
                let floor = last_acked.unwrap_or(0);
                let ok = (last_acked.is_none() && sec.iter().all(|&b| b == 0))
                    || candidates.iter().any(|&g| {
                        g >= floor && payload::sector_matches(lane_file(lane), sec_idx, g, sec)
                    });
                assert!(
                    ok,
                    "seed {seed}: lane {lane} slot {slot} sector {sec_idx} recovered to bytes \
                     that are neither the last acknowledged generation ({last_acked:?}) nor a \
                     newer issued one — acknowledged data was lost or a torn record leaked"
                );
            }
        }
    }

    // the recovered data must also drain through the normal flush path
    // and settle identically on the HDD
    let mut before = vec![0u8; SLOT_SECTORS as usize * sector];
    recovered.read(lane_file(0), 0, &mut before).unwrap();
    recovered.drain();
    recovered.read(lane_file(0), 0, &mut buf).unwrap();
    assert_eq!(buf, before, "seed {seed}: the drain must not change recovered contents");
    recovered.shutdown();
}

#[test]
fn mem_snapshot_crashes_at_eight_seeded_points_recover_acknowledged_writes() {
    for seed in 0..8 {
        crash_and_recover_mem(seed);
    }
}

/// Deterministic freeze point: at the `trigger`-th SSD `write_at`, the
/// writing thread parks until the test releases it. Since the async
/// refactor the writing thread is a submission-queue I/O worker (the
/// client thread stays parked on its completion token, so the write can
/// never acknowledge while the worker is held) — except for the inline
/// superblock write, which still parks the submitting thread itself.
struct PausePoint {
    trigger: u64,
    hits: AtomicU64,
    /// 0 = armed, 1 = reached (writer parked), 2 = released
    state: Mutex<u8>,
    cv: Condvar,
}

impl PausePoint {
    fn new(trigger: u64) -> Arc<Self> {
        Arc::new(Self { trigger, hits: AtomicU64::new(0), state: Mutex::new(0), cv: Condvar::new() })
    }

    fn maybe_pause(&self) {
        if self.hits.fetch_add(1, Ordering::SeqCst) + 1 != self.trigger {
            return;
        }
        let mut st = self.state.lock().unwrap();
        *st = 1;
        self.cv.notify_all();
        while *st != 2 {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn wait_paused(&self) {
        let mut st = self.state.lock().unwrap();
        while *st == 0 {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn release(&self) {
        *self.state.lock().unwrap() = 2;
        self.cv.notify_all();
    }
}

/// [`MemBackend`] wrapper that parks the writing thread at the pause
/// point. `pause_before == false` parks *after* the device write
/// completed, before its completion/barrier are processed;
/// `pause_before == true` parks *before* any bytes move — the request
/// was submitted to the queue but the device never saw it.
struct PauseBackend {
    inner: MemBackend,
    point: Arc<PausePoint>,
    pause_before: bool,
}

impl ssdup::live::Backend for PauseBackend {
    fn write_at(&self, offset: u64, data: &[u8]) -> std::io::Result<()> {
        if self.pause_before {
            self.point.maybe_pause();
            return self.inner.write_at(offset, data);
        }
        self.inner.write_at(offset, data)?;
        self.point.maybe_pause();
        Ok(())
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
        self.inner.read_at(offset, buf)
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn sync(&self) -> std::io::Result<()> {
        self.inner.sync()
    }

    fn kind(&self) -> &'static str {
        "pause"
    }
}

/// One seeded freeze inside the submission/completion pipeline. With
/// `pause_before == false` the I/O worker stalls *between a record's
/// device write and its completion/covering barrier* (the bytes landed
/// but sit unsynced in the device cache); with `pause_before == true` it
/// stalls *before the device write* (the request was enqueued but
/// nothing reached the device — a submitted-but-unprocessed queue
/// entry). Either way the paused write must not have been acknowledged
/// (the client is still parked on its completion token), its record is
/// allowed to vanish, and every write acknowledged before the freeze
/// must come back byte-exact. With a single closed-loop writer the
/// outcome is fully deterministic — nothing can have merged the paused
/// record durable — so the check is exact equality with the last
/// acknowledged generation per slot, not just membership in a candidate
/// set.
fn freeze_in_queue(seed: u64, pause_before: bool) {
    const SLOTS: usize = 8;
    const MAX: usize = 120;
    // hit 1 is the first-touch superblock write; record k's header and
    // payload are hits 2k and 2k+1 (the queue coalesces them into one
    // vectored transfer whose default-impl loop still counts each
    // buffer), so the stride parks the worker at varying depths — at a
    // header write or at a payload write, before or after the device
    // write per `pause_before`.
    // Note what this rig does NOT vary: under the volatile-overlay model
    // neither parity leaves partial record bytes in the frozen image
    // (nothing synced them), so the record is absent whole either way —
    // torn-frame handling is the mem-snapshot suite's job above; this
    // test pins the ack boundary itself.
    let trigger = 2 + seed * 3;
    let mut cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(1);
    cfg.ssd_capacity_sectors = 1 << 16; // the burst stays buffered
    cfg.flush_check = Duration::from_millis(1);
    let ssd_store = MemStore::new(true);
    let hdd_store = MemStore::new(true);
    let point = PausePoint::new(trigger);
    let engine = {
        let ssd = Arc::clone(&ssd_store);
        let hdd = Arc::clone(&hdd_store);
        let point = Arc::clone(&point);
        LiveEngine::with_backends(&cfg, move |_| {
            (
                Box::new(PauseBackend {
                    inner: MemBackend::over(Arc::clone(&ssd), SyntheticLatency::ZERO),
                    point: Arc::clone(&point),
                    pause_before,
                }) as Box<dyn ssdup::live::Backend>,
                Box::new(MemBackend::over(Arc::clone(&hdd), SyntheticLatency::ZERO))
                    as Box<dyn ssdup::live::Backend>,
            )
        })
    };
    let log = Mutex::new(LaneLog::default());
    let stop = AtomicBool::new(false);
    let sector = SECTOR_BYTES as usize;
    let (snap_issued, snap_acked, frozen_ssd, frozen_hdd) = std::thread::scope(|s| {
        let engine = &engine;
        let stop = &stop;
        let log = &log;
        s.spawn(move || {
            let mut rng = Prng::new(seed);
            let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
            for i in 0..MAX {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let slot = rng.gen_range(SLOTS as u64) as usize;
                let gen = payload::write_gen(0, i as u32);
                let off = slot as i32 * SLOT_SECTORS;
                payload::fill_gen(1, off as i64, gen, &mut buf);
                log.lock().unwrap().issued.push((slot, gen));
                engine
                    .submit(
                        Request { app: 0, proc_id: 0, file: 1, offset: off, size: SLOT_SECTORS },
                        &buf,
                    )
                    .unwrap();
                log.lock().unwrap().acked += 1;
            }
        });
        point.wait_paused();
        // ---- the crash: the writer sits between its device write and
        // its barrier. Snapshot the ack log first, then the power-loss
        // image; only then release the writer ----
        let (issued, acked) = {
            let l = log.lock().unwrap();
            (l.issued.clone(), l.acked)
        };
        let frozen_ssd = ssd_store.freeze();
        let frozen_hdd = hdd_store.freeze();
        stop.store(true, Ordering::Relaxed);
        point.release();
        (issued, acked, frozen_ssd, frozen_hdd)
    });
    drop(engine);

    // the frozen write was issued but not acknowledged — the contract
    // places it firmly in "submitted", where it may vanish
    assert_eq!(
        snap_issued.len(),
        snap_acked + 1,
        "trigger {trigger}: exactly one write must be in flight at the freeze"
    );

    let (recovered, report) = LiveEngine::open(&cfg, move |_| {
        (
            Box::new(MemBackend::over(Arc::clone(&frozen_ssd), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
            Box::new(MemBackend::over(Arc::clone(&frozen_hdd), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
        )
    })
    .expect("recovery must succeed");
    assert!(!report.clean(), "trigger {trigger}: a freeze is never a clean shutdown");
    assert_eq!(
        report.records_replayed(),
        snap_acked as u64,
        "trigger {trigger}: exactly the acknowledged records replay — the unsynced \
         in-flight record must not resurface, and no acked one may be lost"
    );

    let mut buf = vec![0u8; SLOT_SECTORS as usize * sector];
    for slot in 0..SLOTS {
        let floor: Option<u64> = snap_issued[..snap_acked]
            .iter()
            .filter(|(s, _)| *s == slot)
            .map(|&(_, g)| g)
            .last();
        let off = slot as i32 * SLOT_SECTORS;
        recovered.read(1, off, &mut buf).unwrap();
        match floor {
            None => assert!(
                buf.iter().all(|&b| b == 0),
                "trigger {trigger}: slot {slot} was never acknowledged and must read as zeros \
                 — an unacknowledged (unsynced) record leaked through recovery"
            ),
            Some(gen) => {
                let mut expect = vec![0u8; buf.len()];
                payload::fill_gen(1, off as i64, gen, &mut expect);
                assert_eq!(
                    buf, expect,
                    "trigger {trigger}: slot {slot} must recover byte-exactly to its last \
                     acknowledged generation {gen}"
                );
            }
        }
    }
    recovered.shutdown();
}

#[test]
fn freeze_between_device_write_and_barrier_keeps_exactly_the_acked_prefix() {
    for seed in 0..6 {
        freeze_in_queue(seed, false);
    }
}

#[test]
fn freeze_of_a_submitted_but_unprocessed_queue_request_keeps_exactly_the_acked_prefix() {
    // the request sits in the submission queue with nothing on the
    // device: the write vanishes whole, and the acked prefix survives
    for seed in 0..6 {
        freeze_in_queue(seed, true);
    }
}

#[test]
fn file_backend_killed_mid_burst_recovers_and_verifies() {
    let dir = std::env::temp_dir().join(format!("ssdup-crash-{}", std::process::id()));
    // sparse random burst, small SSD: several flush cycles happen before
    // the kill, so recovery sees settled regions (watermark skips),
    // still-buffered records (replay), and a dirty superblock
    let sectors = 16_384; // 8 MiB
    let w = ior_spanned(0, IorPattern::SegmentedRandom, 4, sectors, sectors * 16, 128, 21);
    let mut cfg = LiveConfig::new(SystemKind::OrangeFsBB).with_shards(2).with_ssd_mib(1);
    cfg.flush_check = Duration::from_millis(1);
    {
        let engine = LiveEngine::file(&cfg, &dir).expect("create file backends");
        // submit everything but never drain: at the "kill" below, some
        // regions have flushed (their watermarks persisted), the rest of
        // the burst is still buffered in the log
        let mut buf: Vec<u8> = Vec::new();
        for proc in &w.processes {
            for req in &proc.reqs {
                buf.resize(req.bytes() as usize, 0);
                payload::fill(req.file, req.offset as i64, &mut buf);
                engine.submit(*req, &buf).unwrap();
            }
        }
        // CRASH: drop without drain or shutdown — the flushers die
        // wherever they are, the superblock stays dirty
    }
    let (engine, report) = LiveEngine::open_file(&cfg, &dir).expect("reopen images");
    assert!(!report.clean(), "an abandoned engine must reopen dirty");
    // every write was acknowledged, so every byte must be served — from
    // the replayed log or the HDD — before any new drain
    let sector = SECTOR_BYTES as usize;
    let mut got = vec![0u8; 128 * sector];
    let mut expect = vec![0u8; 128 * sector];
    for proc in &w.processes {
        for req in &proc.reqs {
            payload::fill(req.file, req.offset as i64, &mut expect);
            engine.read(req.file, req.offset, &mut got).unwrap();
            assert_eq!(
                got, expect,
                "acknowledged write at offset {} lost or corrupted by the crash",
                req.offset
            );
        }
    }
    // and after draining, the standard whole-workload verifier agrees
    engine.drain();
    let verify = engine.verify_workload(&w);
    assert!(verify.is_ok(), "post-recovery drain verification failed: {verify:?}");
    engine.shutdown();

    // a clean shutdown happened above: the next reopen short-circuits
    let (engine, report) = LiveEngine::open_file(&cfg, &dir).expect("clean reopen");
    assert!(report.clean(), "orderly shutdown must leave clean superblocks");
    assert_eq!(report.sectors_scanned(), 0, "clean reopen must not scan any log");
    assert_eq!(report.records_replayed(), 0);
    // the data is still there, through the recovered file table
    let req = w.processes[0].reqs[0];
    payload::fill(req.file, req.offset as i64, &mut expect);
    engine.read(req.file, req.offset, &mut got).unwrap();
    assert_eq!(got, expect, "clean reopen must still serve the settled data");
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recovery_rejects_a_foreign_shard_log() {
    // shard ids are stamped into records and superblocks: reopening a
    // log with the wrong topology must not silently replay garbage.
    // Here shard 1's image is fed to a 1-shard engine (which expects
    // shard id 0 everywhere): nothing validates, nothing is replayed.
    let store = MemStore::new(false);
    let hdd = MemStore::new(false);
    let cfg_two = crash_cfg(4096);
    {
        let stores = vec![
            (MemStore::new(false), MemStore::new(false)),
            (Arc::clone(&store), Arc::clone(&hdd)),
        ];
        let engine = LiveEngine::with_backends(&cfg_two, move |i| {
            (
                Box::new(MemBackend::over(Arc::clone(&stores[i].0), SyntheticLatency::ZERO))
                    as Box<dyn ssdup::live::Backend>,
                Box::new(MemBackend::over(Arc::clone(&stores[i].1), SyntheticLatency::ZERO))
                    as Box<dyn ssdup::live::Backend>,
            )
        });
        let mut buf = vec![0u8; 8 * SECTOR_BYTES as usize];
        payload::fill(1, 0, &mut buf);
        engine
            .submit(Request { app: 0, proc_id: 0, file: 1, offset: 0, size: 8 }, &buf)
            .unwrap();
        // crash without shutdown
    }
    let mut cfg_one = crash_cfg(4096);
    cfg_one.shards = 1;
    let (engine, report) = LiveEngine::open(&cfg_one, |_| {
        (
            Box::new(MemBackend::over(Arc::clone(&store), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
            Box::new(MemBackend::over(Arc::clone(&hdd), SyntheticLatency::ZERO))
                as Box<dyn ssdup::live::Backend>,
        )
    })
    .expect("open succeeds");
    assert_eq!(report.records_replayed(), 0, "foreign-shard records must not replay");
    engine.shutdown();
}
