//! Cross-layer integration: the AOT-compiled JAX/Pallas detector executed
//! via PJRT must agree with the native Rust mirror — bit-for-bit on S,
//! tight tolerance on percentage/seek-cost (XLA may re-associate the f32
//! reductions).
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use ssdup::detector::native::NativeDetector;
use ssdup::device::SeekModel;
use ssdup::runtime::{ArtifactSet, Runtime};
use ssdup::util::prng::Prng;

fn runtime_or_skip() -> Option<Runtime> {
    match ArtifactSet::load_default() {
        Ok(a) => Some(Runtime::load(a).expect("PJRT client")),
        Err(e) => {
            eprintln!("SKIP: artifacts unavailable ({e}); run `make artifacts`");
            None
        }
    }
}

fn patterned_streams() -> Vec<(String, Vec<(i32, i32)>)> {
    let mut rng = Prng::new(0xA0_70);
    let req = 512;
    let mut out = Vec::new();
    // contiguous, shuffled arrival
    let mut contig: Vec<(i32, i32)> = (0..128).map(|i| (i * req, req)).collect();
    rng.shuffle(&mut contig);
    out.push(("contiguous".to_string(), contig));
    // fully random sparse
    out.push((
        "random".to_string(),
        (0..128).map(|_| (rng.gen_range(1 << 24) as i32 * 8, req)).collect(),
    ));
    // strided with holes
    out.push((
        "strided".to_string(),
        (0..128).map(|i| ((i * 16 + (i % 3) as i32) * req, req)).collect(),
    ));
    // short stream + odd sizes
    out.push((
        "short-mixed".to_string(),
        (0..17).map(|_| (rng.gen_range(1 << 20) as i32, 1 + rng.gen_range(2048) as i32)).collect(),
    ));
    // adversarial: duplicate offsets
    out.push(("duplicates".to_string(), vec![(1000, 8); 64]));
    out
}

#[test]
fn hlo_detector_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let det = rt.detector().expect("compile detector");
    let mut native = NativeDetector::new(SeekModel::default());

    let cases = patterned_streams();
    let streams: Vec<Vec<(i32, i32)>> = cases.iter().map(|(_, s)| s.clone()).collect();
    let hlo = det.run_all(&streams).expect("execute");
    for ((name, stream), h) in cases.iter().zip(&hlo) {
        let n = native.detect(stream);
        assert_eq!(h.s, n.s, "{name}: S mismatch (hlo {} vs native {})", h.s, n.s);
        assert!(
            (h.percentage - n.percentage).abs() < 1e-6,
            "{name}: percentage {} vs {}",
            h.percentage,
            n.percentage
        );
        let denom = n.seek_cost_us.abs().max(1.0);
        assert!(
            (h.seek_cost_us - n.seek_cost_us).abs() / denom < 1e-3,
            "{name}: seek cost {} vs {}",
            h.seek_cost_us,
            n.seek_cost_us
        );
    }
}

#[test]
fn hlo_detector_fuzz_vs_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let det = rt.detector().expect("compile detector");
    let mut native = NativeDetector::new(SeekModel::default());
    let mut rng = Prng::new(77);
    for round in 0..8 {
        let streams: Vec<Vec<(i32, i32)>> = (0..16)
            .map(|_| {
                let n = rng.range(2, 512);
                (0..n)
                    .map(|_| (rng.gen_range(1 << 26) as i32, 1 + rng.gen_range(4096) as i32))
                    .collect()
            })
            .collect();
        let hlo = det.run_all(&streams).expect("execute");
        for (s, h) in streams.iter().zip(&hlo) {
            let n = native.detect(s);
            assert_eq!(h.s, n.s, "round {round}: S mismatch on len {}", s.len());
            assert!((h.percentage - n.percentage).abs() < 1e-6);
        }
    }
}

#[test]
fn hlo_threshold_matches_native_percentlist() {
    let Some(rt) = runtime_or_skip() else { return };
    let thr = rt.threshold().expect("compile threshold");
    use ssdup::redirector::PercentList;
    let mut rng = Prng::new(5);
    for _ in 0..10 {
        let n = rng.range(1, 64);
        let mut list = PercentList::new(64);
        for _ in 0..n {
            list.insert(rng.f64() as f32);
        }
        let (t_hlo, avg_hlo) = thr.run(list.values()).expect("execute");
        let t_native = list.threshold().unwrap();
        let avg_native = list.avgper();
        assert!(
            (t_hlo - t_native).abs() < 1e-6,
            "threshold {t_hlo} vs {t_native} (n={n})"
        );
        assert!((avg_hlo - avg_native).abs() < 1e-5, "avg {avg_hlo} vs {avg_native}");
    }
}

#[test]
fn oversize_inputs_are_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let det = rt.detector().expect("compile detector");
    let too_long: Vec<(i32, i32)> = (0..1000).map(|i| (i, 1)).collect();
    assert!(det.run_batch(&[&too_long]).is_err(), "stream > nmax must error");
    let thr = rt.threshold().expect("compile threshold");
    assert!(thr.run(&vec![0.5; 100]).is_err(), "list > cap must error");
    assert!(thr.run(&[]).is_err(), "empty list must error");
}
