//! Experiment-harness integration: every registered table/figure runs at
//! quick scale and reproduces the paper's *qualitative* claims (orderings
//! and trends, not testbed-absolute numbers).

use ssdup::experiments::{all_ids, run, Scale};
use ssdup::util::json::Json;

fn quick() -> Scale {
    Scale { factor: 32, seed: 0x55D0 }
}

#[test]
fn every_registered_experiment_runs_and_renders() {
    for id in all_ids() {
        let rep = run(id, quick()).unwrap_or_else(|| panic!("{id} not registered"));
        assert_eq!(rep.id, id);
        assert!(!rep.rows.is_empty(), "{id} produced no rows");
        let rendered = rep.render();
        assert!(rendered.contains(id));
        // machine-readable data round-trips through our JSON substrate
        let s = rep.data.to_string();
        assert_eq!(Json::parse(&s).unwrap(), rep.data, "{id} data round-trip");
    }
}

#[test]
fn unknown_experiment_is_none() {
    assert!(run("fig99", quick()).is_none());
}

#[test]
fn fig5_ordering_random_gt_mixed_gt_contiguous() {
    let rep = run("fig5", quick()).unwrap();
    let get = |pattern: &str| -> f64 {
        rep.data
            .as_arr()
            .unwrap()
            .iter()
            .find(|r| r.get("pattern").unwrap().as_str() == Some(pattern))
            .unwrap()
            .get("random_pct")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let contig = get("seg-contiguous");
    let random = get("seg-random");
    let mixed = get("mixed");
    assert!(random > 0.95, "seg-random must be ~100% random: {random}");
    assert!(random >= mixed && mixed > contig, "ordering violated: r={random} m={mixed} c={contig}");
    assert!(contig < 0.3, "contiguous must be mostly sequential: {contig}");
}

#[test]
fn fig6_inverse_correlation() {
    let rep = run("fig6", quick()).unwrap();
    let rows = rep.data.as_arr().unwrap();
    let first_pct = rows.first().unwrap().get("random_pct").unwrap().as_f64().unwrap();
    let last_pct = rows.last().unwrap().get("random_pct").unwrap().as_f64().unwrap();
    let first_t = rows.first().unwrap().get("mbps").unwrap().as_f64().unwrap();
    let last_t = rows.last().unwrap().get("mbps").unwrap().as_f64().unwrap();
    assert!(last_pct > first_pct, "randomness grows with procs: {first_pct} -> {last_pct}");
    assert!(last_t < first_t, "throughput falls with procs: {first_t} -> {last_t}");
}

#[test]
fn fig11_ssdup_plus_saves_ssd_vs_bb() {
    let rep = run("fig11", quick()).unwrap();
    for row in rep.data.as_arr().unwrap() {
        let plus_ratio = row.get("ssdup_plus_ssd_ratio").unwrap().as_f64().unwrap();
        let bb_ratio = row.get("bb_ssd_ratio").unwrap().as_f64().unwrap();
        assert!(plus_ratio <= bb_ratio + 1e-9, "SSDUP+ must never buffer more than BB");
        let native = row.get("orangefs").unwrap().as_f64().unwrap();
        let plus = row.get("ssdup+").unwrap().as_f64().unwrap();
        assert!(plus >= native * 0.9, "SSDUP+ {plus} must not lose to native {native}");
    }
}

#[test]
fn table1_overhead_below_one_percent() {
    let rep = run("table1", quick()).unwrap();
    for row in rep.data.as_arr().unwrap() {
        let overhead = row.get("overhead_pct").unwrap().as_f64().unwrap();
        assert!(overhead < 1.0, "paper claims <1% overhead; measured {overhead}%");
    }
}
