//! Two-region pipeline + AVL buffer integration: conservation, ordering,
//! and flush-strategy behaviour under sustained pressure.

use ssdup::buffer::{BufferOutcome, FlushStrategy, Pipeline};
use ssdup::util::prng::Prng;

#[test]
fn sustained_pressure_round_trips_every_byte_in_order() {
    let mut p = Pipeline::new(4096);
    let mut rng = Prng::new(9);
    let mut written: Vec<(u32, i64, i64)> = Vec::new(); // (file, off, size)
    let mut flushed: Vec<(u32, i64, i64)> = Vec::new();
    let mut offset_cursor: Vec<i64> = vec![0; 4];

    for _ in 0..2000 {
        let file = rng.gen_range(4) as u32;
        let size = 1 + rng.gen_range(64) as i64;
        let off = offset_cursor[file as usize];
        offset_cursor[file as usize] += size + rng.gen_range(32) as i64; // holes
        match p.buffer(file, off, size) {
            BufferOutcome::Buffered { .. } | BufferOutcome::BufferedAndFull { .. } => {
                written.push((file, off, size));
            }
            BufferOutcome::Blocked => {
                // flush synchronously and retry once
                if p.next_flush().is_some() {
                    for e in p.drain_flushing() {
                        flushed.push((e.file, e.orig_offset, e.size));
                    }
                    p.flush_done();
                }
                if let BufferOutcome::Buffered { .. } | BufferOutcome::BufferedAndFull { .. } =
                    p.buffer(file, off, size)
                {
                    written.push((file, off, size));
                }
            }
        }
    }
    // final drain (both regions)
    loop {
        p.enqueue_residual_flush();
        match p.next_flush() {
            Some(_) => {
                for e in p.drain_flushing() {
                    flushed.push((e.file, e.orig_offset, e.size));
                }
                p.flush_done();
            }
            None => break,
        }
    }
    assert!(!p.dirty());
    // conservation: every buffered sector flushed exactly once
    let wsum: i64 = written.iter().map(|w| w.2).sum();
    let fsum: i64 = flushed.iter().map(|f| f.2).sum();
    assert_eq!(wsum, fsum, "bytes in == bytes flushed");
    // ordering: within each flush batch, extents per file are ascending;
    // reconstruct per-file coverage equality
    let norm = |v: &[(u32, i64, i64)]| {
        let mut sectors: Vec<(u32, i64)> = Vec::new();
        for &(f, o, s) in v {
            for k in 0..s {
                sectors.push((f, o + k));
            }
        }
        sectors.sort_unstable();
        sectors
    };
    assert_eq!(norm(&written), norm(&flushed), "identical sector coverage");
}

#[test]
fn flush_extent_counts_shrink_when_writes_arrive_in_order() {
    // in-order appends merge into one extent; random appends do not —
    // quantifies the log-structure + AVL payoff
    let mut in_order = Pipeline::new(1 << 20);
    let mut shuffled = Pipeline::new(1 << 20);
    let mut offs: Vec<i64> = (0..1024).map(|i| i * 512).collect();
    for &o in &offs {
        in_order.buffer(1, o, 512);
    }
    let mut rng = Prng::new(3);
    rng.shuffle(&mut offs);
    for &o in &offs {
        shuffled.buffer(1, o, 512);
    }
    in_order.enqueue_residual_flush();
    shuffled.enqueue_residual_flush();
    in_order.next_flush().unwrap();
    shuffled.next_flush().unwrap();
    let a = in_order.drain_flushing();
    let b = shuffled.drain_flushing();
    assert_eq!(a.len(), 1, "in-order appends collapse to one extent");
    assert!(b.len() > 100, "shuffled appends stay fragmented ({})", b.len());
    // but BOTH are offset-sorted for the sequential HDD pass
    assert!(b.windows(2).all(|w| w[0].orig_offset < w[1].orig_offset));
}

#[test]
fn traffic_aware_strategy_eventually_flushes_under_permanent_load() {
    // even if random percentage stays low, `drained` forces progress —
    // no livelock at end of run
    let s = FlushStrategy::TrafficAware { pause_below: 0.45 };
    assert!(!s.allow_flush(0.1, true, false));
    assert!(s.allow_flush(0.1, true, true), "drained mode must always flush");
}

#[test]
fn pipeline_alternates_regions() {
    let mut p = Pipeline::new(2000);
    let mut flush_regions = Vec::new();
    for i in 0..10 {
        match p.buffer(1, i * 1000, 1000) {
            BufferOutcome::Blocked => {
                if p.next_flush().is_some() {
                    flush_regions.push(p.flushing_region().unwrap());
                    p.drain_flushing();
                    p.flush_done();
                }
                p.buffer(1, i * 1000, 1000);
            }
            _ => {}
        }
    }
    // regions must alternate 0,1,0,1...
    for w in flush_regions.windows(2) {
        assert_ne!(w[0], w[1], "pipeline must alternate regions: {flush_regions:?}");
    }
}
