//! Core domain types shared across layers.
//!
//! All offsets and sizes are expressed in **512-byte sectors** (i32), the
//! unit the AOT-compiled detector kernels use (python/compile/constants.py
//! explains the int32 rationale). Simulated time is in microseconds.

/// Simulated microseconds.
pub type Usec = u64;

/// Bytes per sector.
pub const SECTOR_BYTES: u64 = 512;

/// Sectors per 256 KB — the paper's default request size.
pub const DEFAULT_REQ_SECTORS: i32 = 512;

/// The paper's default request-stream length (CFQ queue depth).
pub const DEFAULT_STREAM_LEN: usize = 128;

/// Convert sectors to bytes.
#[inline]
pub fn sectors_to_bytes(sectors: i64) -> u64 {
    sectors as u64 * SECTOR_BYTES
}

/// Convert a byte count to sectors (rounding up).
#[inline]
pub fn bytes_to_sectors(bytes: u64) -> i64 {
    bytes.div_ceil(SECTOR_BYTES) as i64
}

/// Convert MiB to sectors.
#[inline]
pub fn mib_to_sectors(mib: u64) -> i64 {
    (mib * 1024 * 1024 / SECTOR_BYTES) as i64
}

/// A single write request as seen by an I/O node (post-striping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Request {
    /// issuing application (for mixed-load accounting)
    pub app: u16,
    /// issuing process within the whole cluster
    pub proc_id: u32,
    /// target file handle
    pub file: u32,
    /// file-relative offset in sectors
    pub offset: i32,
    /// length in sectors
    pub size: i32,
}

impl Request {
    pub fn bytes(&self) -> u64 {
        sectors_to_bytes(self.size as i64)
    }

    /// End offset (exclusive), in sectors.
    pub fn end(&self) -> i32 {
        self.offset + self.size
    }
}

/// Where the redirector decided a stream's requests should go.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Hdd,
    Ssd,
}

impl std::fmt::Display for Route {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Route::Hdd => write!(f, "HDD"),
            Route::Ssd => write!(f, "SSD"),
        }
    }
}

/// Result of detecting one request stream (paper §2.2/§2.3.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Detection {
    /// S = sum of random factors (Eq. 1)
    pub s: i32,
    /// S / (N - 1)
    pub percentage: f32,
    /// estimated HDD seek microseconds to serve the sorted stream
    pub seek_cost_us: f32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions() {
        assert_eq!(sectors_to_bytes(512), 256 * 1024);
        assert_eq!(bytes_to_sectors(256 * 1024), 512);
        assert_eq!(bytes_to_sectors(1), 1);
        assert_eq!(bytes_to_sectors(513), 2);
        assert_eq!(mib_to_sectors(1), 2048);
    }

    #[test]
    fn request_accessors() {
        let r = Request { app: 0, proc_id: 3, file: 1, offset: 100, size: 512 };
        assert_eq!(r.bytes(), 256 * 1024);
        assert_eq!(r.end(), 612);
    }
}
