//! The end-to-end cluster simulation: closed-loop application processes →
//! striped sub-requests → per-I/O-node servers (detector + redirector +
//! pipelined SSD buffer + devices), driven by the deterministic DES.
//!
//! One function, [`simulate`], runs any of the paper's four systems over
//! any workload and returns the `SimResult` every experiment is built on.

use std::collections::VecDeque;
use std::time::Instant;

use crate::buffer::{BufferOutcome, FlushExtent, FlushStrategy, Pipeline, Region};
use crate::detector::hlo::DetectBackend;
use crate::detector::native::NativeDetector;
use crate::detector::stream::StreamGrouper;
use crate::device::{Hdd, Ssd};
use crate::fs::{FileTable, StripeLayout, SubRequest};
use crate::redirector::{AdaptivePolicy, AlwaysHdd, AlwaysSsd, RoutePolicy, WatermarkPolicy};
use crate::server::config::{SimConfig, SystemKind};
use crate::server::metrics::{AppStats, NodeStats, SimResult};
use crate::sim::Engine;
use crate::types::{Route, Usec};
use crate::util::prng::Prng;
use crate::workload::Workload;

#[derive(Clone, Copy, Debug)]
enum HddTag {
    Direct { req_id: u32 },
    Flush,
}

#[derive(Clone, Copy, Debug)]
enum SsdTag {
    Append { req_id: u32 },
    FlushRead,
}

#[derive(Clone, Debug)]
enum Ev {
    /// a process becomes eligible to issue requests
    Start { proc: usize },
    /// a sub-request reaches its I/O node
    Arrive { sub: SubRequest, req_id: u32 },
    HddDone { node: usize },
    SsdDone { node: usize },
    /// re-evaluate a paused (traffic-aware) flush
    FlushCheck { node: usize },
    /// a flush freed a region: retry blocked SSD writes
    RetryBlocked { node: usize },
    /// CFQ anticipation deadline: re-poll the HDD dispatcher
    HddPoke { node: usize },
    /// a sub-request reaches the node's NIC (serialized in ready order)
    NicIn { sub: SubRequest, req_id: u32 },
}

/// Per-node SSD buffer organization.
enum SsdBuffer {
    /// native OrangeFS — no SSD
    None,
    /// OrangeFS-BB: whole SSD as one region; while it flushes, new writes
    /// fall back to HDD (§4.2.3)
    Single { region: Region, flushing: bool },
    /// SSDUP / SSDUP+: two-region pipeline
    Pipelined(Pipeline),
}

struct Node {
    hdd: Hdd<HddTag>,
    ssd: Ssd<SsdTag>,
    files: FileTable,
    grouper: StreamGrouper,
    backend: Box<dyn DetectBackend>,
    policy: Box<dyn RoutePolicy>,
    route: Route,
    buffer: SsdBuffer,
    strategy: FlushStrategy,
    flush_extents: VecDeque<FlushExtent>,
    flush_outstanding: usize,
    flush_pause_since: Option<Usec>,
    flush_check_scheduled: bool,
    blocked: VecDeque<(SubRequest, u32)>,
    direct_inflight: u64,
    drained_mode: bool,
    hdd_poke_at: Option<Usec>,
    stats: NodeStats,
    pct_sum: f64,
}

impl Node {
    fn new(cfg: &SimConfig) -> Self {
        let policy: Box<dyn RoutePolicy> = match cfg.system {
            SystemKind::OrangeFs => Box::new(AlwaysHdd),
            SystemKind::OrangeFsBB => Box::new(AlwaysSsd),
            SystemKind::Ssdup => match cfg.static_threshold {
                // degenerate band: one fixed threshold (ablation sweep)
                Some(t) => Box::new(WatermarkPolicy::new(
                    crate::redirector::Watermark::new(t, t),
                )),
                None => Box::<WatermarkPolicy>::default(),
            },
            SystemKind::SsdupPlus => Box::new(AdaptivePolicy::new(cfg.history)),
        };
        let buffer = match cfg.system {
            SystemKind::OrangeFs => SsdBuffer::None,
            SystemKind::OrangeFsBB => {
                SsdBuffer::Single { region: Region::new(cfg.ssd_capacity_sectors), flushing: false }
            }
            SystemKind::Ssdup | SystemKind::SsdupPlus => {
                SsdBuffer::Pipelined(Pipeline::new(cfg.ssd_capacity_sectors))
            }
        };
        let strategy = match cfg.system {
            SystemKind::SsdupPlus => FlushStrategy::TrafficAware { pause_below: cfg.pause_below },
            _ => FlushStrategy::Immediate,
        };
        let route = policy.initial_route();
        Node {
            hdd: Hdd::new(cfg.hdd),
            ssd: Ssd::new(cfg.ssd),
            files: FileTable::new(),
            grouper: StreamGrouper::new(cfg.stream_len),
            backend: Box::new(NativeDetector::new(cfg.hdd.seek)),
            policy,
            route,
            buffer,
            strategy,
            flush_extents: VecDeque::new(),
            flush_outstanding: 0,
            flush_pause_since: None,
            flush_check_scheduled: false,
            blocked: VecDeque::new(),
            direct_inflight: 0,
            drained_mode: false,
            hdd_poke_at: None,
            stats: NodeStats::default(),
            pct_sum: 0.0,
        }
    }

    fn ssd_occupancy(&self) -> i64 {
        match &self.buffer {
            SsdBuffer::None => 0,
            SsdBuffer::Single { region, .. } => region.used(),
            SsdBuffer::Pipelined(p) => p.used_sectors(),
        }
    }

    fn metadata_bytes(&self) -> usize {
        match &self.buffer {
            SsdBuffer::None => 0,
            SsdBuffer::Single { region, .. } => region.metadata_bytes(),
            SsdBuffer::Pipelined(p) => p.metadata_bytes(),
        }
    }

    /// Run detection on a completed stream and update the route.
    fn on_stream_complete(&mut self, reqs: &[(i32, i32)]) {
        let t0 = Instant::now();
        let det = self.backend.detect(reqs);
        self.stats.group_cost_us += t0.elapsed().as_secs_f64() * 1e6;
        self.stats.streams += 1;
        self.pct_sum += det.percentage as f64;
        self.route = self.policy.on_stream(&det);
    }

    fn current_percentage(&self) -> f32 {
        self.policy.current_percentage().unwrap_or(1.0)
    }
}

/// Simulate `workload` under `cfg` with the default (native) detector
/// backend on every node.
pub fn simulate(cfg: &SimConfig, workload: &Workload) -> SimResult {
    let backends: Vec<Box<dyn DetectBackend>> = (0..cfg.nodes)
        .map(|_| Box::new(NativeDetector::new(cfg.hdd.seek)) as Box<dyn DetectBackend>)
        .collect();
    simulate_with_backends(cfg, workload, backends)
}

/// Simulate with caller-provided detection backends (e.g. the PJRT-backed
/// HLO detector — the production three-layer path).
pub fn simulate_with_backends(
    cfg: &SimConfig,
    workload: &Workload,
    backends: Vec<Box<dyn DetectBackend>>,
) -> SimResult {
    assert_eq!(backends.len(), cfg.nodes, "one backend per node");
    let stripe = StripeLayout { stripe_sectors: cfg.stripe_sectors, n_nodes: cfg.nodes };
    let mut nodes: Vec<Node> = (0..cfg.nodes).map(|_| Node::new(cfg)).collect();
    for (n, b) in nodes.iter_mut().zip(backends) {
        n.backend = b;
    }

    // --- process / request / app bookkeeping -----------------------------
    struct ProcState {
        next: usize,
        inflight: usize,
        started: bool,
        issued: u64,
    }
    struct ReqState {
        remaining: u16,
        proc: usize,
        bytes: u64,
    }
    #[derive(Clone)]
    struct AppAccount {
        total_reqs: usize,
        done_reqs: usize,
        bytes: u64,
        start_us: Option<Usec>,
        end_us: Usec,
        started: bool,
    }

    let napps = workload.apps().len();
    let app_index = |app: u16, apps: &[u16]| apps.iter().position(|&a| a == app).unwrap();
    let apps_list = workload.apps();
    let mut apps: Vec<AppAccount> = vec![
        AppAccount { total_reqs: 0, done_reqs: 0, bytes: 0, start_us: None, end_us: 0, started: false };
        napps
    ];
    for p in &workload.processes {
        apps[app_index(p.app, &apps_list)].total_reqs += p.reqs.len();
    }

    let mut procs: Vec<ProcState> = workload
        .processes
        .iter()
        .map(|_| ProcState { next: 0, inflight: 0, started: false, issued: 0 })
        .collect();
    let mut reqs: Vec<ReqState> = Vec::with_capacity(workload.total_requests());
    // processes waiting on an app's completion: (proc index, gap)
    let mut waiters: Vec<Vec<(usize, u64)>> = vec![Vec::new(); napps];

    let mut engine: Engine<Ev> = Engine::new();
    let mut rng = Prng::new(cfg.seed);
    // per-node NIC ingest serialization timeline
    let mut nic_free: Vec<Usec> = vec![0; cfg.nodes];

    for (i, p) in workload.processes.iter().enumerate() {
        match p.after_app {
            None => engine.schedule_at(0, Ev::Start { proc: i }),
            Some((dep, gap)) => waiters[app_index(dep, &apps_list)].push((i, gap)),
        }
    }

    let mut makespan: Usec = 0;
    let mut total_bytes: u64 = 0;

    // --- helper closures cannot capture everything mutably; use macros ---
    macro_rules! pump_hdd {
        ($n:expr, $inflight:expr) => {{
            let now = engine.now();
            if let Some(d) = nodes[$n].hdd.try_dispatch(now) {
                nodes[$n].stats.hdd_seeks += d.seeks;
                $inflight[$n].hdd = Some(d.tags);
                engine.schedule_at(d.done_at, Ev::HddDone { node: $n });
            } else if let Some(deadline) = nodes[$n].hdd.idle_deadline() {
                // anticipation hold: make sure something pokes the device
                // at the deadline even if no arrival does earlier
                if nodes[$n].hdd_poke_at.map_or(true, |t| t > deadline || t <= now) {
                    nodes[$n].hdd_poke_at = Some(deadline);
                    engine.schedule_at(deadline, Ev::HddPoke { node: $n });
                }
            }
        }};
    }
    macro_rules! pump_ssd {
        ($n:expr, $inflight:expr) => {{
            let now = engine.now();
            if let Some(d) = nodes[$n].ssd.try_dispatch(now) {
                $inflight[$n].ssd = Some(d.tags);
                engine.schedule_at(d.done_at, Ev::SsdDone { node: $n });
            }
        }};
    }

    /// Pump the flusher state machine for node `n`.
    macro_rules! pump_flush {
        ($n:expr, $inflight:expr) => {{
            let now = engine.now();
            // acquire the next flush job if idle
            if nodes[$n].flush_extents.is_empty() && nodes[$n].flush_outstanding == 0 {
                let mut drained: Option<Vec<FlushExtent>> = None;
                match &mut nodes[$n].buffer {
                    SsdBuffer::Pipelined(p) => {
                        if p.next_flush().is_some() {
                            drained = Some(p.drain_flushing());
                        }
                    }
                    SsdBuffer::Single { region, flushing } => {
                        if *flushing && region.used() > 0 {
                            drained = Some(region.drain_for_flush());
                        }
                    }
                    SsdBuffer::None => {}
                }
                if let Some(ext) = drained {
                    let t0 = Instant::now();
                    nodes[$n].flush_extents = ext.into();
                    nodes[$n].stats.avl_cost_us += t0.elapsed().as_secs_f64() * 1e6;
                    nodes[$n].stats.flushes += 1;
                }
            }
            // issue flush extents, subject to the traffic-aware gate
            while nodes[$n].flush_outstanding < cfg.flush_inflight
                && !nodes[$n].flush_extents.is_empty()
            {
                let pct = nodes[$n].current_percentage();
                let direct_active = nodes[$n].direct_inflight > 0;
                let drained_mode = nodes[$n].drained_mode;
                if !nodes[$n].strategy.allow_flush(pct, direct_active, drained_mode) {
                    if nodes[$n].flush_pause_since.is_none() {
                        nodes[$n].flush_pause_since = Some(now);
                        nodes[$n].stats.flush_pauses += 1;
                    }
                    if !nodes[$n].flush_check_scheduled {
                        nodes[$n].flush_check_scheduled = true;
                        engine.schedule_in(cfg.flush_check_us, Ev::FlushCheck { node: $n });
                    }
                    break;
                }
                if let Some(since) = nodes[$n].flush_pause_since.take() {
                    nodes[$n].stats.flush_pause_us += now - since;
                }
                let ext = nodes[$n].flush_extents.pop_front().unwrap();
                let lba = nodes[$n].files.lba(ext.file, ext.orig_offset as i32);
                nodes[$n].ssd.enqueue_read(ext.size, SsdTag::FlushRead);
                nodes[$n].hdd.enqueue(lba, ext.size, crate::device::hdd::FLUSH_WRITER, HddTag::Flush);
                nodes[$n].flush_outstanding += 1;
                pump_ssd!($n, $inflight);
                pump_hdd!($n, $inflight);
            }
            // flush complete?
            if nodes[$n].flush_extents.is_empty() && nodes[$n].flush_outstanding == 0 {
                let mut finished = false;
                match &mut nodes[$n].buffer {
                    SsdBuffer::Pipelined(p) => {
                        if p.flushing_region().is_some() {
                            p.flush_done();
                            finished = true;
                        }
                    }
                    SsdBuffer::Single { flushing, .. } => {
                        if *flushing {
                            *flushing = false;
                            finished = true;
                        }
                    }
                    SsdBuffer::None => {}
                }
                if finished {
                    if let Some(since) = nodes[$n].flush_pause_since.take() {
                        nodes[$n].stats.flush_pause_us += now - since;
                    }
                    // retry blocked requests via an event (breaks the
                    // pump_flush <-> buffer_sub macro recursion)
                    if !nodes[$n].blocked.is_empty() {
                        engine.schedule_in(0, Ev::RetryBlocked { node: $n });
                    }
                }
            }
        }};
    }

    /// Try to buffer a sub-request into node `n`'s SSD. Returns false if
    /// it could not be buffered. `$queue_on_block` selects arrival
    /// semantics (queue + count the blocked request) vs retry semantics
    /// (leave the queue and stats untouched — the caller already holds
    /// the request at the front of the blocked queue).
    macro_rules! buffer_sub {
        ($n:expr, $sub:expr, $req_id:expr, $queue_on_block:expr, $inflight:expr) => {{
            let sub: SubRequest = $sub;
            let size = sub.size as i64;
            let t0 = Instant::now();
            let outcome = match &mut nodes[$n].buffer {
                SsdBuffer::None => unreachable!("SSD route without SSD"),
                SsdBuffer::Single { region, flushing } => {
                    if *flushing {
                        // BB under flush: fall back to direct HDD write
                        BufferOutcome::Blocked
                    } else if let Some(off) =
                        region.buffer(sub.parent.file, sub.local_offset as i64, size)
                    {
                        BufferOutcome::Buffered { region: 0, ssd_offset: off }
                    } else {
                        // full: start flushing, fall back to HDD
                        *flushing = true;
                        BufferOutcome::Blocked
                    }
                }
                SsdBuffer::Pipelined(p) => p.buffer(sub.parent.file, sub.local_offset as i64, size),
            };
            nodes[$n].stats.avl_cost_us += t0.elapsed().as_secs_f64() * 1e6;
            let ok = match outcome {
                BufferOutcome::Buffered { .. } => {
                    nodes[$n].ssd.enqueue_append(size, SsdTag::Append { req_id: $req_id });
                    nodes[$n].stats.ssd_bytes_buffered += sub.bytes();
                    pump_ssd!($n, $inflight);
                    true
                }
                BufferOutcome::BufferedAndFull { .. } => {
                    nodes[$n].ssd.enqueue_append(size, SsdTag::Append { req_id: $req_id });
                    nodes[$n].stats.ssd_bytes_buffered += sub.bytes();
                    pump_ssd!($n, $inflight);
                    pump_flush!($n, $inflight);
                    true
                }
                BufferOutcome::Blocked => match &nodes[$n].buffer {
                    SsdBuffer::Single { .. } => {
                        // BB fallback: direct HDD write
                        let lba = nodes[$n].files.lba(sub.parent.file, sub.local_offset);
                        let tag = HddTag::Direct { req_id: $req_id };
                        nodes[$n].hdd.enqueue(lba, size, sub.parent.proc_id, tag);
                        nodes[$n].direct_inflight += 1;
                        pump_hdd!($n, $inflight);
                        pump_flush!($n, $inflight);
                        true
                    }
                    _ => {
                        // SSDUP/SSDUP+: wait for a region
                        if $queue_on_block {
                            nodes[$n].blocked.push_back((sub, $req_id));
                            nodes[$n].stats.blocked_requests += 1;
                        }
                        pump_flush!($n, $inflight);
                        false
                    }
                },
            };
            let occ = nodes[$n].ssd_occupancy();
            if occ > nodes[$n].stats.peak_ssd_occupancy_sectors {
                nodes[$n].stats.peak_ssd_occupancy_sectors = occ;
            }
            let md = nodes[$n].metadata_bytes();
            if md > nodes[$n].stats.avl_metadata_peak_bytes {
                nodes[$n].stats.avl_metadata_peak_bytes = md;
            }
            ok
        }};
    }

    /// Issue requests for `proc` until its I/O depth is full.
    macro_rules! issue {
        ($p:expr) => {{
            let wl = &workload.processes[$p];
            while procs[$p].inflight < cfg.io_depth && procs[$p].next < wl.reqs.len() {
                let req = wl.reqs[procs[$p].next];
                procs[$p].next += 1;
                procs[$p].inflight += 1;
                let req_id = reqs.len() as u32;
                let subs = stripe.split(req);
                reqs.push(ReqState { remaining: subs.len() as u16, proc: $p, bytes: req.bytes() });
                let ai = app_index(req.app, &apps_list);
                if apps[ai].start_us.is_none() {
                    apps[ai].start_us = Some(engine.now());
                }
                // HPC apps alternate computation with bursty I/O: every
                // `burst_len` requests a process pauses for a compute
                // phase. This is what gives server streams their
                // *composition variance* (some windows contiguous-heavy,
                // some random-heavy) — the paper's mixed-load premise.
                procs[$p].issued += 1;
                let mut jitter = rng.exp(cfg.jitter_us) as u64;
                if cfg.burst_len > 0 && procs[$p].issued % cfg.burst_len == 0 {
                    jitter += rng.exp(cfg.burst_gap_us) as u64;
                }
                for sub in subs {
                    // ready time at the node's NIC; the NIC serializes in
                    // ready order (NicIn events pop time-ordered)
                    engine.schedule_in(jitter + cfg.net_us, Ev::NicIn { sub, req_id });
                }
            }
        }};
    }

    // per-node in-flight tag buffers
    #[derive(Default)]
    struct Inflight {
        hdd: Option<Vec<HddTag>>,
        ssd: Option<Vec<SsdTag>>,
    }
    let mut inflight: Vec<Inflight> = (0..cfg.nodes).map(|_| Inflight::default()).collect();

    let mut completed_reqs: usize = 0;
    let total_reqs = workload.total_requests();
    let mut all_apps_done = false;

    // ---------------------------- event loop -----------------------------
    while let Some((now, ev)) = engine.pop() {
        match ev {
            Ev::Start { proc } => {
                if !procs[proc].started {
                    procs[proc].started = true;
                    let app = workload.processes[proc].app;
                    let ai = app_index(app, &apps_list);
                    if !apps[ai].started {
                        apps[ai].started = true;
                        // workload change: new job arrived (paper §2.3.2)
                        for n in &mut nodes {
                            n.policy.on_workload_change();
                        }
                    }
                    issue!(proc);
                }
            }
            Ev::Arrive { sub, req_id } => {
                let n = sub.node;
                // route this sub-request by the node's current direction
                let route =
                    if matches!(nodes[n].buffer, SsdBuffer::None) { Route::Hdd } else { nodes[n].route };
                match route {
                    Route::Hdd => {
                        let lba = nodes[n].files.lba(sub.parent.file, sub.local_offset);
                        let tag = HddTag::Direct { req_id };
                        nodes[n].hdd.enqueue(lba, sub.size as i64, sub.parent.proc_id, tag);
                        nodes[n].direct_inflight += 1;
                        pump_hdd!(n, inflight);
                    }
                    Route::Ssd => {
                        buffer_sub!(n, sub, req_id, true, inflight);
                    }
                }
                // feed the detector with the *disk* address the server
                // sees (post-striping, post-layout)
                let lba32 = nodes[n].files.lba(sub.parent.file, sub.local_offset);
                debug_assert!(lba32 <= i32::MAX as i64, "LBA exceeds detector i32 space");
                if let Some(stream) = nodes[n].grouper.push_parts(sub.parent.app, lba32 as i32, sub.size) {
                    nodes[n].on_stream_complete(&stream.reqs);
                    // a route change may allow a paused flush to resume
                    pump_flush!(n, inflight);
                }
            }
            Ev::HddDone { node } => {
                let tags = inflight[node].hdd.take().expect("hdd done without dispatch");
                nodes[node].hdd.complete();
                for tag in tags {
                    match tag {
                        HddTag::Direct { req_id } => {
                            nodes[node].direct_inflight -= 1;
                            let r = &mut reqs[req_id as usize];
                            r.remaining -= 1;
                            if r.remaining == 0 {
                                let p = r.proc;
                                let bytes = r.bytes;
                                procs[p].inflight -= 1;
                                completed_reqs += 1;
                                total_bytes += bytes;
                                makespan = now;
                                let app = workload.processes[p].app;
                                let ai = app_index(app, &apps_list);
                                apps[ai].done_reqs += 1;
                                apps[ai].bytes += bytes;
                                apps[ai].end_us = now;
                                if apps[ai].done_reqs == apps[ai].total_reqs {
                                    for (wp, gap) in waiters[ai].drain(..) {
                                        engine.schedule_in(gap, Ev::Start { proc: wp });
                                    }
                                    for nn in &mut nodes {
                                        nn.policy.on_workload_change();
                                    }
                                }
                                issue!(p);
                            }
                        }
                        HddTag::Flush => {
                            nodes[node].flush_outstanding -= 1;
                        }
                    }
                }
                pump_flush!(node, inflight);
                pump_hdd!(node, inflight);
            }
            Ev::SsdDone { node } => {
                let tags = inflight[node].ssd.take().expect("ssd done without dispatch");
                nodes[node].ssd.complete();
                for tag in tags {
                    if let SsdTag::Append { req_id } = tag {
                        let r = &mut reqs[req_id as usize];
                        r.remaining -= 1;
                        if r.remaining == 0 {
                            let p = r.proc;
                            let bytes = r.bytes;
                            procs[p].inflight -= 1;
                            completed_reqs += 1;
                            total_bytes += bytes;
                            makespan = now;
                            let app = workload.processes[p].app;
                            let ai = app_index(app, &apps_list);
                            apps[ai].done_reqs += 1;
                            apps[ai].bytes += bytes;
                            apps[ai].end_us = now;
                            if apps[ai].done_reqs == apps[ai].total_reqs {
                                for (wp, gap) in waiters[ai].drain(..) {
                                    engine.schedule_in(gap, Ev::Start { proc: wp });
                                }
                                for nn in &mut nodes {
                                    nn.policy.on_workload_change();
                                }
                            }
                            issue!(p);
                        }
                    }
                }
                pump_ssd!(node, inflight);
            }
            Ev::FlushCheck { node } => {
                nodes[node].flush_check_scheduled = false;
                pump_flush!(node, inflight);
            }
            Ev::HddPoke { node } => {
                nodes[node].hdd_poke_at = None;
                pump_hdd!(node, inflight);
            }
            Ev::NicIn { sub, req_id } => {
                // per-node ingest link: serialize the payload transfer
                let start = now.max(nic_free[sub.node]);
                let arrive = start + (sub.bytes() as f64 / cfg.nic_mbps) as u64;
                nic_free[sub.node] = arrive;
                engine.schedule_at(arrive, Ev::Arrive { sub, req_id });
            }
            Ev::RetryBlocked { node } => {
                // Retry the oldest blocked writes in arrival order; stop at
                // the first that still doesn't fit. Retries use
                // queue-on-block = false, so a still-blocked request stays
                // exactly where it is (front of the queue) and is never
                // re-counted — each request contributes to
                // `blocked_requests` once, at its blocking arrival.
                while let Some(&(sub, req_id)) = nodes[node].blocked.front() {
                    if buffer_sub!(node, sub, req_id, false, inflight) {
                        nodes[node].blocked.pop_front();
                    } else {
                        break;
                    }
                }
            }
        }

        // all application writes acked -> final drain of the buffers
        if !all_apps_done && completed_reqs == total_reqs {
            all_apps_done = true;
            for n in 0..cfg.nodes {
                nodes[n].drained_mode = true;
                if let Some(stream) = nodes[n].grouper.flush_partial() {
                    nodes[n].on_stream_complete(&stream.reqs);
                }
                match &mut nodes[n].buffer {
                    SsdBuffer::Pipelined(p) => {
                        p.enqueue_residual_flush();
                    }
                    SsdBuffer::Single { region, flushing } => {
                        if region.used() > 0 {
                            *flushing = true;
                        }
                    }
                    SsdBuffer::None => {}
                }
                pump_flush!(n, inflight);
            }
        }
        // keep pumping residual flushes until every region is clean
        if all_apps_done {
            for n in 0..cfg.nodes {
                let dirty = match &mut nodes[n].buffer {
                    SsdBuffer::Pipelined(p) => {
                        if p.flushing_region().is_none() && p.flush_pending.is_empty() {
                            p.enqueue_residual_flush();
                        }
                        p.dirty()
                    }
                    SsdBuffer::Single { region, flushing } => {
                        if region.used() > 0 {
                            *flushing = true;
                        }
                        *flushing || region.used() > 0
                    }
                    SsdBuffer::None => false,
                };
                if dirty {
                    pump_flush!(n, inflight);
                }
            }
        }
    }

    let drained_us = engine.now();
    debug_assert_eq!(completed_reqs, total_reqs, "all requests must complete");
    for n in &nodes {
        debug_assert!(!n.dirty_buffers(), "buffers must drain");
    }

    // ------------------------------ results ------------------------------
    let mut node_stats = Vec::with_capacity(cfg.nodes);
    let mut streams_total = 0u64;
    let mut pct_sum = 0.0;
    for n in &mut nodes {
        n.stats.hdd_bytes = n.hdd.bytes_written;
        n.stats.hdd_busy_us = n.hdd.total_busy_us;
        n.stats.ssd_bytes_read = n.ssd.bytes_read;
        streams_total += n.stats.streams;
        pct_sum += n.pct_sum;
        node_stats.push(n.stats.clone());
    }
    let ssd_bytes: u64 = node_stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    SimResult {
        system: cfg.system.name(),
        workload: workload.name.clone(),
        makespan_us: makespan,
        drained_us,
        total_bytes,
        per_app: apps_list
            .iter()
            .zip(&apps)
            .map(|(&app, a)| AppStats {
                app,
                bytes: a.bytes,
                start_us: a.start_us.unwrap_or(0),
                end_us: a.end_us,
            })
            .collect(),
        nodes: node_stats,
        mean_percentage: if streams_total > 0 { pct_sum / streams_total as f64 } else { 0.0 },
        ssd_ratio: if total_bytes > 0 { ssd_bytes as f64 / total_bytes as f64 } else { 0.0 },
        events: engine.processed(),
    }
}

impl Node {
    fn dirty_buffers(&self) -> bool {
        match &self.buffer {
            SsdBuffer::None => false,
            SsdBuffer::Single { region, flushing } => *flushing || region.used() > 0,
            SsdBuffer::Pipelined(p) => p.dirty(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::DEFAULT_REQ_SECTORS;
    use crate::workload::ior::{ior, IorPattern};

    fn small_cfg(system: SystemKind) -> SimConfig {
        let mut c = SimConfig::new(system);
        c.seed = 42;
        c
    }

    fn small_ior(pattern: IorPattern, procs: u32) -> Workload {
        // 64 MiB total, 256 KB requests -> 256 requests
        ior(0, pattern, procs, 131_072, DEFAULT_REQ_SECTORS, 9)
    }

    #[test]
    fn orangefs_completes_all_bytes() {
        let w = small_ior(IorPattern::SegmentedContiguous, 4);
        let r = simulate(&small_cfg(SystemKind::OrangeFs), &w);
        assert_eq!(r.total_bytes, w.total_bytes());
        assert!(r.throughput_mbps() > 0.0);
        assert_eq!(r.ssd_ratio, 0.0, "native OrangeFS never touches SSD");
    }

    #[test]
    fn bb_routes_everything_to_ssd() {
        let w = small_ior(IorPattern::SegmentedRandom, 4);
        let r = simulate(&small_cfg(SystemKind::OrangeFsBB), &w);
        assert_eq!(r.total_bytes, w.total_bytes());
        assert!(r.ssd_ratio > 0.95, "BB buffers ~all data, got {}", r.ssd_ratio);
    }

    #[test]
    fn ssdup_plus_buffers_random_but_not_contiguous() {
        let seq = simulate(
            &small_cfg(SystemKind::SsdupPlus),
            &small_ior(IorPattern::SegmentedContiguous, 4),
        );
        // larger load so detection has warmed up (the first stream per
        // node is always routed by the bootstrap direction); the span is
        // kept at 16x the data so random offsets stay sparse
        let rnd = simulate(
            &small_cfg(SystemKind::SsdupPlus),
            &crate::workload::ior::ior_spanned(
                0,
                IorPattern::SegmentedRandom,
                16,
                524_288,
                524_288 * 16,
                DEFAULT_REQ_SECTORS,
                9,
            ),
        );
        assert!(
            seq.ssd_ratio < 0.3,
            "contiguous load should mostly bypass SSD, got {}",
            seq.ssd_ratio
        );
        assert!(
            rnd.ssd_ratio > 0.5,
            "random load should mostly hit SSD, got {}",
            rnd.ssd_ratio
        );
    }

    #[test]
    fn random_load_faster_on_ssdup_plus_than_orangefs() {
        let w = small_ior(IorPattern::SegmentedRandom, 16);
        let native = simulate(&small_cfg(SystemKind::OrangeFs), &w);
        let plus = simulate(&small_cfg(SystemKind::SsdupPlus), &w);
        assert!(
            plus.throughput_mbps() > native.throughput_mbps() * 1.3,
            "SSDUP+ {} vs OrangeFS {}",
            plus.throughput_mbps(),
            native.throughput_mbps()
        );
    }

    #[test]
    fn limited_ssd_still_completes_and_drains() {
        // 256 MiB so random streams stay *sparse* (a tiny file's random
        // permutation looks contiguous once sorted — scale artifact)
        let w = ior(0, IorPattern::SegmentedRandom, 8, 524_288, DEFAULT_REQ_SECTORS, 9);
        // 64 MiB SSD for a 256 MiB random load -> multiple flush cycles
        let cfg = small_cfg(SystemKind::SsdupPlus).with_ssd_mib(64);
        let r = simulate(&cfg, &w);
        assert_eq!(r.total_bytes, w.total_bytes());
        assert!(r.nodes.iter().map(|n| n.flushes).sum::<u64>() >= 2, "must have flushed");
        assert!(r.drained_us >= r.makespan_us);
        // buffered bytes eventually reach HDD: hdd bytes ~ total
        let hdd: u64 = r.nodes.iter().map(|n| n.hdd_bytes).sum();
        assert_eq!(hdd, w.total_bytes(), "every byte lands on HDD");
    }

    #[test]
    fn blocked_retry_preserves_fifo_and_exact_counts() {
        // tiny SSD + random load -> regions fill while the flusher is busy,
        // exercising the blocked queue and the RetryBlocked event path
        let w = ior(0, IorPattern::SegmentedRandom, 16, 262_144, DEFAULT_REQ_SECTORS, 3);
        let cfg = small_cfg(SystemKind::SsdupPlus).with_ssd_mib(8);
        let a = simulate(&cfg, &w);
        let blocked: u64 = a.nodes.iter().map(|n| n.blocked_requests).sum();
        assert!(blocked > 0, "scenario must exercise the blocked-retry path");
        // despite blocking, the run completes and every byte reaches HDD
        assert_eq!(a.total_bytes, w.total_bytes());
        let hdd: u64 = a.nodes.iter().map(|n| n.hdd_bytes).sum();
        assert_eq!(hdd, w.total_bytes(), "every byte lands on HDD after drain");
        // each sub-request is counted at its blocking arrival only: with
        // 2 nodes and 256 KB requests there are exactly 2 subs per
        // request, so retries that fail must not inflate the counter
        assert!(
            blocked <= 2 * w.total_requests() as u64,
            "blocked_requests double-counted: {blocked}"
        );
        // the retry path must preserve FIFO order and event determinism
        let b = simulate(&cfg, &w);
        let blocked_b: u64 = b.nodes.iter().map(|n| n.blocked_requests).sum();
        assert_eq!(blocked, blocked_b);
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.drained_us, b.drained_us);
    }

    #[test]
    fn deterministic_same_seed() {
        let w = small_ior(IorPattern::Strided, 8);
        let a = simulate(&small_cfg(SystemKind::SsdupPlus), &w);
        let b = simulate(&small_cfg(SystemKind::SsdupPlus), &w);
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.events, b.events);
        assert_eq!(a.ssd_ratio, b.ssd_ratio);
    }

    #[test]
    fn sequential_apps_respect_gap() {
        let a = small_ior(IorPattern::SegmentedContiguous, 2);
        let b = small_ior(IorPattern::SegmentedContiguous, 2);
        let gap = 3_000_000;
        let w = Workload::sequential("seq", a, gap, b);
        let r = simulate(&small_cfg(SystemKind::OrangeFs), &w);
        let apps = &r.per_app;
        assert_eq!(apps.len(), 2);
        assert!(
            apps[1].start_us >= apps[0].end_us + gap,
            "app B started at {} before app A end {} + gap",
            apps[1].start_us,
            apps[0].end_us
        );
    }
}
