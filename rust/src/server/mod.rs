//! The I/O-node server layer: configuration, the end-to-end cluster
//! simulation, and result metrics. This is where the paper's four systems
//! (OrangeFS, OrangeFS-BB, SSDUP, SSDUP+) are assembled from the
//! detector/redirector/buffer/device building blocks.

pub mod cluster;
pub mod config;
pub mod metrics;

pub use cluster::{simulate, simulate_with_backends};
pub use config::{SimConfig, SystemKind};
pub use metrics::{AppStats, NodeStats, SimResult};
