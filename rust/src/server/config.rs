//! Simulation / server configuration.

use crate::device::{HddConfig, SsdConfig};
use crate::types::mib_to_sectors;

/// Which of the paper's four systems the I/O nodes run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// native OrangeFS: every write to HDD
    OrangeFs,
    /// OrangeFS-BB: every write to SSD; single region; while the full SSD
    /// flushes, new writes fall back to HDD (§4.2.3 analysis)
    OrangeFsBB,
    /// SSDUP (ICS'17): static 45/30 water marks, immediate flushing
    Ssdup,
    /// SSDUP+: adaptive threshold + traffic-aware pipelined flushing
    SsdupPlus,
}

impl SystemKind {
    pub const ALL: [SystemKind; 4] =
        [SystemKind::OrangeFs, SystemKind::OrangeFsBB, SystemKind::Ssdup, SystemKind::SsdupPlus];

    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::OrangeFs => "orangefs",
            SystemKind::OrangeFsBB => "orangefs-bb",
            SystemKind::Ssdup => "ssdup",
            SystemKind::SsdupPlus => "ssdup+",
        }
    }

    pub fn uses_ssd(&self) -> bool {
        !matches!(self, SystemKind::OrangeFs)
    }
}

impl std::str::FromStr for SystemKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "orangefs" | "native" => Ok(SystemKind::OrangeFs),
            "orangefs-bb" | "bb" => Ok(SystemKind::OrangeFsBB),
            "ssdup" => Ok(SystemKind::Ssdup),
            "ssdup+" | "ssdupplus" | "ssdup-plus" => Ok(SystemKind::SsdupPlus),
            other => Err(format!("unknown system '{other}'")),
        }
    }
}

/// Full simulation configuration (defaults mirror the paper's testbed:
/// 2 I/O nodes, 64 KB stripes, CFQ depth 128, 240 GB SSD — effectively
/// unconstrained unless an experiment shrinks it).
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub system: SystemKind,
    pub nodes: usize,
    pub stripe_sectors: i32,
    pub stream_len: usize,
    pub hdd: HddConfig,
    pub ssd: SsdConfig,
    /// per-node SSD buffer capacity in sectors
    pub ssd_capacity_sectors: i64,
    /// one-way network latency per sub-request, us
    pub net_us: u64,
    /// per-node NIC ingest bandwidth, MB/s (the paper's testbed is
    /// Gigabit Ethernet: ~117 MB/s per I/O node — this is what caps
    /// OrangeFS-BB at ~220 MB/s aggregate in Fig 11)
    pub nic_mbps: f64,
    /// outstanding requests per process (async MPI-IO depth)
    pub io_depth: usize,
    /// mean exponential think/jitter time per request issue, us
    pub jitter_us: f64,
    /// requests per I/O burst (0 = no compute phases); every burst_len
    /// requests a process pauses ~burst_gap_us (compute/I-O alternation)
    pub burst_len: u64,
    pub burst_gap_us: f64,
    /// traffic-aware flush pause threshold (SSDUP+ only)
    pub pause_below: f32,
    /// re-check interval while a flush is paused, us
    pub flush_check_us: u64,
    /// max flush extents enqueued in the HDD queue at once
    pub flush_inflight: usize,
    /// adaptive PercentList history size
    pub history: usize,
    /// override SSDUP's 45/30 water marks with one degenerate threshold
    /// (ablation-threshold experiment)
    pub static_threshold: Option<f32>,
    pub seed: u64,
}

impl SimConfig {
    pub fn new(system: SystemKind) -> Self {
        Self {
            system,
            nodes: 2,
            stripe_sectors: 128,
            stream_len: 128,
            hdd: HddConfig::default(),
            ssd: SsdConfig::default(),
            ssd_capacity_sectors: mib_to_sectors(240 * 1024), // 240 GB
            net_us: 1000,
            nic_mbps: 117.0,
            io_depth: 8,
            jitter_us: 2000.0,
            burst_len: 64,
            burst_gap_us: 150_000.0,
            pause_below: 0.45,
            flush_check_us: 100_000,
            flush_inflight: 12,
            history: 64,
            static_threshold: None,
            seed: 0x55D0_u64,
        }
    }

    /// Limit the per-node SSD capacity (Fig 13/14 use small SSDs).
    pub fn with_ssd_mib(mut self, mib: u64) -> Self {
        self.ssd_capacity_sectors = mib_to_sectors(mib);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_queue_size(mut self, q: usize) -> Self {
        self.hdd.queue_size = q;
        self.stream_len = q; // the paper ties stream length to CFQ depth
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_kind_parses() {
        assert_eq!("ssdup+".parse::<SystemKind>().unwrap(), SystemKind::SsdupPlus);
        assert_eq!("BB".parse::<SystemKind>().unwrap(), SystemKind::OrangeFsBB);
        assert!("nope".parse::<SystemKind>().is_err());
    }

    #[test]
    fn builders_apply() {
        let c = SimConfig::new(SystemKind::SsdupPlus).with_ssd_mib(8192).with_queue_size(32);
        assert_eq!(c.ssd_capacity_sectors, 16 * 1024 * 1024);
        assert_eq!(c.hdd.queue_size, 32);
        assert_eq!(c.stream_len, 32);
    }
}
