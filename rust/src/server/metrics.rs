//! Result metrics: per-run statistics the paper tables/figures are built
//! from, plus the wall-clock latency histogram the live engine's load
//! generator records.

use crate::types::Usec;

/// Number of linear sub-buckets per power-of-two octave (2^3 = 8): values
/// below 16 are exact, everything above is bucketed within ~12.5%.
const HIST_SUB_BITS: u32 = 3;
// max index is (63 - 3 + 1) * 8 + 7 = 495 (for u64::MAX), so 512 covers
// the full u64 range
const HIST_BUCKETS: usize = 512;

/// Log-bucketed latency histogram (microseconds). HDR-style bucketing:
/// fixed memory, ~12.5% worst-case value error, O(1) record, mergeable
/// across load-generator threads.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

fn bucket_index(v: u64) -> usize {
    let v = v.max(1);
    let exp = 63 - v.leading_zeros() as u64; // floor(log2(v))
    if exp < HIST_SUB_BITS as u64 {
        return v as usize; // small values map to themselves
    }
    let sub = (v >> (exp - HIST_SUB_BITS as u64)) & ((1 << HIST_SUB_BITS) - 1);
    (((exp - HIST_SUB_BITS as u64 + 1) << HIST_SUB_BITS) + sub) as usize
}

/// Lower bound of the value range covered by `idx` (inverse of
/// `bucket_index` up to bucket granularity).
fn bucket_value(idx: usize) -> u64 {
    if idx < (2 << HIST_SUB_BITS) {
        return idx as u64;
    }
    let exp = idx as u64 / (1 << HIST_SUB_BITS) + HIST_SUB_BITS as u64 - 1;
    let sub = idx as u64 % (1 << HIST_SUB_BITS);
    ((1 << HIST_SUB_BITS) + sub) << (exp - HIST_SUB_BITS as u64)
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; HIST_BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    #[inline]
    pub fn record(&mut self, us: u64) {
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Exact total of recorded values — the additive quantity stage
    /// attribution reconciles across histograms (sums are exact even
    /// though quantiles are bucketed).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Bucket index a value falls into — the granularity unit for
    /// "within one bucket" accuracy statements (property tests compare
    /// `quantile()` against an exact reference through this).
    pub fn bucket_of(us: u64) -> usize {
        bucket_index(us)
    }

    /// Value at quantile `q` in [0, 1] (bucket lower bound; exact for
    /// values < 16 us, within ~12.5% above). 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return bucket_value(idx);
            }
        }
        self.max_us
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Fold another histogram in (per-thread histograms -> run total).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// One-line `p50/p95/p99/max` summary.
    pub fn summary(&self) -> String {
        format!(
            "p50 {}us  p95 {}us  p99 {}us  max {}us  (n={})",
            self.p50(),
            self.p95(),
            self.p99(),
            self.max_us,
            self.count
        )
    }
}

/// Per-application I/O statistics.
#[derive(Clone, Debug)]
pub struct AppStats {
    pub app: u16,
    pub bytes: u64,
    pub start_us: Usec,
    pub end_us: Usec,
}

impl AppStats {
    /// Application-visible write bandwidth, MB/s.
    pub fn throughput_mbps(&self) -> f64 {
        if self.end_us <= self.start_us {
            return 0.0;
        }
        self.bytes as f64 / (self.end_us - self.start_us) as f64
    }
}

/// Per-node device + buffer statistics.
#[derive(Clone, Debug, Default)]
pub struct NodeStats {
    pub hdd_bytes: u64,
    pub hdd_seeks: u64,
    pub hdd_busy_us: f64,
    pub ssd_bytes_buffered: u64,
    pub ssd_bytes_read: u64,
    pub peak_ssd_occupancy_sectors: i64,
    pub streams: u64,
    pub flushes: u64,
    pub flush_pause_us: Usec,
    pub flush_pauses: u64,
    pub blocked_requests: u64,
    pub avl_metadata_peak_bytes: usize,
    /// detection overhead accounting (Table 1)
    pub group_cost_us: f64,
    pub avl_cost_us: f64,
}

/// Full simulation result.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub system: &'static str,
    pub workload: String,
    /// time of the last application ack (the app-visible makespan)
    pub makespan_us: Usec,
    /// time when the final background flush drained
    pub drained_us: Usec,
    pub total_bytes: u64,
    pub per_app: Vec<AppStats>,
    pub nodes: Vec<NodeStats>,
    /// mean random percentage over all streams
    pub mean_percentage: f64,
    /// fraction of bytes routed to SSD
    pub ssd_ratio: f64,
    /// simulated events processed (debug/perf visibility)
    pub events: u64,
}

impl SimResult {
    /// Aggregate application-visible throughput, MB/s.
    pub fn throughput_mbps(&self) -> f64 {
        if self.makespan_us == 0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.makespan_us as f64
    }

    pub fn app(&self, app: u16) -> Option<&AppStats> {
        self.per_app.iter().find(|a| a.app == app)
    }

    pub fn ssd_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.ssd_bytes_buffered).sum()
    }

    pub fn total_flush_pause_us(&self) -> Usec {
        self.nodes.iter().map(|n| n.flush_pause_us).sum()
    }

    /// One-line human summary (used by the CLI and examples).
    pub fn summary(&self) -> String {
        format!(
            "{:<12} {:<34} {:>8.2} MB/s  ssd {:>5.1}%  rp {:>5.1}%  pauses {:>6.1}s  makespan {:>7.2}s",
            self.system,
            self.workload,
            self.throughput_mbps(),
            self.ssd_ratio * 100.0,
            self.mean_percentage * 100.0,
            self.total_flush_pause_us() as f64 / 1e6,
            self.makespan_us as f64 / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_small_values_are_exact() {
        let mut h = LatencyHistogram::new();
        for v in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 10] {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.p50(), 5);
        assert_eq!(h.quantile(1.0), 10);
        assert_eq!(h.max_us(), 10);
        assert!((h.mean_us() - 5.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_within_bucket_error() {
        let mut h = LatencyHistogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        // bucket lower bounds are within 12.5% below the true quantile
        for (q, truth) in [(0.5, 5_000u64), (0.95, 9_500), (0.99, 9_900)] {
            let got = h.quantile(q) as f64;
            let t = truth as f64;
            assert!(got <= t && got >= t * 0.87, "q={q}: got {got}, truth {t}");
        }
        assert_eq!(h.max_us(), 10_000);
    }

    #[test]
    fn histogram_merge_equals_combined_recording() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for v in [3u64, 70, 900, 12_000] {
            a.record(v);
            all.record(v);
        }
        for v in [5u64, 55, 1_000_000] {
            b.record(v);
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), all.quantile(q));
        }
        assert_eq!(a.max_us(), all.max_us());
        assert!(a.summary().contains("p99"));
    }

    #[test]
    fn histogram_extreme_values_do_not_panic() {
        let mut h = LatencyHistogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) > 1 << 59);
    }

    #[test]
    fn throughput_math() {
        let a = AppStats { app: 0, bytes: 100 * 1024 * 1024, start_us: 0, end_us: 1_000_000 };
        assert!((a.throughput_mbps() - 104.857).abs() < 0.01);
        let zero = AppStats { app: 0, bytes: 5, start_us: 7, end_us: 7 };
        assert_eq!(zero.throughput_mbps(), 0.0);
    }

    #[test]
    fn result_aggregates() {
        let r = SimResult {
            system: "ssdup+",
            workload: "w".into(),
            makespan_us: 2_000_000,
            drained_us: 2_500_000,
            total_bytes: 200 * 1024 * 1024,
            per_app: vec![],
            nodes: vec![
                NodeStats { ssd_bytes_buffered: 10, flush_pause_us: 5, ..Default::default() },
                NodeStats { ssd_bytes_buffered: 20, flush_pause_us: 7, ..Default::default() },
            ],
            mean_percentage: 0.5,
            ssd_ratio: 0.25,
            events: 1,
        };
        assert!((r.throughput_mbps() - 104.857).abs() < 0.01);
        assert_eq!(r.ssd_bytes(), 30);
        assert_eq!(r.total_flush_pause_us(), 12);
        assert!(r.summary().contains("ssdup+"));
    }
}
