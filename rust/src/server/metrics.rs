//! Result metrics collected by a simulation run — the numbers every paper
//! table/figure is built from.

use crate::types::Usec;

/// Per-application I/O statistics.
#[derive(Clone, Debug)]
pub struct AppStats {
    pub app: u16,
    pub bytes: u64,
    pub start_us: Usec,
    pub end_us: Usec,
}

impl AppStats {
    /// Application-visible write bandwidth, MB/s.
    pub fn throughput_mbps(&self) -> f64 {
        if self.end_us <= self.start_us {
            return 0.0;
        }
        self.bytes as f64 / (self.end_us - self.start_us) as f64
    }
}

/// Per-node device + buffer statistics.
#[derive(Clone, Debug, Default)]
pub struct NodeStats {
    pub hdd_bytes: u64,
    pub hdd_seeks: u64,
    pub hdd_busy_us: f64,
    pub ssd_bytes_buffered: u64,
    pub ssd_bytes_read: u64,
    pub peak_ssd_occupancy_sectors: i64,
    pub streams: u64,
    pub flushes: u64,
    pub flush_pause_us: Usec,
    pub flush_pauses: u64,
    pub blocked_requests: u64,
    pub avl_metadata_peak_bytes: usize,
    /// detection overhead accounting (Table 1)
    pub group_cost_us: f64,
    pub avl_cost_us: f64,
}

/// Full simulation result.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub system: &'static str,
    pub workload: String,
    /// time of the last application ack (the app-visible makespan)
    pub makespan_us: Usec,
    /// time when the final background flush drained
    pub drained_us: Usec,
    pub total_bytes: u64,
    pub per_app: Vec<AppStats>,
    pub nodes: Vec<NodeStats>,
    /// mean random percentage over all streams
    pub mean_percentage: f64,
    /// fraction of bytes routed to SSD
    pub ssd_ratio: f64,
    /// simulated events processed (debug/perf visibility)
    pub events: u64,
}

impl SimResult {
    /// Aggregate application-visible throughput, MB/s.
    pub fn throughput_mbps(&self) -> f64 {
        if self.makespan_us == 0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.makespan_us as f64
    }

    pub fn app(&self, app: u16) -> Option<&AppStats> {
        self.per_app.iter().find(|a| a.app == app)
    }

    pub fn ssd_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.ssd_bytes_buffered).sum()
    }

    pub fn total_flush_pause_us(&self) -> Usec {
        self.nodes.iter().map(|n| n.flush_pause_us).sum()
    }

    /// One-line human summary (used by the CLI and examples).
    pub fn summary(&self) -> String {
        format!(
            "{:<12} {:<34} {:>8.2} MB/s  ssd {:>5.1}%  rp {:>5.1}%  pauses {:>6.1}s  makespan {:>7.2}s",
            self.system,
            self.workload,
            self.throughput_mbps(),
            self.ssd_ratio * 100.0,
            self.mean_percentage * 100.0,
            self.total_flush_pause_us() as f64 / 1e6,
            self.makespan_us as f64 / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let a = AppStats { app: 0, bytes: 100 * 1024 * 1024, start_us: 0, end_us: 1_000_000 };
        assert!((a.throughput_mbps() - 104.857).abs() < 0.01);
        let zero = AppStats { app: 0, bytes: 5, start_us: 7, end_us: 7 };
        assert_eq!(zero.throughput_mbps(), 0.0);
    }

    #[test]
    fn result_aggregates() {
        let r = SimResult {
            system: "ssdup+",
            workload: "w".into(),
            makespan_us: 2_000_000,
            drained_us: 2_500_000,
            total_bytes: 200 * 1024 * 1024,
            per_app: vec![],
            nodes: vec![
                NodeStats { ssd_bytes_buffered: 10, flush_pause_us: 5, ..Default::default() },
                NodeStats { ssd_bytes_buffered: 20, flush_pause_us: 7, ..Default::default() },
            ],
            mean_percentage: 0.5,
            ssd_ratio: 0.25,
            events: 1,
        };
        assert!((r.throughput_mbps() - 104.857).abs() < 0.01);
        assert_eq!(r.ssd_bytes(), 30);
        assert_eq!(r.total_flush_pause_us(), 12);
        assert!(r.summary().contains("ssdup+"));
    }
}
