//! Discrete-event simulation substrate.
//!
//! The whole evaluation runs on simulated time (the paper's testbed — real
//! HDD/SSD/OrangeFS cluster — is a hardware gate; see DESIGN.md
//! §Substitutions). The engine is a deterministic event queue generic over
//! the event payload; tie-breaks use insertion sequence so identical seeds
//! replay identically.

mod engine;

pub use engine::Engine;
