//! Deterministic event-queue engine.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::types::Usec;

struct Scheduled<E> {
    at: Usec,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then by
        // insertion order for same-timestamp determinism.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event queue.
pub struct Engine<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: Usec,
    seq: u64,
    processed: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0, seq: 0, processed: 0 }
    }

    /// Current simulated time (time of the most recently popped event).
    pub fn now(&self) -> Usec {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `at` (clamped to >= now).
    pub fn schedule_at(&mut self, at: Usec, payload: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, payload });
    }

    /// Schedule `payload` after `delay` microseconds.
    pub fn schedule_in(&mut self, delay: Usec, payload: E) {
        self.schedule_at(self.now.saturating_add(delay), payload);
    }

    /// Pop the next event, advancing the clock. Returns None when drained.
    pub fn pop(&mut self) -> Option<(Usec, E)> {
        let s = self.heap.pop()?;
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = s.at;
        self.processed += 1;
        Some((s.at, s.payload))
    }

    /// Peek the next event time without popping.
    pub fn peek_time(&self) -> Option<Usec> {
        self.heap.peek().map(|s| s.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(30, 3);
        e.schedule_at(10, 1);
        e.schedule_at(20, 2);
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(e.now(), 30);
        assert_eq!(e.processed(), 3);
    }

    #[test]
    fn same_timestamp_is_fifo() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..100 {
            e.schedule_at(5, i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_is_relative_to_now() {
        let mut e: Engine<&'static str> = Engine::new();
        e.schedule_at(100, "first");
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 100);
        e.schedule_in(50, "second");
        let (t2, p) = e.pop().unwrap();
        assert_eq!((t2, p), (150, "second"));
    }

    #[test]
    fn schedule_in_past_clamps_to_now() {
        let mut e: Engine<u8> = Engine::new();
        e.schedule_at(100, 1);
        e.pop();
        e.schedule_at(10, 2); // in the past -> clamped
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 100);
    }

    #[test]
    fn interleaved_scheduling_during_processing() {
        // events that schedule follow-ups — the standard DES pattern
        let mut e: Engine<u64> = Engine::new();
        e.schedule_at(0, 0);
        let mut log = Vec::new();
        while let Some((t, gen)) = e.pop() {
            log.push((t, gen));
            if gen < 5 {
                e.schedule_in(10, gen + 1);
            }
        }
        assert_eq!(log, (0..=5).map(|g| (g * 10, g)).collect::<Vec<_>>());
    }
}
