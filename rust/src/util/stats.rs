//! Small statistics substrate used by the metrics module, the bench
//! harness, and the experiment reports.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn stdev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile by linear interpolation on the sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Pearson correlation coefficient; 0.0 when undefined.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Streaming mean/min/max/count accumulator for hot loops (no allocation).
#[derive(Clone, Debug, Default)]
pub struct Accum {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stdev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stdev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stdev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_correlations() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let up = [2.0, 4.0, 6.0, 8.0];
        let down = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &up) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &down) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0; 4]), 0.0);
    }

    #[test]
    fn accum_tracks_extremes() {
        let mut a = Accum::new();
        for x in [3.0, -1.0, 10.0] {
            a.push(x);
        }
        assert_eq!(a.n, 3);
        assert_eq!(a.min, -1.0);
        assert_eq!(a.max, 10.0);
        assert!((a.mean() - 4.0).abs() < 1e-12);
    }
}
