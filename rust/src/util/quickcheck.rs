//! Mini property-testing substrate (proptest is not on the image).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it retries with progressively simpler
//! inputs from the same generator family (size-bounded regeneration — a
//! pragmatic stand-in for true shrinking) and reports the smallest
//! counterexample found plus the reproduction seed.

use crate::util::prng::Prng;

/// A generator is any `Fn(&mut Prng, usize) -> T`; the `usize` is a size
/// hint the runner ramps up, so early cases are small.
pub fn forall<T, G, P>(seed: u64, cases: usize, name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Prng, usize) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Prng::new(seed);
    for case in 0..cases {
        // ramp size 1..=64 over the run so failures tend to be small
        let size = 1 + (case * 64) / cases.max(1);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            // regeneration-based simplification: try many small inputs to
            // find a smaller failing case before reporting.
            let mut smallest: Option<(usize, T)> = None;
            let mut shrink_rng = Prng::new(seed ^ 0xDEAD_BEEF);
            for s in 1..=size {
                for _ in 0..50 {
                    let cand = gen(&mut shrink_rng, s);
                    if !prop(&cand) {
                        smallest = Some((s, cand));
                        break;
                    }
                }
                if smallest.is_some() {
                    break;
                }
            }
            match smallest {
                Some((s, cand)) => panic!(
                    "property '{name}' failed (seed={seed}, case={case}, size={size});\n\
                     simplified counterexample (size {s}): {cand:?}"
                ),
                None => panic!(
                    "property '{name}' failed (seed={seed}, case={case}, size={size});\n\
                     counterexample: {input:?}"
                ),
            }
        }
    }
}

/// Generator helpers.
pub mod gens {
    use crate::util::prng::Prng;

    /// Vec of i32 in [lo, hi), length <= size*scale.
    pub fn vec_i32(rng: &mut Prng, size: usize, scale: usize, lo: i32, hi: i32) -> Vec<i32> {
        let len = rng.gen_range((size * scale + 1) as u64) as usize;
        (0..len).map(|_| lo + rng.gen_range((hi - lo) as u64) as i32).collect()
    }

    /// Sorted unique u64 offsets.
    pub fn sorted_unique(rng: &mut Prng, size: usize, max: u64) -> Vec<u64> {
        let len = (rng.gen_range(size as u64 + 1) as usize).min(max as usize);
        let mut v = rng.sample_distinct(max, len);
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        forall(1, 200, "reverse twice is id", |rng, size| {
            gens::vec_i32(rng, size, 4, -100, 100)
        }, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property 'sum is small' failed")]
    fn failing_property_panics_with_counterexample() {
        forall(2, 500, "sum is small", |rng, size| {
            gens::vec_i32(rng, size, 8, 0, 100)
        }, |v| v.iter().sum::<i32>() < 50);
    }

    #[test]
    fn sorted_unique_is_sorted_and_unique() {
        forall(3, 100, "sorted_unique invariant", |rng, size| {
            gens::sorted_unique(rng, size, 10_000)
        }, |v| v.windows(2).all(|w| w[0] < w[1]));
    }
}
