//! Substrate layer: everything a production repo would pull from crates.io
//! but this offline image must provide in-tree (see Cargo.toml note).

pub mod benchkit;
pub mod cli;
pub mod crc;
pub mod json;
pub mod prng;
pub mod quickcheck;
pub mod stats;
pub mod threadpool;
