//! In-tree micro/macro-benchmark harness (criterion is not on the image).
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries (harness = false);
//! each uses this module: warmup, timed iterations, mean/stdev/p50/p95,
//! and a stable one-line-per-bench report that EXPERIMENTS.md quotes.
//! Honors `SSDUP_BENCH_FAST=1` to shrink iteration counts in CI.

use std::hint::black_box;
use std::time::Instant;

use crate::util::stats;

pub use std::hint::black_box as bb;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stdev_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// optional throughput denominator: items processed per iteration
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<44} {:>12.1} ns/iter (p50 {:>10.1}, p95 {:>10.1}, sd {:>9.1}, n={})",
            self.name, self.mean_ns, self.p50_ns, self.p95_ns, self.stdev_ns, self.iters
        );
        if self.items_per_iter > 0.0 {
            let per_item = self.mean_ns / self.items_per_iter;
            let mops = 1000.0 / per_item;
            line.push_str(&format!("  [{per_item:.1} ns/item, {mops:.2} Mitems/s]"));
        }
        line
    }
}

pub struct Bench {
    warmup_iters: u64,
    measure_samples: usize,
    iters_per_sample: u64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        let fast = std::env::var("SSDUP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        if fast {
            Self { warmup_iters: 3, measure_samples: 5, iters_per_sample: 3, results: vec![] }
        } else {
            Self { warmup_iters: 20, measure_samples: 30, iters_per_sample: 10, results: vec![] }
        }
    }

    /// Override sampling (macro benches that take ~seconds per iteration).
    pub fn slow(mut self) -> Self {
        let fast = std::env::var("SSDUP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        self.warmup_iters = 1;
        self.measure_samples = if fast { 3 } else { 10 };
        self.iters_per_sample = 1;
        self
    }

    /// Benchmark `f`, treating each call as processing `items` units
    /// (pass 0.0 for pure latency benches).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, items: f64, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns = Vec::with_capacity(self.measure_samples);
        for _ in 0..self.measure_samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / self.iters_per_sample as f64;
            samples_ns.push(dt);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: self.measure_samples as u64 * self.iters_per_sample,
            mean_ns: stats::mean(&samples_ns),
            stdev_ns: stats::stdev(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            items_per_iter: items,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Optional filter from argv: `cargo bench -- <substring>`.
    pub fn should_run(name: &str) -> bool {
        let filters: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
        filters.is_empty() || filters.iter().any(|f| name.contains(f.as_str()))
    }
}

/// Print a section header so bench output groups visibly per paper table.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("SSDUP_BENCH_FAST", "1");
        let mut b = Bench::new();
        let r = b.run("spin", 100.0, || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.items_per_iter, 100.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn report_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean_ns: 1234.5,
            stdev_ns: 1.0,
            p50_ns: 1230.0,
            p95_ns: 1240.0,
            items_per_iter: 0.0,
        };
        let s = r.report();
        assert!(s.contains("ns/iter"));
        assert!(!s.contains("ns/item"));
    }
}
