//! Tiny CLI-argument substrate (no clap on the offline image).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! switch style used by the `ssdup` binary and the examples.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// positional arguments in order (subcommand first)
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` options
    pub options: BTreeMap<String, String>,
    /// bare `--key` switches
    pub switches: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid { key: String, value: String, why: String },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(opt) => write!(f, "unknown option --{opt}"),
            CliError::MissingValue(opt) => write!(f, "option --{opt} expects a value"),
            CliError::Invalid { key, value, why } => {
                write!(f, "invalid value for --{key}: {value} ({why})")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse an iterator of argv-style strings (without the program name).
    /// `value_opts` lists options that consume a value; anything else
    /// starting with `--` is a boolean switch.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, value_opts: &[&str]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&rest) {
                    match it.next() {
                        Some(v) => {
                            out.options.insert(rest.to_string(), v);
                        }
                        None => return Err(CliError::MissingValue(rest.to_string())),
                    }
                } else {
                    out.switches.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(value_opts: &[&str]) -> Result<Args, CliError> {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: T::Err| CliError::Invalid {
                key: key.to_string(),
                value: v.to_string(),
                why: e.to_string(),
            }),
        }
    }

    /// Comma-separated list option, e.g. `--procs 8,16,32`.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|e: T::Err| CliError::Invalid {
                        key: key.to_string(),
                        value: p.to_string(),
                        why: e.to_string(),
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_switches() {
        let a = Args::parse(argv("exp fig11 --procs 8,16 --seed=7 --verbose"), &["procs", "seed"]).unwrap();
        assert_eq!(a.subcommand(), Some("exp"));
        assert_eq!(a.positional[1], "fig11");
        assert_eq!(a.get("procs"), Some("8,16"));
        assert_eq!(a.get("seed"), Some("7"));
        assert!(a.has("verbose"));
    }

    #[test]
    fn get_parse_and_list() {
        let a = Args::parse(argv("run --n 42 --ratios 0.1,0.5"), &["n", "ratios"]).unwrap();
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 42);
        assert_eq!(a.get_parse("missing", 9usize).unwrap(), 9);
        assert_eq!(a.get_list::<f64>("ratios", &[]).unwrap(), vec![0.1, 0.5]);
        assert_eq!(a.get_list::<u32>("missing", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(matches!(
            Args::parse(argv("run --n"), &["n"]),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn invalid_value_is_error() {
        let a = Args::parse(argv("run --n abc"), &["n"]).unwrap();
        assert!(a.get_parse("n", 0usize).is_err());
    }
}
