//! Minimal JSON substrate (offline image ships no serde facade).
//!
//! Covers the full JSON grammar the repo needs: the artifact manifest
//! written by `python/compile/aot.py`, experiment configs, and the
//! machine-readable experiment reports. Numbers are kept as f64 (i64s the
//! manifest uses are exactly representable).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `v.at(&["artifacts", "detector", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs unsupported (not needed here)
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    let raw = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(raw);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]).unwrap().as_bool(), Some(false));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_real_manifest_shape() {
        let text = r#"{
          "version": 1,
          "batch": 16,
          "nmax": 512,
          "seek_model": {"knee_sectors": 2048, "short_base_us": 500.0},
          "artifacts": {"detector": {"file": "detector.hlo.txt",
            "inputs": [["offsets", "s32", [16, 512]]]}}
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("batch").unwrap().as_i64(), Some(16));
        assert_eq!(
            v.at(&["artifacts", "detector", "file"]).unwrap().as_str(),
            Some("detector.hlo.txt")
        );
        let inp = v.at(&["artifacts", "detector", "inputs"]).unwrap().as_arr().unwrap();
        assert_eq!(inp[0].as_arr().unwrap()[1].as_str(), Some("s32"));
    }

    #[test]
    fn round_trip() {
        let v = Json::obj(vec![
            ("name", "ssdup+".into()),
            ("n", 128i64.into()),
            ("ratio", Json::Num(0.25)),
            ("rows", vec![1i64, 2, 3].into()),
            ("nested", Json::obj(vec![("ok", true.into())])),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_on_output() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
