//! Zero-dependency CRC-32C (Castagnoli), table-driven.
//!
//! The live engine's on-SSD record frames and superblocks carry a
//! CRC-32C over header + payload so recovery can tell a complete record
//! from a torn or stale one (`live::record`). Castagnoli rather than the
//! IEEE polynomial for its better error-detection properties on storage
//! workloads (same choice as iSCSI, ext4, and btrfs).
//!
//! The reflected polynomial is `0x82F63B78`; the check value — the CRC of
//! the ASCII bytes `"123456789"` — is `0xE3069283`.

/// Reflected CRC-32C polynomial.
const POLY: u32 = 0x82F6_3B78;

/// 256-entry lookup table, built at compile time.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// Incremental CRC-32C: `update` over any number of chunks, `finish` to
/// read the digest. Used by record framing to checksum a header and its
/// payload without concatenating them.
#[derive(Clone, Copy, Debug)]
pub struct Crc32c {
    state: u32,
}

impl Default for Crc32c {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32c {
    pub fn new() -> Self {
        Self { state: !0 }
    }

    pub fn update(&mut self, data: &[u8]) -> &mut Self {
        let mut crc = self.state;
        for &b in data {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
        self
    }

    pub fn finish(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC-32C of `data`.
pub fn crc32c(data: &[u8]) -> u32 {
    let mut c = Crc32c::new();
    c.update(data);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Bit-by-bit reference implementation (no table): the table-driven
    /// fast path must agree with it on arbitrary input.
    fn crc32c_bitwise(data: &[u8]) -> u32 {
        let mut crc = !0u32;
        for &b in data {
            crc ^= b as u32;
            for _ in 0..8 {
                crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            }
        }
        !crc
    }

    #[test]
    fn known_answer_vectors() {
        // the CRC-32C check value (iSCSI test vector, RFC 3720 appendix)
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(b""), 0);
        // 32 zero bytes (iSCSI test vector)
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
        // 32 0xFF bytes (iSCSI test vector)
        assert_eq!(crc32c(&[0xFFu8; 32]), 0x62A8_AB43);
        // ascending 0x00..0x1F (iSCSI test vector)
        let asc: Vec<u8> = (0u8..32).collect();
        assert_eq!(crc32c(&asc), 0x46DD_794E);
    }

    #[test]
    fn table_matches_bitwise_reference_on_random_data() {
        let mut rng = crate::util::prng::Prng::new(99);
        for _ in 0..64 {
            let len = rng.gen_range(512) as usize;
            let data: Vec<u8> = (0..len).map(|_| rng.gen_range(256) as u8).collect();
            assert_eq!(crc32c(&data), crc32c_bitwise(&data));
        }
    }

    #[test]
    fn incremental_update_equals_one_shot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let one_shot = crc32c(&data);
        let mut inc = Crc32c::new();
        for chunk in data.chunks(17) {
            inc.update(chunk);
        }
        assert_eq!(inc.finish(), one_shot);
        // empty updates are identity
        let mut inc2 = Crc32c::new();
        inc2.update(&[]).update(&data).update(&[]);
        assert_eq!(inc2.finish(), one_shot);
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data = vec![0xA5u8; 64];
        let clean = crc32c(&data);
        for byte in 0..64 {
            for bit in 0..8 {
                data[byte] ^= 1 << bit;
                assert_ne!(crc32c(&data), clean, "flip at {byte}:{bit} must change the CRC");
                data[byte] ^= 1 << bit;
            }
        }
    }
}
