//! Deterministic PRNG substrate (offline image ships no `rand` crate).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! pairing: SplitMix64 is a good one-shot mixer, xoshiro256** passes BigCrush
//! and is fast enough for the simulator hot loop. Every workload generator
//! and property test takes an explicit seed so runs are reproducible.

/// SplitMix64: used to expand a user seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the main generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // all-zero state is the one forbidden state; SplitMix64 of any seed
        // cannot produce four zeros in a row, but belt-and-braces:
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi) — convenience for index ranges.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// true with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct values from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: u64, k: usize) -> Vec<u64> {
        assert!((k as u64) <= n, "sample_distinct: k > n");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k as u64)..n {
            let t = self.gen_range(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Exponentially distributed with mean `mean` (arrival gaps).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Fork a statistically independent child generator (for per-process
    /// workload streams) — hashes the label into the child seed.
    pub fn fork(&mut self, label: u64) -> Prng {
        let mut sm = SplitMix64::new(self.next_u64() ^ label.wrapping_mul(0xA24B_AED4_963E_E407));
        Prng::new(sm.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut p = Prng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = p.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_in_unit_interval_with_sane_mean() {
        let mut p = Prng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = p.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle moved something");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut p = Prng::new(11);
        let s = p.sample_distinct(1000, 100);
        assert_eq!(s.len(), 100);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 100);
        assert!(s.iter().all(|&v| v < 1000));
    }

    #[test]
    fn exp_has_requested_mean() {
        let mut p = Prng::new(13);
        let mean: f64 = (0..20_000).map(|_| p.exp(5.0)).sum::<f64>() / 20_000.0;
        assert!((mean - 5.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn fork_produces_independent_streams() {
        let mut p = Prng::new(17);
        let mut c1 = p.fork(1);
        let mut c2 = p.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
