//! Minimal thread-pool substrate (no tokio on the offline image).
//!
//! The experiment harness fans independent simulations out across cores;
//! the server's request loop itself is a discrete-event simulation and
//! stays single-threaded by design (determinism).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("ssdup-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Default pool sized to available parallelism.
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("worker alive");
    }

    /// Map `f` over `inputs` in parallel, preserving order.
    pub fn map<I, O, F>(&self, inputs: Vec<I>, f: F) -> Vec<O>
    where
        I: Send + 'static,
        O: Send + 'static,
        F: Fn(I) -> O + Send + Sync + 'static,
    {
        let n = inputs.len();
        let f = Arc::new(f);
        let (otx, orx) = mpsc::channel::<(usize, O)>();
        for (i, input) in inputs.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let otx = otx.clone();
            self.execute(move || {
                let out = f(input);
                let _ = otx.send((i, out));
            });
        }
        drop(otx);
        let mut slots: Vec<Option<O>> = (0..n).map(|_| None).collect();
        for (i, out) in orx {
            slots[i] = Some(out);
        }
        slots.into_iter().map(|s| s.expect("all jobs completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `jobs` concurrently on scoped threads and return their results in
/// order. Unlike [`ThreadPool::map`], the closures may borrow from the
/// caller's stack (no `'static` bound) — the live load generator drives a
/// stack-owned engine with it. Panics propagate to the caller.
pub fn scoped_map<T, F>(jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    std::thread::scope(|scope| {
        let handles: Vec<_> = jobs.into_iter().map(|job| scope.spawn(job)).collect();
        handles.into_iter().map(|h| h.join().expect("scoped job panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64u64).collect(), |x| x * x);
        assert_eq!(out, (0..64u64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_on_empty_input() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scoped_map_borrows_from_stack() {
        let data: Vec<u64> = (0..32).collect();
        let jobs: Vec<_> = data
            .chunks(8)
            .map(|chunk| move || chunk.iter().sum::<u64>())
            .collect();
        let sums = scoped_map(jobs);
        assert_eq!(sums.len(), 4);
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }
}
