//! Per-node disk layout: maps (file, node-local offset) to an absolute
//! disk LBA. Files get well-separated base extents — writes to different
//! files land in different disk regions, which is what makes mixed loads
//! seek-heavy on HDD (paper Fig 3d/5d).

use std::collections::HashMap;

/// Sector spacing between file base extents: 64 Mi sectors = 32 GiB of
/// logical address space per file — larger than any evaluated file so
/// extents never collide, while keeping LBAs for tens of files within i32
/// (the detector kernels' offset dtype).
pub const DEFAULT_FILE_EXTENT_SECTORS: i64 = 64 * 1024 * 1024;

#[derive(Clone, Debug, Default)]
pub struct FileTable {
    base: HashMap<u32, i64>,
    next_slot: i64,
    extent: i64,
}

impl FileTable {
    pub fn new() -> Self {
        Self { base: HashMap::new(), next_slot: 0, extent: DEFAULT_FILE_EXTENT_SECTORS }
    }

    pub fn with_extent(extent: i64) -> Self {
        assert!(extent > 0);
        Self { base: HashMap::new(), next_slot: 0, extent }
    }

    /// Absolute LBA of `local_offset` within `file`, creating the file's
    /// extent on first touch.
    pub fn lba(&mut self, file: u32, local_offset: i32) -> i64 {
        let extent = self.extent;
        let next = &mut self.next_slot;
        let base = *self.base.entry(file).or_insert_with(|| {
            let b = *next * extent;
            *next += 1;
            b
        });
        base + local_offset as i64
    }

    pub fn files(&self) -> usize {
        self.base.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_file_is_contiguous() {
        let mut t = FileTable::new();
        let a = t.lba(1, 0);
        let b = t.lba(1, 100);
        assert_eq!(b - a, 100);
    }

    #[test]
    fn different_files_are_far_apart() {
        let mut t = FileTable::new();
        let a = t.lba(1, 0);
        let b = t.lba(2, 0);
        assert!((b - a).abs() >= DEFAULT_FILE_EXTENT_SECTORS);
        assert_eq!(t.files(), 2);
    }

    #[test]
    fn base_assignment_is_first_touch_stable() {
        let mut t = FileTable::new();
        let a1 = t.lba(9, 5);
        let a2 = t.lba(9, 5);
        assert_eq!(a1, a2);
    }

    #[test]
    fn custom_extent() {
        let mut t = FileTable::with_extent(1000);
        t.lba(1, 0);
        assert_eq!(t.lba(2, 0), 1000);
        assert_eq!(t.lba(3, 0), 2000);
    }
}
