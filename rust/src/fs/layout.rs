//! Per-node disk layout: maps (file, node-local offset) to an absolute
//! disk LBA. Files get well-separated base extents — writes to different
//! files land in different disk regions, which is what makes mixed loads
//! seek-heavy on HDD (paper Fig 3d/5d).

use std::collections::HashMap;

/// Sector spacing between file base extents: 64 Mi sectors = 32 GiB of
/// logical address space per file — larger than any evaluated file so
/// extents never collide, while keeping LBAs for tens of files within i32
/// (the detector kernels' offset dtype).
pub const DEFAULT_FILE_EXTENT_SECTORS: i64 = 64 * 1024 * 1024;

#[derive(Clone, Debug, Default)]
pub struct FileTable {
    base: HashMap<u32, i64>,
    next_slot: i64,
    extent: i64,
}

impl FileTable {
    pub fn new() -> Self {
        Self { base: HashMap::new(), next_slot: 0, extent: DEFAULT_FILE_EXTENT_SECTORS }
    }

    pub fn with_extent(extent: i64) -> Self {
        assert!(extent > 0);
        Self { base: HashMap::new(), next_slot: 0, extent }
    }

    /// Absolute LBA of `local_offset` within `file`, creating the file's
    /// extent on first touch.
    pub fn lba(&mut self, file: u32, local_offset: i32) -> i64 {
        self.lba_or_new(file, local_offset).0
    }

    /// Like [`FileTable::lba`], but also reports whether this call
    /// *created* the file's extent. The live shard persists the table to
    /// its superblock on first touch — the mapping decides where every
    /// byte of the file lives on disk, so it must survive a crash (a
    /// restarted table that re-dealt extents in a different first-touch
    /// order would read every file from the wrong place).
    pub fn lba_or_new(&mut self, file: u32, local_offset: i32) -> (i64, bool) {
        let extent = self.extent;
        let next = &mut self.next_slot;
        let mut created = false;
        let base = *self.base.entry(file).or_insert_with(|| {
            let b = *next * extent;
            *next += 1;
            created = true;
            b
        });
        (base + local_offset as i64, created)
    }

    /// Non-creating lookup: the absolute LBA of `local_offset` within
    /// `file`, or `None` if the file has no extent yet. Read paths use
    /// this — a read must never mint an extent, because minted entries
    /// are only persisted on *write* first-touch, and an entry that
    /// exists in memory but not in the superblock would let the file's
    /// first write skip persistence and be orphaned at recovery.
    pub fn lookup(&self, file: u32, local_offset: i32) -> Option<i64> {
        self.base.get(&file).map(|&b| b + local_offset as i64)
    }

    /// The table as `(file, extent slot)` pairs, ascending by file —
    /// what the live shard serializes into its superblock.
    pub fn entries(&self) -> Vec<(u32, u32)> {
        let mut out: Vec<(u32, u32)> =
            self.base.iter().map(|(&f, &b)| (f, (b / self.extent) as u32)).collect();
        out.sort_unstable();
        out
    }

    /// Crash recovery: re-seat one `(file, slot)` entry read back from a
    /// superblock. Keeps `next_slot` past every restored slot so new
    /// files never collide with recovered extents.
    pub fn restore_entry(&mut self, file: u32, slot: u32) {
        let prev = self.base.insert(file, slot as i64 * self.extent);
        debug_assert!(prev.is_none(), "file {file} restored twice");
        self.next_slot = self.next_slot.max(slot as i64 + 1);
    }

    /// Does `lba` fall inside some known file's extent? Recovery uses
    /// this to discard orphaned log records (a record whose file never
    /// reached a durable superblock was never acknowledged).
    pub fn owns_lba(&self, lba: i64) -> bool {
        self.base.values().any(|&b| (b..b + self.extent).contains(&lba))
    }

    pub fn files(&self) -> usize {
        self.base.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_file_is_contiguous() {
        let mut t = FileTable::new();
        let a = t.lba(1, 0);
        let b = t.lba(1, 100);
        assert_eq!(b - a, 100);
    }

    #[test]
    fn different_files_are_far_apart() {
        let mut t = FileTable::new();
        let a = t.lba(1, 0);
        let b = t.lba(2, 0);
        assert!((b - a).abs() >= DEFAULT_FILE_EXTENT_SECTORS);
        assert_eq!(t.files(), 2);
    }

    #[test]
    fn base_assignment_is_first_touch_stable() {
        let mut t = FileTable::new();
        let a1 = t.lba(9, 5);
        let a2 = t.lba(9, 5);
        assert_eq!(a1, a2);
    }

    #[test]
    fn entries_round_trip_through_restore() {
        let mut t = FileTable::with_extent(1000);
        t.lba(7, 0);
        t.lba(3, 5);
        t.lba(9, 1);
        let entries = t.entries();
        assert_eq!(entries, vec![(3, 1), (7, 0), (9, 2)], "ascending by file, slot by arrival");
        // a fresh table restored from those entries maps identically and
        // deals the next file past every recovered slot
        let mut r = FileTable::with_extent(1000);
        for (f, s) in entries {
            r.restore_entry(f, s);
        }
        assert_eq!(r.lba(7, 4), t.lba(7, 4));
        assert_eq!(r.lba(3, 0), t.lba(3, 0));
        let (new_base, created) = r.lba_or_new(42, 0);
        assert!(created);
        assert_eq!(new_base, 3000, "new files allocate past recovered slots");
        assert!(r.owns_lba(1500));
        assert!(!r.owns_lba(5000));
    }

    #[test]
    fn custom_extent() {
        let mut t = FileTable::with_extent(1000);
        t.lba(1, 0);
        assert_eq!(t.lba(2, 0), 1000);
        assert_eq!(t.lba(3, 0), 2000);
    }
}
