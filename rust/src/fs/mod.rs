//! OrangeFS-like parallel-file-system substrate.
//!
//! Files are striped round-robin across I/O nodes (OrangeFS default stripe
//! 64 KB); each node owns an HDD + SSD pair and runs its own SSDUP+
//! instance (the paper: "SSDUP+ resides in each I/O node... SSDUP+ in
//! different I/O nodes does not need to communicate with each other").

pub mod layout;
pub mod striping;

pub use layout::FileTable;
pub use striping::{StripeLayout, SubRequest};
