//! Round-robin file striping (OrangeFS semantics).

use crate::types::Request;

/// A request fragment routed to one I/O node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubRequest {
    pub node: usize,
    /// node-local file offset in sectors (dense per-node address space)
    pub local_offset: i32,
    pub size: i32,
    pub parent: Request,
}

impl SubRequest {
    pub fn bytes(&self) -> u64 {
        crate::types::sectors_to_bytes(self.size as i64)
    }
}

/// Stripe layout: `stripe_sectors`-sized stripes dealt round-robin over
/// `n_nodes` I/O nodes.
#[derive(Clone, Copy, Debug)]
pub struct StripeLayout {
    pub stripe_sectors: i32,
    pub n_nodes: usize,
}

impl Default for StripeLayout {
    fn default() -> Self {
        // OrangeFS default strip size 64 KB = 128 sectors; the paper's
        // testbed has 2 I/O nodes.
        Self { stripe_sectors: 128, n_nodes: 2 }
    }
}

impl StripeLayout {
    /// Split a logical request into per-node sub-requests. Like OrangeFS
    /// list-I/O, the portions of one request that land on the same node
    /// and are contiguous in its local address space are coalesced into a
    /// single server I/O — a 256 KB request over 64 KB stripes on 2 nodes
    /// yields exactly one 128 KB sub-request per node (the Table-1 note:
    /// requests above the stripe size stripe across both servers).
    pub fn split(&self, req: Request) -> Vec<SubRequest> {
        assert!(req.size > 0, "empty request");
        let mut out: Vec<SubRequest> = Vec::new();
        let mut off = req.offset;
        let mut remaining = req.size;
        while remaining > 0 {
            let stripe_idx = off / self.stripe_sectors;
            let within = off % self.stripe_sectors;
            let take = (self.stripe_sectors - within).min(remaining);
            let node = (stripe_idx as usize) % self.n_nodes;
            // node-local dense offset: which of *this node's* stripes,
            // times stripe size, plus the intra-stripe offset
            let local_stripe = stripe_idx / self.n_nodes as i32;
            let local_offset = local_stripe * self.stripe_sectors + within;
            // coalesce with this node's previous fragment if contiguous
            if let Some(prev) = out.iter_mut().rev().find(|s| s.node == node) {
                if prev.local_offset + prev.size == local_offset {
                    prev.size += take;
                    off += take;
                    remaining -= take;
                    continue;
                }
            }
            out.push(SubRequest { node, local_offset, size: take, parent: req });
            off += take;
            remaining -= take;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(offset: i32, size: i32) -> Request {
        Request { app: 0, proc_id: 0, file: 7, offset, size }
    }

    #[test]
    fn small_request_stays_on_one_node() {
        let l = StripeLayout { stripe_sectors: 128, n_nodes: 2 };
        let subs = l.split(req(0, 64));
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].node, 0);
        assert_eq!(subs[0].local_offset, 0);
        assert_eq!(subs[0].size, 64);
    }

    #[test]
    fn request_spanning_stripes_coalesces_per_node() {
        let l = StripeLayout { stripe_sectors: 128, n_nodes: 2 };
        // 256 KB request = 512 sectors = 4 stripes -> one coalesced
        // 128 KB sub-request per node (list-I/O semantics)
        let subs = l.split(req(0, 512));
        assert_eq!(subs.len(), 2);
        assert_eq!(subs.iter().map(|s| s.node).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(subs.iter().map(|s| s.local_offset).collect::<Vec<_>>(), vec![0, 0]);
        assert!(subs.iter().all(|s| s.size == 256));
    }

    #[test]
    fn unaligned_offset_takes_stripe_remainder() {
        let l = StripeLayout { stripe_sectors: 128, n_nodes: 2 };
        let subs = l.split(req(100, 100));
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0], SubRequest { node: 0, local_offset: 100, size: 28, parent: req(100, 100) });
        assert_eq!(subs[1].node, 1);
        assert_eq!(subs[1].local_offset, 0);
        assert_eq!(subs[1].size, 72);
    }

    #[test]
    fn sizes_conserved() {
        let l = StripeLayout { stripe_sectors: 128, n_nodes: 3 };
        for (off, size) in [(0, 1), (5, 1000), (127, 2), (128, 128), (1000, 4096)] {
            let subs = l.split(req(off, size));
            assert_eq!(subs.iter().map(|s| s.size).sum::<i32>(), size, "off={off} size={size}");
            assert!(subs.iter().all(|s| s.size > 0));
        }
    }

    #[test]
    fn contiguous_logical_maps_to_contiguous_local() {
        // sequential writes to one file must stay sequential per node —
        // the property that keeps segmented-contiguous cheap on HDD
        let l = StripeLayout { stripe_sectors: 128, n_nodes: 2 };
        let mut per_node: Vec<Vec<i32>> = vec![vec![]; 2];
        for i in 0..32 {
            for s in l.split(req(i * 128, 128)) {
                per_node[s.node].push(s.local_offset);
            }
        }
        for node in &per_node {
            assert!(node.windows(2).all(|w| w[1] == w[0] + 128), "{node:?}");
        }
    }
}
