//! Zero-dependency observability for the live engine: where does a p99
//! write spend its time, and what is the flusher doing *right now*?
//!
//! Three cooperating pieces, all built on the standard library only:
//!
//! * [`trace`] — a lock-free per-thread trace collector. Instrumented
//!   code emits compact timestamped spans into fixed-capacity SPSC
//!   rings; overflow drops events (counted, never blocking) and a
//!   *disabled* collector costs one atomic load per span. Drained
//!   events export as Chrome `chrome://tracing` JSON
//!   (`ssdup live --trace out.json`).
//! * [`stages`] — the pipeline-stage taxonomy ([`Stage`]) and per-stage
//!   latency attribution ([`StageSet`]): every acknowledged write's
//!   route/reserve/device/barrier/publish spans fold into per-shard
//!   [`crate::server::metrics::LatencyHistogram`]s, so a run can print
//!   a p50/p95/p99 *decomposition* of ack latency and name the dominant
//!   stage.
//! * [`snapshot`] — the interval reporter: counter snapshots diffed on
//!   a cadence (`ssdup live --stats-interval MS`) into machine-readable
//!   JSON lines — throughput, writes-per-sync, blocked waits, flusher
//!   duty cycle, SSD occupancy.
//!
//! Stage attribution (a few `Instant::now()` reads and one leaf-mutex
//! histogram fold per operation) is always on; trace *event emission* is
//! what the enabled flag gates. See the "Observability" section in
//! [`crate::live`] for the stage taxonomy and the overhead contract.

pub mod snapshot;
pub mod stages;
pub mod trace;

pub use snapshot::{Counters, Snapshotter};
pub use stages::{Stage, StageSet, N_STAGES};
pub use trace::{chrome_trace_json, TraceCollector, TraceEvent, DEFAULT_RING_EVENTS};
