//! Per-stage latency attribution: the fixed pipeline-stage taxonomy and
//! a bundle of per-stage [`LatencyHistogram`]s.
//!
//! Every acknowledged write is decomposed into adjacent, non-overlapping
//! stage spans (route → reserve → device write → barrier wait → publish)
//! whose durations are folded into a per-shard [`StageSet`]. Because the
//! spans share their boundary timestamps, the per-stage sums add up to
//! the total submit latency (up to one microsecond of truncation per
//! stage), which is what lets `LiveReport` print a p50/p95/p99
//! *decomposition* of ack latency and name the dominant stage.
//!
//! The same taxonomy labels the trace events emitted by
//! [`crate::obs::trace`], so a Chrome-trace timeline and the histogram
//! decomposition always speak the same language.

use crate::server::metrics::LatencyHistogram;

/// One pipeline stage of the live engine. The discriminant doubles as
/// the index into [`StageSet`] and the compact stage id carried by trace
/// events.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum Stage {
    /// Whole `Shard::submit` call: entry to acknowledged (published).
    Submit = 0,
    /// Stream grouping + route decision, *including* any valve/absorb
    /// waits that force another routing pass — time spent blocked on
    /// backpressure is routing time, not device time.
    Route = 1,
    /// Slot + ownership claim under the core lock (reserve phase).
    Reserve = 2,
    /// Unlocked SSD log append (header + payload sectors).
    SsdWrite = 3,
    /// Unlocked direct HDD write.
    HddWrite = 4,
    /// Group-commit barrier: from barrier entry until a covering device
    /// sync completed (shared-leader wait included).
    BarrierWait = 5,
    /// Publish critical section: re-acquire the core lock, mark the
    /// claim durable, wake waiters.
    Publish = 6,
    /// Read resolve/pin critical section (waits for in-flight overlaps).
    ReadResolve = 7,
    /// Unlocked read segment transfers (SSD and HDD tiers).
    ReadDevice = 8,
    /// One coalesced flusher copy run: SSD read + HDD write of a run.
    FlushRun = 9,
    /// Traffic-aware flush gate pause (§2.4.2): random traffic present,
    /// directs in flight, flusher held off the HDD.
    FlushPause = 10,
    /// Superblock slot write + covering barrier.
    SbWrite = 11,
    /// Recovery: superblock read + region scan + record replay.
    Replay = 12,
    /// Build + enqueue of the request batch onto the shard's `IoQueue`
    /// (includes any wait for a free depth slot under backpressure).
    IoSubmit = 13,
    /// Submission-queue residency: from enqueued until an I/O worker
    /// started the batch's first device write.
    QueueWait = 14,
    /// Backoff sleeps spent re-driving transient device faults below the
    /// completion token (sum per acknowledged batch). Overlaps
    /// `SsdWrite`/`HddWrite` rather than partitioning `Submit`, so it is
    /// *not* an ack component — it attributes how much of the device
    /// stage was fault recovery.
    FaultRetry = 15,
    /// Wait for an HDD-bandwidth token from the global flush coordinator
    /// before a flush cycle's copy runs start. Booked on *every*
    /// acquisition (zero-length when uncontended) so coordinated runs
    /// always trace the stage; a flusher-side span like `FlushRun`, not
    /// an ack component.
    FlushTokenWait = 16,
}

/// Number of stages (length of [`Stage::ALL`]).
pub const N_STAGES: usize = 17;

impl Stage {
    /// Every stage, in discriminant order.
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Submit,
        Stage::Route,
        Stage::Reserve,
        Stage::SsdWrite,
        Stage::HddWrite,
        Stage::BarrierWait,
        Stage::Publish,
        Stage::ReadResolve,
        Stage::ReadDevice,
        Stage::FlushRun,
        Stage::FlushPause,
        Stage::SbWrite,
        Stage::Replay,
        Stage::IoSubmit,
        Stage::QueueWait,
        Stage::FaultRetry,
        Stage::FlushTokenWait,
    ];

    /// The additive components of an acknowledged write: these spans are
    /// adjacent and partition a `Submit` span, so their sums reconcile
    /// with the `Submit` total.
    pub const ACK_COMPONENTS: [Stage; 7] = [
        Stage::Route,
        Stage::Reserve,
        Stage::IoSubmit,
        Stage::QueueWait,
        Stage::SsdWrite,
        Stage::BarrierWait,
        Stage::Publish,
    ];

    /// Stable snake_case name (trace event `name`, JSON keys, CLI).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::Route => "route",
            Stage::Reserve => "reserve",
            Stage::SsdWrite => "ssd_write",
            Stage::HddWrite => "hdd_write",
            Stage::BarrierWait => "barrier_wait",
            Stage::Publish => "publish",
            Stage::ReadResolve => "read_resolve",
            Stage::ReadDevice => "read_device",
            Stage::FlushRun => "flush_run",
            Stage::FlushPause => "flush_pause",
            Stage::SbWrite => "sb_write",
            Stage::Replay => "replay",
            Stage::IoSubmit => "io_submit",
            Stage::QueueWait => "queue_wait",
            Stage::FaultRetry => "fault_retry",
            Stage::FlushTokenWait => "flush_token_wait",
        }
    }

    /// Inverse of [`Stage::name`].
    pub fn from_name(name: &str) -> Option<Stage> {
        Stage::ALL.into_iter().find(|s| s.name() == name)
    }
}

/// One latency histogram per pipeline stage — a shard's (or a whole
/// run's, after merging) ack-latency decomposition.
#[derive(Clone, Debug)]
pub struct StageSet {
    hists: [LatencyHistogram; N_STAGES],
}

impl Default for StageSet {
    fn default() -> Self {
        Self::new()
    }
}

impl StageSet {
    pub fn new() -> Self {
        Self { hists: std::array::from_fn(|_| LatencyHistogram::new()) }
    }

    #[inline]
    pub fn record(&mut self, stage: Stage, us: u64) {
        self.hists[stage as usize].record(us);
    }

    pub fn get(&self, stage: Stage) -> &LatencyHistogram {
        &self.hists[stage as usize]
    }

    /// Fold another set in (per-shard sets -> run total).
    pub fn merge(&mut self, other: &StageSet) {
        for (a, b) in self.hists.iter_mut().zip(&other.hists) {
            a.merge(b);
        }
    }

    /// Total recorded time across the additive ack components — the
    /// reconstruction of total ack latency from its parts.
    pub fn ack_component_sum_us(&self) -> u64 {
        let mut total = 0u64;
        for s in Stage::ACK_COMPONENTS {
            total += self.get(s).sum_us();
        }
        total += self.get(Stage::HddWrite).sum_us(); // alternative of SsdWrite
        total
    }

    /// The ack component where acknowledged writes spent the most total
    /// time. `None` until a write has been recorded.
    pub fn dominant_ack_stage(&self) -> Option<Stage> {
        let mut best: Option<(Stage, u64)> = None;
        for s in Stage::ACK_COMPONENTS.into_iter().chain([Stage::HddWrite]) {
            let sum = self.get(s).sum_us();
            if sum > 0 && best.map(|(_, b)| sum > b).unwrap_or(true) {
                best = Some((s, sum));
            }
        }
        best.map(|(s, _)| s)
    }

    /// Multi-line p50/p95/p99 decomposition table for every stage that
    /// recorded at least one span, dominant ack stage named at the end.
    pub fn summary(&self) -> String {
        let mut out = String::from(
            "stage           count      p50us      p95us      p99us     mean_us\n",
        );
        for s in Stage::ALL {
            let h = self.get(s);
            if h.count() == 0 {
                continue;
            }
            out.push_str(&format!(
                "{:<14} {:>6} {:>10} {:>10} {:>10} {:>11.1}\n",
                s.name(),
                h.count(),
                h.p50(),
                h.p95(),
                h.p99(),
                h.mean_us(),
            ));
        }
        match self.dominant_ack_stage() {
            Some(s) => out.push_str(&format!("dominant ack stage: {}\n", s.name())),
            None => out.push_str("dominant ack stage: none (no writes recorded)\n"),
        }
        out
    }

    /// Machine-readable form for `BENCH_live.json`:
    /// `{stage: {count, p50_us, p95_us, p99_us, mean_us, sum_us}}`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut stages = std::collections::BTreeMap::new();
        for s in Stage::ALL {
            let h = self.get(s);
            if h.count() == 0 {
                continue;
            }
            stages.insert(
                s.name().to_string(),
                Json::Obj(std::collections::BTreeMap::from([
                    ("count".to_string(), Json::Num(h.count() as f64)),
                    ("p50_us".to_string(), Json::Num(h.p50() as f64)),
                    ("p95_us".to_string(), Json::Num(h.p95() as f64)),
                    ("p99_us".to_string(), Json::Num(h.p99() as f64)),
                    ("mean_us".to_string(), Json::Num(h.mean_us())),
                    ("sum_us".to_string(), Json::Num(h.sum_us() as f64)),
                ])),
            );
        }
        Json::Obj(stages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_round_trip() {
        for s in Stage::ALL {
            assert_eq!(Stage::from_name(s.name()), Some(s), "{}", s.name());
        }
        assert_eq!(Stage::from_name("bogus"), None);
        // discriminants are the ALL indices (trace events rely on this)
        for (i, s) in Stage::ALL.into_iter().enumerate() {
            assert_eq!(s as usize, i);
        }
    }

    #[test]
    fn record_merge_and_dominant() {
        let mut a = StageSet::new();
        a.record(Stage::Route, 5);
        a.record(Stage::SsdWrite, 100);
        a.record(Stage::Submit, 110);
        let mut b = StageSet::new();
        b.record(Stage::SsdWrite, 300);
        a.merge(&b);
        assert_eq!(a.get(Stage::SsdWrite).count(), 2);
        assert_eq!(a.get(Stage::SsdWrite).sum_us(), 400);
        assert_eq!(a.dominant_ack_stage(), Some(Stage::SsdWrite));
        let s = a.summary();
        assert!(s.contains("ssd_write"), "{s}");
        assert!(s.contains("dominant ack stage: ssd_write"), "{s}");
        assert!(!s.contains("hdd_write"), "empty stages are omitted: {s}");
    }

    #[test]
    fn empty_set_is_quiet() {
        let s = StageSet::new();
        assert_eq!(s.dominant_ack_stage(), None);
        assert_eq!(s.ack_component_sum_us(), 0);
        assert!(s.summary().contains("none"));
        assert_eq!(s.to_json().to_string(), "{}");
    }
}
