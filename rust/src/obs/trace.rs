//! Lock-free per-thread trace collector with Chrome-trace export.
//!
//! Every instrumented thread owns a fixed-capacity single-producer /
//! single-consumer ring of compact [`TraceEvent`]s; the collector drains
//! all rings on demand. The hot path never blocks and never allocates:
//! a full ring **drops** the event and bumps a shared `dropped_events`
//! counter, and a *disabled* collector costs exactly one atomic load per
//! span ([`TraceCollector::is_enabled`]).
//!
//! Rings are registered lazily the first time a thread emits into a
//! given collector; a thread-local cache maps collector id → ring so the
//! steady-state emit path is: atomic enabled check, TLS lookup, one slot
//! write, one `Release` store.
//!
//! Export is the Chrome `chrome://tracing` / Perfetto JSON event format:
//! complete (`"ph":"X"`) events with microsecond timestamps relative to
//! the collector's epoch, `pid` = shard id, `tid` = ring (thread) id.

use std::cell::{RefCell, UnsafeCell};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::stages::Stage;
use crate::util::json::Json;

/// Default per-thread ring capacity, in events (~16K events ≈ 0.5 MiB
/// per instrumented thread).
pub const DEFAULT_RING_EVENTS: usize = 16 * 1024;

/// One completed span. Compact and `Copy` so ring slots are plain moves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub stage: Stage,
    /// Shard the span ran on (Chrome trace `pid`).
    pub shard: u32,
    /// Ring (thread) id within the collector (Chrome trace `tid`).
    pub tid: u32,
    /// Span start, microseconds since the collector's epoch.
    pub start_us: u64,
    pub dur_us: u64,
}

impl Default for TraceEvent {
    fn default() -> Self {
        Self { stage: Stage::Submit, shard: 0, tid: 0, start_us: 0, dur_us: 0 }
    }
}

/// Fixed-capacity SPSC event ring. Producer = the owning thread (via the
/// thread-local cache), consumer = whoever holds the collector's
/// registry lock in [`TraceCollector::drain`].
struct Ring {
    slots: Box<[UnsafeCell<TraceEvent>]>,
    /// Next slot the producer writes (monotone; slot = head % capacity).
    head: AtomicU64,
    /// Next slot the consumer reads (monotone).
    tail: AtomicU64,
    tid: u32,
}

// SAFETY: the ring is SPSC by construction. The single producer (the
// thread that registered the ring — rings are reached only through the
// thread-local cache) writes a slot *before* publishing it with a
// `Release` store of `head`; the single consumer (serialized by the
// registry mutex in `drain`) `Acquire`-loads `head`, so it observes
// fully written slots, and frees them with a `Release` store of `tail`
// which the producer `Acquire`-loads before reusing a slot. Producer and
// consumer never touch the same slot concurrently: the producer writes
// only slots in `[head, tail + capacity)`, the consumer reads only
// `[tail, head)`.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    fn new(capacity: usize, tid: u32) -> Self {
        let slots: Vec<UnsafeCell<TraceEvent>> =
            (0..capacity.max(1)).map(|_| UnsafeCell::new(TraceEvent::default())).collect();
        Self {
            slots: slots.into_boxed_slice(),
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            tid,
        }
    }

    /// Producer side: push or drop. Returns false when the ring was full
    /// (the caller counts the drop); never blocks.
    fn push(&self, ev: TraceEvent) -> bool {
        // Relaxed head: this thread is the only producer, so it reads
        // its own last store. Acquire tail: pairs with the consumer's
        // Release in `drain_into` — a freed slot was fully copied out.
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head - tail >= self.slots.len() as u64 {
            return false;
        }
        let idx = (head % self.slots.len() as u64) as usize;
        // SAFETY: slot `idx` is unpublished (>= previous head, < tail +
        // capacity), so the consumer will not read it until the Release
        // store below, and no other producer exists.
        unsafe { *self.slots[idx].get() = ev };
        self.head.store(head + 1, Ordering::Release);
        true
    }

    /// Consumer side: copy out everything published since the last drain.
    fn drain_into(&self, out: &mut Vec<TraceEvent>) {
        // Acquire head: pairs with the producer's Release publish, so
        // every slot below it holds a complete event. Relaxed tail: the
        // single consumer reads its own last store.
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        while tail < head {
            let idx = (tail % self.slots.len() as u64) as usize;
            // SAFETY: `tail < head` means the slot was published by a
            // Release store the Acquire load above synchronized with,
            // and the producer will not reuse it until `tail` advances.
            out.push(unsafe { *self.slots[idx].get() });
            tail += 1;
        }
        // Release: hands the drained slots back to the producer — its
        // Acquire tail load must see our copies as complete
        self.tail.store(tail, Ordering::Release);
    }
}

/// Collector ids are process-global so a thread can cache rings for any
/// number of live collectors (one per engine, plus tests).
static NEXT_COLLECTOR_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (collector id, this thread's ring in that collector).
    static TLS_RINGS: RefCell<Vec<(u64, Arc<Ring>)>> = const { RefCell::new(Vec::new()) };
}

/// The per-engine trace collector: an enabled flag, an epoch, and the
/// registry of per-thread rings.
pub struct TraceCollector {
    id: u64,
    enabled: AtomicBool,
    epoch: Instant,
    ring_events: usize,
    dropped: AtomicU64,
    rings: Mutex<Vec<Arc<Ring>>>,
}

impl Default for TraceCollector {
    fn default() -> Self {
        Self::new(DEFAULT_RING_EVENTS)
    }
}

impl TraceCollector {
    /// A collector that starts *disabled*: spans cost one atomic load
    /// until [`TraceCollector::set_enabled`] turns them on.
    pub fn new(ring_events: usize) -> Self {
        Self {
            // Relaxed: unique-id allocation needs atomicity, not ordering
            id: NEXT_COLLECTOR_ID.fetch_add(1, Ordering::Relaxed),
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            ring_events,
            dropped: AtomicU64::new(0),
            rings: Mutex::new(Vec::new()),
        }
    }

    pub fn set_enabled(&self, on: bool) {
        // Relaxed: advisory flag — a span booked around the flip may be
        // kept or skipped either way, which is fine for tracing
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The one branch a disabled collector costs on the hot path.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        // Relaxed: advisory flag read (see set_enabled)
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record a completed span. No-op (one atomic load) when disabled.
    #[inline]
    pub fn emit(&self, stage: Stage, shard: u32, start: Instant, end: Instant) {
        if !self.is_enabled() {
            return;
        }
        self.emit_always(stage, shard, start, end);
    }

    fn emit_always(&self, stage: Stage, shard: u32, start: Instant, end: Instant) {
        let ev = TraceEvent {
            stage,
            shard,
            tid: 0, // stamped with the ring id below
            start_us: start.duration_since(self.epoch).as_micros() as u64,
            dur_us: end.duration_since(start).as_micros() as u64,
        };
        TLS_RINGS.with(|cell| {
            let mut cached = cell.borrow_mut();
            let ring = match cached.iter().find(|(id, _)| *id == self.id) {
                Some((_, ring)) => Arc::clone(ring),
                None => {
                    let ring = self.register_ring();
                    cached.push((self.id, Arc::clone(&ring)));
                    // collectors come and go (one per engine); drop cache
                    // entries whose collector can no longer be reached
                    cached.retain(|(_, r)| Arc::strong_count(r) > 1);
                    ring
                }
            };
            if !ring.push(TraceEvent { tid: ring.tid, ..ev }) {
                // Relaxed: overflow tally, surfaced once per snapshot
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        });
    }

    fn register_ring(&self) -> Arc<Ring> {
        let mut rings = self.rings.lock().unwrap();
        let ring = Arc::new(Ring::new(self.ring_events, rings.len() as u32));
        rings.push(Arc::clone(&ring));
        ring
    }

    /// Events dropped to ring overflow since construction.
    pub fn dropped_events(&self) -> u64 {
        // Relaxed: stats read, no synchronization implied
        self.dropped.load(Ordering::Relaxed)
    }

    /// Drain every ring, returning all buffered events ordered by start
    /// time. Concurrent emitters keep running — they only ever touch the
    /// producer end of their own ring.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let rings = self.rings.lock().unwrap();
        let mut out = Vec::new();
        for ring in rings.iter() {
            ring.drain_into(&mut out);
        }
        out.sort_by_key(|e| (e.start_us, e.tid, e.stage as u8));
        out
    }
}

/// Render drained events as a Chrome `chrome://tracing` document
/// (`traceEvents` array of complete `"X"` events; `dropped_events` noted
/// in `otherData`).
pub fn chrome_trace_json(events: &[TraceEvent], dropped_events: u64) -> Json {
    let evs = events
        .iter()
        .map(|e| {
            Json::Obj(BTreeMap::from([
                ("name".to_string(), Json::Str(e.stage.name().to_string())),
                ("cat".to_string(), Json::Str("ssdup".to_string())),
                ("ph".to_string(), Json::Str("X".to_string())),
                ("ts".to_string(), Json::Num(e.start_us as f64)),
                ("dur".to_string(), Json::Num(e.dur_us as f64)),
                ("pid".to_string(), Json::Num(e.shard as f64)),
                ("tid".to_string(), Json::Num(e.tid as f64)),
            ]))
        })
        .collect();
    Json::Obj(BTreeMap::from([
        ("traceEvents".to_string(), Json::Arr(evs)),
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
        (
            "otherData".to_string(),
            Json::Obj(BTreeMap::from([(
                "dropped_events".to_string(),
                Json::Num(dropped_events as f64),
            )])),
        ),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn span(c: &TraceCollector, stage: Stage, shard: u32, start_us: u64, dur_us: u64) {
        let start = c.epoch + Duration::from_micros(start_us);
        c.emit(stage, shard, start, start + Duration::from_micros(dur_us));
    }

    #[test]
    fn disabled_collector_emits_nothing() {
        let c = TraceCollector::new(8);
        span(&c, Stage::Submit, 0, 10, 5);
        assert!(c.drain().is_empty());
        assert_eq!(c.dropped_events(), 0);
    }

    #[test]
    fn events_round_trip_in_order() {
        let c = TraceCollector::new(64);
        c.set_enabled(true);
        span(&c, Stage::Route, 3, 20, 2);
        span(&c, Stage::Submit, 3, 10, 15);
        let evs = c.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].stage, Stage::Submit, "sorted by start_us");
        assert_eq!(evs[0].start_us, 10);
        assert_eq!(evs[0].dur_us, 15);
        assert_eq!(evs[0].shard, 3);
        assert_eq!(evs[1].stage, Stage::Route);
        // second drain is empty (events consumed once)
        assert!(c.drain().is_empty());
        // and the ring keeps accepting afterwards
        span(&c, Stage::Publish, 1, 40, 1);
        assert_eq!(c.drain().len(), 1);
    }

    #[test]
    fn overflow_drops_instead_of_blocking() {
        let c = TraceCollector::new(4);
        c.set_enabled(true);
        for i in 0..10 {
            span(&c, Stage::Submit, 0, i, 1);
        }
        assert_eq!(c.drain().len(), 4, "ring capacity bounds buffered events");
        assert_eq!(c.dropped_events(), 6);
        // drained slots are reusable
        span(&c, Stage::Submit, 0, 99, 1);
        assert_eq!(c.drain().len(), 1);
        assert_eq!(c.dropped_events(), 6);
    }

    #[test]
    fn threads_get_their_own_rings() {
        let c = Arc::new(TraceCollector::new(1024));
        c.set_enabled(true);
        std::thread::scope(|s| {
            for t in 0..4 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for i in 0..100 {
                        span(&c, Stage::SsdWrite, t, i, 1);
                    }
                });
            }
        });
        let evs = c.drain();
        assert_eq!(evs.len(), 400);
        let tids: std::collections::BTreeSet<u32> = evs.iter().map(|e| e.tid).collect();
        assert_eq!(tids.len(), 4, "one ring per emitting thread: {tids:?}");
        assert_eq!(c.dropped_events(), 0);
    }

    #[test]
    fn chrome_export_is_valid_json() {
        let c = TraceCollector::new(16);
        c.set_enabled(true);
        span(&c, Stage::FlushRun, 2, 100, 50);
        let doc = chrome_trace_json(&c.drain(), 7);
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("chrome trace must re-parse");
        let evs = parsed.get("traceEvents").and_then(|j| j.as_arr()).expect("traceEvents");
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("name").and_then(|j| j.as_str()), Some("flush_run"));
        assert_eq!(evs[0].get("ph").and_then(|j| j.as_str()), Some("X"));
        assert_eq!(evs[0].get("ts").and_then(|j| j.as_f64()), Some(100.0));
        assert_eq!(evs[0].get("dur").and_then(|j| j.as_f64()), Some(50.0));
        assert_eq!(evs[0].get("pid").and_then(|j| j.as_f64()), Some(2.0));
        assert_eq!(
            parsed.get("otherData").and_then(|j| j.get("dropped_events")).and_then(|j| j.as_f64()),
            Some(7.0)
        );
    }
}
