//! Periodic snapshot reporter: interval-diffed counter snapshots emitted
//! as machine-readable JSON lines (one object per line on stderr via
//! `ssdup live --stats-interval MS`) — the live telemetry feed a future
//! autotuner consumes instead of end-of-run totals.
//!
//! The diff logic is pure (counters in, JSON out) so it is unit-testable
//! without an engine; `loadgen` drives it from a sampler thread that
//! snapshots `ShardStats` on an interval. All derived rates guard the
//! zero denominator and report 0.0 rather than NaN/inf.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::live::shard::ShardStats;
use crate::util::json::Json;

/// The counter totals one interval tick sees (summed over shards).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    pub bytes_in: u64,
    pub ssd_bytes_buffered: u64,
    pub flushed_bytes: u64,
    pub superseded_bytes: u64,
    pub blocked_waits: u64,
    pub flushes: u64,
    pub flush_runs: u64,
    pub flush_pauses: u64,
    pub flush_pause_us: u64,
    pub flush_run_us: u64,
    pub syncs: u64,
    pub sync_barriers: u64,
    pub dropped_trace_events: u64,
    pub io_retries: u64,
    pub transient_faults: u64,
    pub degraded_shards: u64,
    pub queued_for_flush_bytes: u64,
    pub superseded_at_flush_bytes: u64,
    pub hot_defers: u64,
    pub hdd_direct_bytes: u64,
    pub rerouted_writes: u64,
    pub streams: u64,
    pub biased_streams: u64,
    pub io_reqs: u64,
    pub io_device_writes: u64,
    pub flush_token_waits: u64,
    pub flush_token_wait_us: u64,
    /// gauge, not a counter: shards holding a flush token right now.
    /// `from_stats` cannot see the coordinator, so the sampler fills
    /// this in (stays 0 when uncoordinated)
    pub flush_token_holders: u64,
}

impl Counters {
    /// Collapse per-shard stats into one snapshot.
    pub fn from_stats(stats: &[ShardStats], dropped_trace_events: u64) -> Self {
        let mut c = Counters { dropped_trace_events, ..Default::default() };
        for s in stats {
            c.bytes_in += s.bytes_in;
            c.ssd_bytes_buffered += s.ssd_bytes_buffered;
            c.flushed_bytes += s.flushed_bytes;
            c.superseded_bytes += s.superseded_bytes;
            c.blocked_waits += s.blocked_waits;
            c.flushes += s.flushes;
            c.flush_runs += s.flush_runs;
            c.flush_pauses += s.flush_pauses;
            c.flush_pause_us += s.flush_pause_us;
            c.flush_run_us += s.flush_run_us;
            c.syncs += s.syncs;
            c.sync_barriers += s.sync_barriers;
            c.io_retries += s.io_retries;
            c.transient_faults += s.transient_faults;
            c.degraded_shards += s.degraded as u64;
            c.queued_for_flush_bytes += s.queued_for_flush_bytes;
            c.superseded_at_flush_bytes += s.superseded_at_flush_bytes;
            c.hot_defers += s.hot_defers;
            c.hdd_direct_bytes += s.hdd_direct_bytes;
            c.rerouted_writes += s.rerouted_writes;
            c.streams += s.streams;
            c.biased_streams += s.biased_streams;
            c.io_reqs += s.io_reqs;
            c.io_device_writes += s.io_device_writes;
            c.flush_token_waits += s.flush_token_waits;
            c.flush_token_wait_us += s.flush_token_wait_us;
        }
        c
    }

    /// Bytes currently resident in the SSD logs (buffered minus what the
    /// flusher settled or superseded away).
    pub fn ssd_occupancy_bytes(&self) -> u64 {
        self.ssd_bytes_buffered.saturating_sub(self.flushed_bytes + self.superseded_bytes)
    }
}

/// Interval differ: keeps the previous tick's counters and turns each
/// new snapshot into one JSON line of deltas and rates.
#[derive(Clone, Debug, Default)]
pub struct Snapshotter {
    prev: Counters,
    elapsed: Duration,
    seq: u64,
}

fn ratio(num: f64, den: f64) -> f64 {
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

impl Snapshotter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold in one tick: `cur` is the running total, `since_start` the
    /// wall clock since the run began. Returns the JSON-line object for
    /// the interval since the previous tick.
    pub fn tick(&mut self, cur: Counters, since_start: Duration) -> Json {
        let interval = since_start.saturating_sub(self.elapsed);
        let interval_s = interval.as_secs_f64();
        let d = |cur_v: u64, prev_v: u64| cur_v.saturating_sub(prev_v);
        let bytes = d(cur.bytes_in, self.prev.bytes_in);
        let barriers = d(cur.sync_barriers, self.prev.sync_barriers);
        let syncs = d(cur.syncs, self.prev.syncs);
        let obj = BTreeMap::from([
            ("seq".to_string(), Json::Num(self.seq as f64)),
            ("t_s".to_string(), Json::Num(since_start.as_secs_f64())),
            ("interval_s".to_string(), Json::Num(interval_s)),
            // throughput over the interval, MB/s (1e6 bytes per second)
            ("mbps".to_string(), Json::Num(ratio(bytes as f64 / 1e6, interval_s))),
            ("bytes_in".to_string(), Json::Num(cur.bytes_in as f64)),
            (
                "writes_per_sync".to_string(),
                Json::Num(ratio(barriers as f64, syncs as f64)),
            ),
            ("blocked_waits".to_string(), Json::Num(d(cur.blocked_waits, self.prev.blocked_waits) as f64)),
            ("flushes".to_string(), Json::Num(d(cur.flushes, self.prev.flushes) as f64)),
            ("flush_runs".to_string(), Json::Num(d(cur.flush_runs, self.prev.flush_runs) as f64)),
            ("flush_pauses".to_string(), Json::Num(d(cur.flush_pauses, self.prev.flush_pauses) as f64)),
            (
                "flush_run_ms".to_string(),
                Json::Num(d(cur.flush_run_us, self.prev.flush_run_us) as f64 / 1e3),
            ),
            (
                "flush_pause_ms".to_string(),
                Json::Num(d(cur.flush_pause_us, self.prev.flush_pause_us) as f64 / 1e3),
            ),
            ("ssd_occupancy_bytes".to_string(), Json::Num(cur.ssd_occupancy_bytes() as f64)),
            (
                "dropped_trace_events".to_string(),
                Json::Num(cur.dropped_trace_events as f64),
            ),
            (
                "io_retries".to_string(),
                Json::Num(d(cur.io_retries, self.prev.io_retries) as f64),
            ),
            (
                "transient_faults".to_string(),
                Json::Num(d(cur.transient_faults, self.prev.transient_faults) as f64),
            ),
            ("degraded_shards".to_string(), Json::Num(cur.degraded_shards as f64)),
            // flush-amplification saved this interval: of the bytes that
            // were queued for flushing, how many a rewrite superseded in
            // the buffer before the copy ran
            (
                "superseded_at_flush".to_string(),
                Json::Num(ratio(
                    d(cur.superseded_at_flush_bytes, self.prev.superseded_at_flush_bytes) as f64,
                    d(cur.queued_for_flush_bytes, self.prev.queued_for_flush_bytes) as f64,
                )),
            ),
            ("hot_defers".to_string(), Json::Num(d(cur.hot_defers, self.prev.hot_defers) as f64)),
            // route split this interval: bytes that bypassed the SSD
            // buffer for the HDD, and writes the valve sent back around
            (
                "hdd_direct_bytes".to_string(),
                Json::Num(d(cur.hdd_direct_bytes, self.prev.hdd_direct_bytes) as f64),
            ),
            (
                "rerouted_writes".to_string(),
                Json::Num(d(cur.rerouted_writes, self.prev.rerouted_writes) as f64),
            ),
            // detector activity: streams classified, and how many the
            // hot/cold segregation biased to the cold log
            ("streams".to_string(), Json::Num(d(cur.streams, self.prev.streams) as f64)),
            (
                "biased_streams".to_string(),
                Json::Num(d(cur.biased_streams, self.prev.biased_streams) as f64),
            ),
            // submission-queue effectiveness: requests enqueued vs the
            // coalesced device commands that served them
            ("io_reqs".to_string(), Json::Num(d(cur.io_reqs, self.prev.io_reqs) as f64)),
            (
                "io_device_writes".to_string(),
                Json::Num(d(cur.io_device_writes, self.prev.io_device_writes) as f64),
            ),
            // array-level flush staggering felt by this engine's shards
            (
                "flush_token_waits".to_string(),
                Json::Num(d(cur.flush_token_waits, self.prev.flush_token_waits) as f64),
            ),
            (
                "flush_token_wait_ms".to_string(),
                Json::Num(d(cur.flush_token_wait_us, self.prev.flush_token_wait_us) as f64 / 1e3),
            ),
            // gauge: how many shards hold a flush token right now — the
            // live view of coordinator staggering
            ("flush_token_holders".to_string(), Json::Num(cur.flush_token_holders as f64)),
        ]);
        self.prev = cur;
        self.elapsed = since_start;
        self.seq += 1;
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get_num(j: &Json, key: &str) -> f64 {
        j.get(key).and_then(|v| v.as_f64()).unwrap_or_else(|| panic!("missing {key}: {j}"))
    }

    #[test]
    fn first_tick_reports_totals_as_deltas() {
        let mut s = Snapshotter::new();
        let cur = Counters {
            bytes_in: 10_000_000,
            ssd_bytes_buffered: 8_000_000,
            flushed_bytes: 1_000_000,
            superseded_bytes: 500_000,
            syncs: 10,
            sync_barriers: 40,
            blocked_waits: 3,
            ..Default::default()
        };
        let j = s.tick(cur, Duration::from_secs(2));
        assert_eq!(get_num(&j, "seq"), 0.0);
        assert!((get_num(&j, "mbps") - 5.0).abs() < 1e-9);
        assert_eq!(get_num(&j, "writes_per_sync"), 4.0);
        assert_eq!(get_num(&j, "blocked_waits"), 3.0);
        assert_eq!(get_num(&j, "ssd_occupancy_bytes"), 6_500_000.0);
    }

    #[test]
    fn second_tick_diffs_against_first() {
        let mut s = Snapshotter::new();
        let a = Counters { bytes_in: 1_000_000, syncs: 2, sync_barriers: 10, ..Default::default() };
        s.tick(a, Duration::from_secs(1));
        let b = Counters {
            bytes_in: 3_000_000,
            syncs: 2, // no new syncs this interval
            sync_barriers: 10,
            flush_pauses: 1,
            flush_pause_us: 2_500,
            flush_runs: 2,
            flush_run_us: 7_500,
            ..Default::default()
        };
        let j = s.tick(b, Duration::from_secs(2));
        assert_eq!(get_num(&j, "seq"), 1.0);
        assert!((get_num(&j, "mbps") - 2.0).abs() < 1e-9);
        assert_eq!(get_num(&j, "writes_per_sync"), 0.0, "zero denominator yields 0.0");
        assert!((get_num(&j, "flush_pause_ms") - 2.5).abs() < 1e-9);
        assert!((get_num(&j, "flush_run_ms") - 7.5).abs() < 1e-9);
        assert_eq!(get_num(&j, "flush_runs"), 2.0);
    }

    #[test]
    fn superseded_at_flush_is_an_interval_ratio_and_holders_a_gauge() {
        let mut s = Snapshotter::new();
        let a = Counters {
            queued_for_flush_bytes: 1_000,
            superseded_at_flush_bytes: 100,
            flush_token_holders: 2,
            hot_defers: 1,
            ..Default::default()
        };
        let j = s.tick(a, Duration::from_secs(1));
        assert!((get_num(&j, "superseded_at_flush") - 0.1).abs() < 1e-9);
        assert_eq!(get_num(&j, "flush_token_holders"), 2.0);
        assert_eq!(get_num(&j, "hot_defers"), 1.0);
        // second interval: 1000 more bytes queued, 500 superseded in
        // queue — the ratio covers this interval only, not the total
        let b = Counters {
            queued_for_flush_bytes: 2_000,
            superseded_at_flush_bytes: 600,
            flush_token_holders: 0,
            hot_defers: 1,
            ..Default::default()
        };
        let j = s.tick(b, Duration::from_secs(2));
        assert!((get_num(&j, "superseded_at_flush") - 0.5).abs() < 1e-9);
        assert_eq!(get_num(&j, "flush_token_holders"), 0.0, "gauge, not diffed");
        assert_eq!(get_num(&j, "hot_defers"), 0.0, "counter, diffed");
        // an idle interval divides zero by zero and reports 0.0
        let j = s.tick(b, Duration::from_secs(3));
        assert_eq!(get_num(&j, "superseded_at_flush"), 0.0);
        assert!(get_num(&j, "superseded_at_flush").is_finite());
    }

    #[test]
    fn zero_everything_is_all_zeros_not_nan() {
        let mut s = Snapshotter::new();
        let j = s.tick(Counters::default(), Duration::ZERO);
        for key in ["mbps", "writes_per_sync", "interval_s", "flush_pause_ms"] {
            let v = get_num(&j, key);
            assert_eq!(v, 0.0, "{key} must be 0.0, got {v}");
            assert!(v.is_finite());
        }
    }

    #[test]
    fn counters_fold_shard_stats() {
        let mut a = ShardStats::default();
        a.bytes_in = 100;
        a.flush_run_us = 7;
        a.io_retries = 4;
        a.degraded = true;
        a.queued_for_flush_bytes = 80;
        a.superseded_at_flush_bytes = 20;
        a.hdd_direct_bytes = 64;
        a.io_reqs = 12;
        a.io_device_writes = 3;
        let mut b = ShardStats::default();
        b.bytes_in = 50;
        b.flush_pause_us = 3;
        b.transient_faults = 2;
        b.queued_for_flush_bytes = 40;
        b.hot_defers = 5;
        b.streams = 6;
        b.biased_streams = 2;
        b.rerouted_writes = 1;
        b.flush_token_waits = 4;
        b.flush_token_wait_us = 900;
        let c = Counters::from_stats(&[a, b], 9);
        assert_eq!(c.bytes_in, 150);
        assert_eq!(c.queued_for_flush_bytes, 120);
        assert_eq!(c.superseded_at_flush_bytes, 20);
        assert_eq!(c.hot_defers, 5);
        assert_eq!(c.hdd_direct_bytes, 64);
        assert_eq!(c.io_reqs, 12);
        assert_eq!(c.io_device_writes, 3);
        assert_eq!(c.streams, 6);
        assert_eq!(c.biased_streams, 2);
        assert_eq!(c.rerouted_writes, 1);
        assert_eq!(c.flush_token_waits, 4);
        assert_eq!(c.flush_token_wait_us, 900);
        assert_eq!(c.flush_token_holders, 0, "the sampler fills the gauge in");
        assert_eq!(c.flush_run_us, 7);
        assert_eq!(c.flush_pause_us, 3);
        assert_eq!(c.dropped_trace_events, 9);
        assert_eq!(c.io_retries, 4);
        assert_eq!(c.transient_faults, 2);
        assert_eq!(c.degraded_shards, 1, "one shard flies degraded");
    }
}
