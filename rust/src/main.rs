//! `ssdup` — CLI for the SSDUP+ reproduction.
//!
//! Subcommands:
//!   exp <id>|all   regenerate a paper table/figure (see `ssdup list`)
//!   list           list experiment ids
//!   run            run one simulation (system/pattern/procs flags)
//!   runtime-info   verify artifacts + PJRT round-trip
//!   version        print version

use ssdup::experiments::{self, Scale};
use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::util::cli::Args;
use ssdup::util::json::Json;
use ssdup::util::threadpool::ThreadPool;
use ssdup::workload::ior::{ior, IorPattern};

const VALUE_OPTS: &[&str] = &[
    "scale", "seed", "json", "system", "pattern", "procs", "size-mib", "req-kb", "ssd-mib",
    "queue",
];

fn main() {
    let args = match Args::from_env(VALUE_OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand() {
        Some("exp") => cmd_exp(&args),
        Some("list") => {
            for id in experiments::all_ids() {
                println!("{id}");
            }
            0
        }
        Some("run") => cmd_run(&args),
        Some("runtime-info") => cmd_runtime_info(),
        Some("version") => {
            println!("ssdup {}", ssdup::version());
            0
        }
        _ => {
            eprintln!(
                "usage: ssdup <exp|list|run|runtime-info|version> [flags]\n\
                 \n\
                 ssdup exp all [--scale 8] [--seed N] [--json out.json]\n\
                 ssdup exp fig11 --scale 4\n\
                 ssdup run --system ssdup+ --pattern strided --procs 32 --size-mib 2048\n"
            );
            2
        }
    };
    std::process::exit(code);
}

fn scale_from(args: &Args) -> Scale {
    let mut s = Scale::default();
    s.factor = args.get_parse("scale", s.factor).unwrap_or(s.factor);
    s.seed = args.get_parse("seed", s.seed).unwrap_or(s.seed);
    s
}

fn cmd_exp(args: &Args) -> i32 {
    let scale = scale_from(args);
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let ids: Vec<&str> = if which == "all" {
        experiments::all_ids()
    } else {
        match experiments::all_ids().into_iter().find(|&i| i == which) {
            Some(i) => vec![i],
            None => {
                eprintln!("unknown experiment '{which}' (see `ssdup list`)");
                return 2;
            }
        }
    };
    // experiments are independent: fan out across cores
    let pool = ThreadPool::default_size();
    let reports = pool.map(ids.clone(), move |id| {
        let t0 = std::time::Instant::now();
        let rep = experiments::run(id, scale).expect("registered id");
        (rep, t0.elapsed())
    });
    let mut json_out = Vec::new();
    for (rep, dt) in &reports {
        rep.print();
        println!("({} ran in {:.1}s)\n", rep.id, dt.as_secs_f64());
        json_out.push(Json::obj(vec![
            ("id", Json::from(rep.id)),
            ("title", Json::from(rep.title.clone())),
            ("data", rep.data.clone()),
        ]));
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, Json::Arr(json_out).to_string()).expect("write json");
        println!("wrote {path}");
    }
    0
}

fn cmd_run(args: &Args) -> i32 {
    let system: SystemKind = match args.get_or("system", "ssdup+").parse() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let pattern = match args.get_or("pattern", "strided") {
        "contig" | "segmented-contiguous" => IorPattern::SegmentedContiguous,
        "random" | "segmented-random" => IorPattern::SegmentedRandom,
        "strided" => IorPattern::Strided,
        other => {
            eprintln!("unknown pattern '{other}'");
            return 2;
        }
    };
    let procs: u32 = args.get_parse("procs", 32).unwrap_or(32);
    let size_mib: u64 = args.get_parse("size-mib", 2048).unwrap_or(2048);
    let req_kb: i32 = args.get_parse("req-kb", 256).unwrap_or(256);
    let seed: u64 = args.get_parse("seed", 7).unwrap_or(7);
    let total_sectors = (size_mib * 1024 * 1024 / 512) as i64;
    let w = ior(0, pattern, procs, total_sectors, req_kb * 2, seed);

    let mut cfg = SimConfig::new(system).with_seed(seed);
    if let Some(mib) = args.get("ssd-mib") {
        cfg = cfg.with_ssd_mib(mib.parse().unwrap_or(8192));
    }
    if let Some(q) = args.get("queue") {
        cfg = cfg.with_queue_size(q.parse().unwrap_or(128));
    }
    let r = simulate(&cfg, &w);
    println!("{}", r.summary());
    for a in &r.per_app {
        println!(
            "  app {}: {:.2} MB/s ({} MiB in {:.2}s)",
            a.app,
            a.throughput_mbps(),
            a.bytes / (1 << 20),
            (a.end_us.saturating_sub(a.start_us)) as f64 / 1e6
        );
    }
    for (i, n) in r.nodes.iter().enumerate() {
        println!(
            "  node {i}: hdd {} MiB ({} seeks), ssd {} MiB buffered, {} flushes, {} blocked",
            n.hdd_bytes / (1 << 20),
            n.hdd_seeks,
            n.ssd_bytes_buffered / (1 << 20),
            n.flushes,
            n.blocked_requests
        );
    }
    0
}

fn cmd_runtime_info() -> i32 {
    match ssdup::runtime::Runtime::load_default() {
        Ok(rt) => {
            println!("artifacts: {}", rt.artifacts.dir.display());
            println!("platform:  {}", rt.platform());
            let det = rt.detector().expect("compile detector");
            let streams: Vec<Vec<(i32, i32)>> = vec![
                (0..128).map(|i| (i * 512, 512)).collect(),
                (0..128).map(|i| (i * 9973, 512)).collect(),
            ];
            let out = det.run_all(&streams).expect("execute");
            println!(
                "detector:  batch={} nmax={} | contiguous S={} random S={}",
                det.batch, det.nmax, out[0].s, out[1].s
            );
            0
        }
        Err(e) => {
            eprintln!("runtime unavailable: {e}");
            1
        }
    }
}
