//! `ssdup` — CLI for the SSDUP+ reproduction + live engine.
//!
//! Subcommands:
//!   exp <id>|all   regenerate a paper table/figure (see `ssdup list`)
//!   list           list experiment ids
//!   run            run one simulation (system/pattern/procs flags)
//!   live           run the real-time sharded engine on a live workload
//!   trace-check    validate a --trace export (CI smoke: stages present?)
//!   check          run the project-invariant static analyzer (blocking in CI)
//!   runtime-info   verify artifacts + PJRT round-trip
//!   version        print version

use ssdup::experiments::{self, Scale};
use ssdup::live::{self, LiveConfig, LiveEngine, SyntheticLatency};
use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::util::cli::Args;
use ssdup::util::json::Json;
use ssdup::util::threadpool::ThreadPool;
use ssdup::workload::ior::{ior, ior_spanned, IorPattern};
use ssdup::workload::rewrite::checkpoint_rewrite;
use ssdup::workload::Workload;

const VALUE_OPTS: &[&str] = &[
    "scale", "seed", "json", "system", "pattern", "procs", "size-mib", "req-kb", "ssd-mib",
    "queue", "shards", "backend", "clients", "dir", "crash-at", "group-commit-window",
    "trace", "stats-interval", "require", "io-workers", "io-depth", "fault-spec",
    "flush-concurrency", "hot-defer-window", "root",
];

fn main() {
    // under `check`, --json is a boolean switch (machine-readable
    // diagnostics), not `exp`'s `--json out.json` value option
    let value_opts: Vec<&str> = if std::env::args().nth(1).as_deref() == Some("check") {
        VALUE_OPTS.iter().copied().filter(|o| *o != "json").collect()
    } else {
        VALUE_OPTS.to_vec()
    };
    let args = match Args::parse(std::env::args().skip(1), &value_opts) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand() {
        Some("exp") => cmd_exp(&args),
        Some("list") => {
            for id in experiments::all_ids() {
                println!("{id}");
            }
            0
        }
        Some("run") => cmd_run(&args),
        Some("live") => cmd_live(&args),
        Some("trace-check") => cmd_trace_check(&args),
        Some("check") => cmd_check(&args),
        Some("runtime-info") => cmd_runtime_info(),
        Some("version") => {
            println!("ssdup {}", ssdup::version());
            0
        }
        _ => {
            eprintln!(
                "usage: ssdup <exp|list|run|live|trace-check|check|runtime-info|version> [flags]\n\
                 \n\
                 ssdup exp all [--scale 8] [--seed N] [--json out.json]\n\
                 ssdup exp fig11 --scale 4\n\
                 ssdup run --system ssdup+ --pattern strided --procs 32 --size-mib 2048\n\
                 ssdup live --shards 4 --backend mem|file [--dir DIR]\n\
                 \x20          [--pattern mixed|contig|random|strided|rewrite]\n\
                 \x20          [--procs 16] [--size-mib 1024] [--ssd-mib 64] [--clients 8]\n\
                 \x20          [--no-verify] [--keep]\n\
                 \x20          [--group-commit-window US]  leader batching window (default 0)\n\
                 \x20          [--no-group-commit]         per-record fsync baseline\n\
                 \x20          [--io-workers N]  I/O worker threads per device queue (default 4)\n\
                 \x20          [--io-depth N]    submission-queue depth per device (default 64)\n\
                 \x20          [--flush-concurrency N]  shards flushing the shared HDD tier at\n\
                 \x20                           once (default 2; 0 = uncoordinated flushers)\n\
                 \x20          [--hot-defer-window MS]  defer flushing mostly-hot log regions\n\
                 \x20                           up to MS ms (default 0 = off)\n\
                 \x20          [--trace OUT.json]     record spans, export chrome://tracing JSON\n\
                 \x20          [--stats-interval MS]  emit JSON-line telemetry snapshots on stderr\n\
                 \x20          [--crash-at N]   kill the process (no shutdown) after N acked requests\n\
                 \x20          [--recover]      reopen --dir images, replay the log, drain\n\
                 \x20          [--fault-spec S] scripted fault injection, e.g.\n\
                 \x20                           ssd:eio:p=0.01:transient=3,hdd:dead@op=5000\n\
                 ssdup trace-check OUT.json [--require submit,route,...]  validate a trace export\n\
                 ssdup check [--json] [--fix-hints] [--root DIR]  run the project-invariant lints\n"
            );
            2
        }
    };
    std::process::exit(code);
}

fn scale_from(args: &Args) -> Scale {
    let d = Scale::default();
    Scale {
        factor: args.get_parse("scale", d.factor).unwrap_or(d.factor),
        seed: args.get_parse("seed", d.seed).unwrap_or(d.seed),
    }
}

fn cmd_exp(args: &Args) -> i32 {
    let scale = scale_from(args);
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let ids: Vec<&str> = if which == "all" {
        experiments::all_ids()
    } else {
        match experiments::all_ids().into_iter().find(|&i| i == which) {
            Some(i) => vec![i],
            None => {
                eprintln!("unknown experiment '{which}' (see `ssdup list`)");
                return 2;
            }
        }
    };
    // experiments are independent: fan out across cores
    let pool = ThreadPool::default_size();
    let reports = pool.map(ids.clone(), move |id| {
        let t0 = std::time::Instant::now();
        let rep = experiments::run(id, scale).expect("registered id");
        (rep, t0.elapsed())
    });
    let mut json_out = Vec::new();
    for (rep, dt) in &reports {
        rep.print();
        println!("({} ran in {:.1}s)\n", rep.id, dt.as_secs_f64());
        json_out.push(Json::obj(vec![
            ("id", Json::from(rep.id)),
            ("title", Json::from(rep.title.clone())),
            ("data", rep.data.clone()),
        ]));
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, Json::Arr(json_out).to_string()).expect("write json");
        println!("wrote {path}");
    }
    0
}

fn cmd_run(args: &Args) -> i32 {
    let system: SystemKind = match args.get_or("system", "ssdup+").parse() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let pattern = match args.get_or("pattern", "strided") {
        "contig" | "segmented-contiguous" => IorPattern::SegmentedContiguous,
        "random" | "segmented-random" => IorPattern::SegmentedRandom,
        "strided" => IorPattern::Strided,
        other => {
            eprintln!("unknown pattern '{other}'");
            return 2;
        }
    };
    let procs: u32 = args.get_parse("procs", 32).unwrap_or(32);
    let size_mib: u64 = args.get_parse("size-mib", 2048).unwrap_or(2048);
    let req_kb: i32 = args.get_parse("req-kb", 256).unwrap_or(256);
    let seed: u64 = args.get_parse("seed", 7).unwrap_or(7);
    let total_sectors = (size_mib * 1024 * 1024 / 512) as i64;
    let w = ior(0, pattern, procs, total_sectors, req_kb * 2, seed);

    let mut cfg = SimConfig::new(system).with_seed(seed);
    if let Some(mib) = args.get("ssd-mib") {
        cfg = cfg.with_ssd_mib(mib.parse().unwrap_or(8192));
    }
    if let Some(q) = args.get("queue") {
        cfg = cfg.with_queue_size(q.parse().unwrap_or(128));
    }
    let r = simulate(&cfg, &w);
    println!("{}", r.summary());
    for a in &r.per_app {
        println!(
            "  app {}: {:.2} MB/s ({} MiB in {:.2}s)",
            a.app,
            a.throughput_mbps(),
            a.bytes / (1 << 20),
            (a.end_us.saturating_sub(a.start_us)) as f64 / 1e6
        );
    }
    for (i, n) in r.nodes.iter().enumerate() {
        println!(
            "  node {i}: hdd {} MiB ({} seeks), ssd {} MiB buffered, {} flushes, {} blocked",
            n.hdd_bytes / (1 << 20),
            n.hdd_seeks,
            n.ssd_bytes_buffered / (1 << 20),
            n.flushes,
            n.blocked_requests
        );
    }
    0
}

/// Build the live workload: `mixed` is the paper's headline scenario —
/// one contiguous and one random app sharing the engine. The returned
/// flag says whether the run needs versioned payloads (rewrite patterns,
/// where *which* copy of a sector survived matters).
fn live_workload(
    pattern: &str,
    procs: u32,
    total_sectors: i64,
    req_sectors: i32,
    seed: u64,
) -> Option<(Workload, bool)> {
    let span = total_sectors * 8; // keep random offsets paper-sparse
    let half = total_sectors / 2;
    match pattern {
        "mixed" => Some((
            Workload::concurrent(
                "live-mixed",
                ior_spanned(0, IorPattern::SegmentedContiguous, procs / 2, half, span, req_sectors, seed),
                ior_spanned(0, IorPattern::SegmentedRandom, procs / 2, half, span, req_sectors, seed + 1),
            ),
            false,
        )),
        "contig" | "segmented-contiguous" => Some((
            ior_spanned(0, IorPattern::SegmentedContiguous, procs, total_sectors, span, req_sectors, seed),
            false,
        )),
        "random" | "segmented-random" => Some((
            ior_spanned(0, IorPattern::SegmentedRandom, procs, total_sectors, span, req_sectors, seed),
            false,
        )),
        "strided" => {
            Some((ior_spanned(0, IorPattern::Strided, procs, total_sectors, span, req_sectors, seed), false))
        }
        // checkpoint-rewrite: every sector written twice across mixed
        // routes — the ownership-map overwrite-safety scenario
        "rewrite" | "checkpoint-rewrite" => {
            Some((checkpoint_rewrite((procs / 2).max(1), half, req_sectors, 1_000, seed), true))
        }
        _ => None,
    }
}

fn cmd_live(args: &Args) -> i32 {
    let system: SystemKind = match args.get_or("system", "ssdup+").parse() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let shards: usize = args.get_parse("shards", 4).unwrap_or(4).max(1);
    let backend = args.get_or("backend", "mem");
    let procs: u32 = args.get_parse("procs", 16).unwrap_or(16).max(2);
    let size_mib: u64 = args.get_parse("size-mib", 256).unwrap_or(256);
    let req_kb: i32 = args.get_parse("req-kb", 256).unwrap_or(256);
    let ssd_mib: u64 = args.get_parse("ssd-mib", 64).unwrap_or(64);
    let clients: usize = args.get_parse("clients", 8).unwrap_or(8);
    let seed: u64 = args.get_parse("seed", 7).unwrap_or(7);
    let pattern = args.get_or("pattern", "mixed");
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    let stats_ms: u64 = args.get_parse("stats-interval", 0).unwrap_or(0);

    // --fault-spec: wrap every backend in seeded deterministic fault
    // injectors (grammar in live::fault); --seed varies the streams
    let fault_spec = match args.get("fault-spec") {
        Some(s) => match live::FaultSpec::parse(s) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("error: {e}");
                return 2;
            }
        },
        None => live::FaultSpec::default(),
    };

    let crash_at: Option<u64> = match args.get("crash-at") {
        Some(v) => match v.parse() {
            Ok(n) => Some(n),
            Err(_) => {
                eprintln!("error: --crash-at expects a request count, got '{v}'");
                return 2;
            }
        },
        None => None,
    };
    // group commit defaults on; --no-group-commit is the per-record-sync
    // baseline, --group-commit-window (µs) trades ack latency for batch
    let window_us: u64 = args.get_parse("group-commit-window", 0).unwrap_or(0);
    let io_workers: usize = args.get_parse("io-workers", 4).unwrap_or(4).max(1);
    let io_depth: usize = args.get_parse("io-depth", 64).unwrap_or(64).max(1);
    let flush_concurrency: usize = args.get_parse("flush-concurrency", 2).unwrap_or(2);
    let hot_defer_ms: u64 = args.get_parse("hot-defer-window", 0).unwrap_or(0);
    let cfg = LiveConfig::new(system)
        .with_shards(shards)
        .with_ssd_mib(ssd_mib)
        .with_group_commit(!args.has("no-group-commit"))
        .with_group_commit_window(std::time::Duration::from_micros(window_us))
        .with_io_workers(io_workers)
        .with_io_depth(io_depth)
        .with_flush_concurrency(flush_concurrency)
        .with_hot_defer_window(std::time::Duration::from_millis(hot_defer_ms))
        .with_trace(trace_path.is_some());

    // --recover: reopen a previous `--backend file` run's images (same
    // --shards/--ssd-mib as the crashed run), replay the log, drain the
    // recovered data to the HDD images, and shut down cleanly. No
    // workload is generated or verified here — the recovered bytes
    // predate this process.
    if args.has("recover") {
        let (Some(dir), "file") = (args.get("dir"), backend) else {
            eprintln!("--recover requires --backend file --dir DIR (the crashed run's images)");
            return 2;
        };
        let dir = std::path::Path::new(dir);
        let (engine, report) = match LiveEngine::open_file_faulty(&cfg, dir, &fault_spec, seed) {
            Ok(pair) => pair,
            Err(e) => {
                eprintln!("error: cannot reopen backends under {}: {e}", dir.display());
                return 1;
            }
        };
        println!("{}", report.summary());
        engine.drain();
        let obs = std::sync::Arc::clone(engine.trace());
        let stats = engine.shutdown();
        let flushed: u64 = stats.iter().map(|s| s.flushed_bytes).sum();
        println!(
            "recovered data drained: {} MiB settled on the HDD images; clean superblocks written",
            flushed / (1 << 20)
        );
        print_fault_line(&stats, 0);
        if let Some(path) = &trace_path {
            if !write_trace(&obs, path) {
                return 1;
            }
        }
        return 0;
    }

    let total_sectors = (size_mib * 1024 * 1024 / 512) as i64;
    let Some((workload, versioned)) = live_workload(pattern, procs, total_sectors, req_kb * 2, seed)
    else {
        eprintln!("unknown pattern '{pattern}' (mixed|contig|random|strided|rewrite)");
        return 2;
    };

    let mut created_dir: Option<std::path::PathBuf> = None;
    let engine = match backend {
        "mem" => LiveEngine::mem_faulty(
            &cfg,
            SyntheticLatency::ssd(),
            SyntheticLatency::hdd(),
            &fault_spec,
            seed,
        ),
        "file" => {
            let dir = match args.get("dir") {
                Some(d) => std::path::PathBuf::from(d),
                None => {
                    let d = std::env::temp_dir()
                        .join(format!("ssdup-live-{}", std::process::id()));
                    created_dir = Some(d.clone());
                    d
                }
            };
            println!("backend dir: {}", dir.display());
            match LiveEngine::file_faulty(&cfg, &dir, &fault_spec, seed) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("error: cannot create file backends: {e}");
                    return 1;
                }
            }
        }
        other => {
            eprintln!("unknown backend '{other}' (mem|file)");
            return 2;
        }
    };

    println!(
        "live: {} | {} shards | {} backend | {} MiB over {} procs, {} clients | ssd {} MiB/shard\n",
        system.name(),
        shards,
        backend,
        size_mib,
        procs,
        clients,
        ssd_mib
    );

    // --crash-at N: submit closed-loop (single client) until N requests
    // have been acknowledged, then kill the process on the spot — no
    // drain, no shutdown, flushers mid-flight. The images under --dir
    // are left exactly as a power cut would: reopen them with --recover.
    if let Some(limit) = crash_at {
        if backend != "file" {
            eprintln!("--crash-at requires --backend file (a mem backend dies with the process)");
            return 2;
        }
        let dir_note = args.get("dir").map(str::to_owned).or_else(|| {
            created_dir.as_ref().map(|d| d.display().to_string())
        });
        let mut acked = 0u64;
        let mut buf: Vec<u8> = Vec::new();
        let mut cursors = vec![0usize; workload.processes.len()];
        loop {
            let mut progressed = false;
            for (pi, proc) in workload.processes.iter().enumerate() {
                if cursors[pi] >= proc.reqs.len() {
                    continue;
                }
                let req = proc.reqs[cursors[pi]];
                let gen = if versioned {
                    live::payload::write_gen(proc.proc_id, cursors[pi] as u32)
                } else {
                    0
                };
                cursors[pi] += 1;
                progressed = true;
                buf.resize(req.bytes() as usize, 0);
                live::payload::fill_gen(req.file, req.offset as i64, gen, &mut buf);
                if let Err(e) = engine.submit(req, &buf) {
                    eprintln!("error: submit rejected before the crash point: {e}");
                    return 1;
                }
                acked += 1;
                if acked >= limit {
                    println!("crash-at: {acked} requests acknowledged — dying without shutdown");
                    if let Some(d) = &dir_note {
                        println!(
                            "recover with: ssdup live --recover --backend file --dir {d} \
                             --shards {shards} --ssd-mib {ssd_mib}"
                        );
                    }
                    // a real crash: no drain, no clean superblock, no
                    // destructors — flusher threads die mid-I/O
                    std::process::exit(41);
                }
            }
            if !progressed {
                break;
            }
        }
        println!("crash-at {limit} never reached ({acked} requests in the whole workload)");
        engine.shutdown();
        return 2;
    }

    let snapshots = (stats_ms > 0).then(|| live::SnapshotOptions {
        interval: std::time::Duration::from_millis(stats_ms),
        out: Box::new(std::io::stderr()) as Box<dyn std::io::Write + Send>,
    });
    let report = live::run_load_reported(&engine, &workload, clients, versioned, snapshots);
    println!("{}", report.summary());
    print_fault_line(&report.shards, report.rejected);
    for (i, s) in report.shards.iter().enumerate() {
        println!(
            "  shard {i}: in {} MiB | ssd {} MiB | direct {} MiB | flushed {} MiB | \
             superseded {} MiB | {} rerouted | {} streams (rp {:.1}%) | {} flushes, \
             {} pauses ({:.2}s), runs {:.2}s (duty {:.0}%), {} blocked waits | \
             {} syncs ({:.1} writes/sync) | io {} reqs -> {} dev writes \
             (depth hw {}, mean {:.1}) | {} retries{}",
            s.bytes_in / (1 << 20),
            s.ssd_bytes_buffered / (1 << 20),
            s.hdd_direct_bytes / (1 << 20),
            s.flushed_bytes / (1 << 20),
            s.superseded_bytes / (1 << 20),
            s.rerouted_writes,
            s.streams,
            s.mean_percentage() * 100.0,
            s.flushes,
            s.flush_pauses,
            s.flush_pause_us as f64 / 1e6,
            s.flush_run_us as f64 / 1e6,
            s.flush_duty_cycle() * 100.0,
            s.blocked_waits,
            s.syncs,
            s.writes_per_sync(),
            s.io_reqs,
            s.io_device_writes,
            s.io_depth_high_water,
            s.io_mean_depth,
            s.io_retries,
            if s.degraded { " | DEGRADED (direct-to-HDD)" } else { "" },
        );
        println!(
            "           flush sched: {} runs | queued {} MiB | superseded-at-flush {} MiB | \
             {} hot defers | {} biased streams | {} token waits ({:.2}s)",
            s.flush_runs,
            s.queued_for_flush_bytes / (1 << 20),
            s.superseded_at_flush_bytes / (1 << 20),
            s.hot_defers,
            s.biased_streams,
            s.flush_token_waits,
            s.flush_token_wait_us as f64 / 1e6,
        );
    }
    println!("\nper-stage ack latency:\n{}", report.stage_summary());

    // under --trace, read a sample request back through the engine so the
    // export also carries the read-path stages (the load generator is
    // write-only)
    if trace_path.is_some() {
        if let Some(req) = workload.processes.iter().find_map(|p| p.reqs.first()) {
            let mut buf = vec![0u8; req.bytes() as usize];
            let _ = engine.read(req.file, req.offset, &mut buf);
        }
    }

    let mut code = 0;
    if !args.has("no-verify") {
        let v = if versioned {
            engine.verify_workload_versioned(&workload)
        } else {
            engine.verify_workload(&workload)
        };
        if v.is_ok() {
            let mib = v.checked_bytes / (1 << 20);
            println!("\nverify: OK — {mib} MiB re-derived and matched on the HDD backends");
        } else {
            let (bad, unread, total) = (v.mismatched_sectors, v.read_errors, v.checked_bytes);
            println!(
                "\nverify: FAILED — {bad} mismatched sectors, {unread} unreadable ranges \
                 of {total} bytes checked"
            );
            code = 1;
        }
    }
    let obs = std::sync::Arc::clone(engine.trace());
    engine.shutdown();
    if let Some(path) = &trace_path {
        if !write_trace(&obs, path) {
            code = 1;
        }
    }
    if let Some(dir) = created_dir {
        if !args.has("keep") {
            std::fs::remove_dir_all(&dir).ok();
        } else {
            println!("kept backend dir: {}", dir.display());
        }
    }
    code
}

/// One greppable fault-handling line (CI's fault-matrix smoke parses
/// `io_retries=`): retries absorbed, transient faults seen, shards that
/// fell back to direct-to-HDD, requests rejected outright.
fn print_fault_line(stats: &[ssdup::live::ShardStats], rejected: u64) {
    let io_retries: u64 = stats.iter().map(|s| s.io_retries).sum();
    let transient: u64 = stats.iter().map(|s| s.transient_faults).sum();
    let degraded = stats.iter().filter(|s| s.degraded).count();
    println!(
        "faults: io_retries={io_retries} transient_faults={transient} \
         degraded_shards={degraded} rejected={rejected}"
    );
}

/// Drain the collector and export Chrome-trace JSON. Runs after
/// `shutdown` so the rings also hold the final drain's flush/superblock
/// spans. Returns false on I/O failure.
fn write_trace(obs: &ssdup::obs::TraceCollector, path: &std::path::Path) -> bool {
    let events = obs.drain();
    let dropped = obs.dropped_events();
    let json = ssdup::obs::chrome_trace_json(&events, dropped);
    match std::fs::write(path, json.to_string()) {
        Ok(()) => {
            println!("trace: {} events ({dropped} dropped) -> {}", events.len(), path.display());
            true
        }
        Err(e) => {
            eprintln!("error: cannot write trace {}: {e}", path.display());
            false
        }
    }
}

/// `ssdup trace-check FILE [--require a,b,c]` — CI smoke validation of a
/// `--trace` export: the file must parse as JSON, and every required
/// stage must have at least one event. Defaults to the write-ack path.
fn cmd_trace_check(args: &Args) -> i32 {
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: ssdup trace-check FILE [--require stage,stage,...]");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: cannot read {path}: {e}");
            return 1;
        }
    };
    let json = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("error: {path} is not valid JSON: {e:?}");
            return 1;
        }
    };
    let Some(events) = json.get("traceEvents").and_then(|v| v.as_arr()) else {
        eprintln!("error: {path} has no traceEvents array");
        return 1;
    };
    let mut counts: std::collections::BTreeMap<&str, u64> = std::collections::BTreeMap::new();
    for ev in events {
        if let Some(name) = ev.get("name").and_then(|v| v.as_str()) {
            *counts.entry(name).or_insert(0) += 1;
        }
    }
    let dropped = json
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(|v| v.as_i64())
        .unwrap_or(0);
    println!("{path}: {} events, {} stages, {dropped} dropped", events.len(), counts.len());
    for (name, n) in &counts {
        println!("  {name:<14} {n}");
    }
    let required: Vec<String> = match args.get("require") {
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect(),
        None => ["submit", "route", "reserve", "barrier_wait", "publish"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    let mut code = 0;
    for stage in &required {
        if ssdup::obs::Stage::from_name(stage).is_none() {
            eprintln!("trace-check: '{stage}' is not a known stage name");
            code = 2;
        } else if counts.get(stage.as_str()).copied().unwrap_or(0) == 0 {
            eprintln!("trace-check: required stage '{stage}' has no events");
            code = 1;
        }
    }
    if code == 0 {
        println!("trace-check: OK ({} required stages present)", required.len());
    }
    code
}

/// `ssdup check` — run the project-invariant static analyzer over the
/// repository's own sources (see `ssdup::analysis`). Exit 0 when clean,
/// 1 when diagnostics fire, 2 when the tree cannot be scanned at all.
fn cmd_check(args: &Args) -> i32 {
    let root = std::path::PathBuf::from(args.get_or("root", "."));
    let outcome = match ssdup::analysis::run_check(&root) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    if args.has("json") {
        let diags: Vec<Json> = outcome.diags.iter().map(|d| d.to_json()).collect();
        let out = Json::obj(vec![
            ("files_scanned", Json::from(outcome.files_scanned)),
            ("diagnostics", Json::Arr(diags)),
            ("ok", Json::from(outcome.diags.is_empty())),
        ]);
        println!("{out}");
    } else {
        let fix_hints = args.has("fix-hints");
        for d in &outcome.diags {
            println!("{}", d.render(fix_hints));
        }
        if outcome.diags.is_empty() {
            println!("check: OK ({} files scanned)", outcome.files_scanned);
        } else {
            eprintln!(
                "check: {} diagnostic(s) in {} files scanned",
                outcome.diags.len(),
                outcome.files_scanned
            );
        }
    }
    if outcome.diags.is_empty() { 0 } else { 1 }
}

#[cfg(feature = "pjrt")]
fn cmd_runtime_info() -> i32 {
    match ssdup::runtime::Runtime::load_default() {
        Ok(rt) => {
            println!("artifacts: {}", rt.artifacts.dir.display());
            println!("platform:  {}", rt.platform());
            let det = rt.detector().expect("compile detector");
            let streams: Vec<Vec<(i32, i32)>> = vec![
                (0..128).map(|i| (i * 512, 512)).collect(),
                (0..128).map(|i| (i * 9973, 512)).collect(),
            ];
            let out = det.run_all(&streams).expect("execute");
            println!(
                "detector:  batch={} nmax={} | contiguous S={} random S={}",
                det.batch, det.nmax, out[0].s, out[1].s
            );
            0
        }
        Err(e) => {
            eprintln!("runtime unavailable: {e}");
            1
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_runtime_info() -> i32 {
    use ssdup::detector::hlo::DetectBackend;
    // built without the `pjrt` feature: report artifact status and prove
    // the native fallback path works
    match ssdup::runtime::ArtifactSet::load_default() {
        Ok(a) => println!("artifacts: {} (validated; PJRT execution compiled out)", a.dir.display()),
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    let mut det = ssdup::detector::hlo::default_backend(ssdup::device::SeekModel::default());
    let contiguous: Vec<(i32, i32)> = (0..128).map(|i| (i * 512, 512)).collect();
    let random: Vec<(i32, i32)> = (0..128).map(|i| (i * 9973, 512)).collect();
    println!(
        "detector:  backend={} | contiguous S={} random S={}",
        det.name(),
        det.detect(&contiguous).s,
        det.detect(&random).s
    );
    0
}
