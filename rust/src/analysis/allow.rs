//! Zero-dep parser for the checked-in lint allow-list
//! (`rust/src/analysis/allow.toml`). The accepted grammar is the TOML
//! subset the file actually needs: `[[allow]]` table headers, bare
//! `key = "string"` pairs, `#` comments, blank lines. Anything else is
//! a hard parse error — the allow-list is code, not prose.
//!
//! Every entry must be *used* by at least one suppressed diagnostic;
//! stale entries are themselves reported (`allow-unused`), so the file
//! can only shrink when the code improves.

use std::cell::Cell;

use crate::analysis::diag::Diagnostic;

/// One `[[allow]]` entry. Empty `file`/`context`/`callee` match
/// anything; `note` is mandatory so every exception carries its why.
#[derive(Clone, Debug, Default)]
pub struct AllowEntry {
    pub lint: String,
    pub file: String,
    pub context: String,
    pub callee: String,
    pub note: String,
    /// Line of the `[[allow]]` header in the allow file.
    pub line: u32,
}

pub struct AllowList {
    /// Path label used in `allow-unused` diagnostics.
    pub path: String,
    pub entries: Vec<AllowEntry>,
    used: Vec<Cell<bool>>,
}

impl AllowList {
    pub fn empty() -> Self {
        AllowList { path: String::new(), entries: Vec::new(), used: Vec::new() }
    }

    /// Parse the allow file; `path` labels error messages.
    pub fn parse(path: &str, text: &str) -> Result<AllowList, String> {
        let mut entries: Vec<AllowEntry> = Vec::new();
        let mut cur: Option<AllowEntry> = None;
        for (i, raw) in text.lines().enumerate() {
            let lineno = (i + 1) as u32;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "[[allow]]" {
                if let Some(e) = cur.take() {
                    entries.push(finish(path, e)?);
                }
                cur = Some(AllowEntry { line: lineno, ..Default::default() });
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                return Err(format!("{path}:{lineno}: expected `key = \"value\"`, got `{line}`"));
            };
            let key = key.trim();
            let val = val.trim();
            if !(val.starts_with('"') && val.ends_with('"') && val.len() >= 2) {
                return Err(format!("{path}:{lineno}: value for `{key}` must be a quoted string"));
            }
            let val = val[1..val.len() - 1].to_string();
            let Some(e) = cur.as_mut() else {
                return Err(format!("{path}:{lineno}: `{key}` outside an [[allow]] table"));
            };
            match key {
                "lint" => e.lint = val,
                "file" => e.file = val,
                "context" => e.context = val,
                "callee" => e.callee = val,
                "note" => e.note = val,
                other => {
                    return Err(format!("{path}:{lineno}: unknown allow key `{other}`"));
                }
            }
        }
        if let Some(e) = cur.take() {
            entries.push(finish(path, e)?);
        }
        let used = entries.iter().map(|_| Cell::new(false)).collect();
        Ok(AllowList { path: path.to_string(), entries, used })
    }

    /// Does any entry cover this diagnostic? Marks the entry used.
    pub fn permits(&self, d: &Diagnostic) -> bool {
        for (e, used) in self.entries.iter().zip(&self.used) {
            let hit = e.lint == d.lint
                && (e.file.is_empty() || d.file.ends_with(&e.file))
                && (e.context.is_empty() || e.context == d.context)
                && (e.callee.is_empty() || e.callee == d.callee);
            if hit {
                used.set(true);
                return true;
            }
        }
        false
    }

    /// Diagnostics for entries that suppressed nothing this run.
    pub fn unused(&self) -> Vec<Diagnostic> {
        self.entries
            .iter()
            .zip(&self.used)
            .filter(|(_, u)| !u.get())
            .map(|(e, _)| Diagnostic {
                lint: "allow-unused",
                file: self.path.clone(),
                line: e.line,
                context: e.context.clone(),
                callee: e.callee.clone(),
                message: format!(
                    "allow entry (lint `{}`, context `{}`) matched no diagnostic — delete it",
                    e.lint, e.context
                ),
                hint: "the code no longer trips this lint; the exception is stale".to_string(),
            })
            .collect()
    }
}

fn finish(path: &str, e: AllowEntry) -> Result<AllowEntry, String> {
    if e.lint.is_empty() {
        return Err(format!("{path}:{}: [[allow]] entry missing `lint`", e.line));
    }
    if e.note.is_empty() {
        return Err(format!(
            "{path}:{}: [[allow]] entry missing `note` — every exception documents its why",
            e.line
        ));
    }
    Ok(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(lint: &'static str, file: &str, context: &str, callee: &str) -> Diagnostic {
        Diagnostic {
            lint,
            file: file.to_string(),
            line: 1,
            context: context.to_string(),
            callee: callee.to_string(),
            message: String::new(),
            hint: String::new(),
        }
    }

    #[test]
    fn parse_match_and_unused() {
        let text = "# header\n[[allow]]\nlint = \"lock-io\"\nfile = \"live/shard.rs\"\ncontext = \"degrade\"\ncallee = \"write_superblock\"\nnote = \"first-touch superblock\"\n\n[[allow]]\nlint = \"panic-free\"\ncontext = \"nobody\"\nnote = \"stale\"\n";
        let a = AllowList::parse("allow.toml", text).unwrap();
        assert_eq!(a.entries.len(), 2);
        assert!(a.permits(&diag("lock-io", "rust/src/live/shard.rs", "degrade", "write_superblock")));
        assert!(!a.permits(&diag("lock-io", "rust/src/live/shard.rs", "sync", "write_superblock")));
        let unused = a.unused();
        assert_eq!(unused.len(), 1);
        assert_eq!(unused[0].context, "nobody");
        assert_eq!(unused[0].line, 9);
    }

    #[test]
    fn note_is_mandatory_and_junk_rejected() {
        assert!(AllowList::parse("a", "[[allow]]\nlint = \"lock-io\"\n").is_err());
        assert!(AllowList::parse("a", "[[allow]]\nlint = lock-io\nnote = \"x\"\n").is_err());
        assert!(AllowList::parse("a", "lint = \"x\"\n").is_err());
        assert!(AllowList::parse("a", "[[allow]]\nwhat = \"x\"\nlint = \"l\"\nnote = \"n\"\n").is_err());
    }
}
