//! Line-aware Rust tokenizer + scope tracker for the invariant lints.
//!
//! This is not a compiler front end: it produces a flat token stream
//! (identifiers, punctuation, string-literal payloads) with, per token,
//! the source line, the brace depth, the enclosing `fn` item, and
//! whether the token sits inside a `#[cfg(test)]` / `#[test]` region.
//! Comments are stripped from the stream but recorded per line so lints
//! can check for adjacent justification comments (the atomic-ordering
//! convention). That is exactly the resolution the lints in this module
//! need — no type information, no expansion, zero dependencies.
//!
//! Handled so the scope tracking stays honest on real sources:
//! line/block comments (nested), string/char/byte literals, raw strings
//! (`r#"…"#`), lifetimes vs char literals, numeric literals, and `::`
//! as a single token. Attribute groups (`#[…]`) are consumed and do not
//! appear in the stream.

/// Token kind: identifier/keyword, single punctuation char (plus the
/// merged `::`), or the payload of a string literal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Str,
}

/// One token with its scope context.
#[derive(Clone, Debug)]
pub struct Tok {
    pub text: String,
    pub kind: TokKind,
    /// 1-based source line.
    pub line: u32,
    /// Brace depth: for `{` the depth of the scope it opens into is
    /// `depth + 1`; for `}` the depth of the scope it returns to.
    pub depth: u32,
    /// Inside a `#[test]` item or `#[cfg(test)]` region.
    pub in_test: bool,
    /// Index into [`SourceFile::fns`] of the innermost enclosing `fn`,
    /// or `u32::MAX` at module scope.
    pub fn_id: u32,
}

pub const NO_FN: u32 = u32::MAX;

/// A lexed file: token stream plus the per-line comment record.
#[derive(Clone, Debug)]
pub struct SourceFile {
    /// Repo-relative path, forward slashes (e.g. `rust/src/live/shard.rs`).
    pub path: String,
    pub toks: Vec<Tok>,
    /// `(line, text)` — block comments contribute one entry per line.
    pub comments: Vec<(u32, String)>,
    /// Names of `fn` items in definition order; [`Tok::fn_id`] indexes here.
    pub fns: Vec<String>,
}

impl SourceFile {
    /// Name of the `fn` enclosing `tok`, if any.
    pub fn fn_name(&self, tok: &Tok) -> Option<&str> {
        self.fns.get(tok.fn_id as usize).map(String::as_str)
    }

    /// Iterate comment texts recorded on lines `lo..=hi` (1-based).
    pub fn comments_in(&self, lo: u32, hi: u32) -> impl Iterator<Item = &str> {
        self.comments
            .iter()
            .filter(move |(l, _)| *l >= lo && *l <= hi)
            .map(|(_, t)| t.as_str())
    }
}

/// Lex `src` (UTF-8 Rust source) into a [`SourceFile`].
pub fn lex_source(path: &str, src: &str) -> SourceFile {
    let raw = raw_tokens(src);
    scope_pass(path, raw)
}

struct RawTok {
    text: String,
    kind: TokKind,
    line: u32,
}

struct RawOut {
    toks: Vec<RawTok>,
    comments: Vec<(u32, String)>,
}

fn raw_tokens(src: &str) -> RawOut {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut toks = Vec::new();
    let mut comments: Vec<(u32, String)> = Vec::new();

    let is_ident_start = |c: char| c.is_ascii_alphabetic() || c == '_';
    let is_ident = |c: char| c.is_ascii_alphanumeric() || c == '_';

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && i + 1 < n && b[i + 1] == '/' {
            // line comment (incl. doc comments): record text to newline
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            let text: String = b[start..j].iter().collect();
            comments.push((line, text.trim_start_matches(['/', '!']).trim().to_string()));
            i = j;
        } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
            // nested block comment: one comment entry per line it spans
            let mut depth = 1;
            let mut j = i + 2;
            let mut seg = String::new();
            while j < n && depth > 0 {
                if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == '\n' {
                        comments.push((line, seg.trim().trim_start_matches('*').trim().to_string()));
                        seg = String::new();
                        line += 1;
                    } else {
                        seg.push(b[j]);
                    }
                    j += 1;
                }
            }
            comments.push((line, seg.trim().trim_start_matches('*').trim().to_string()));
            i = j;
        } else if c == '"' {
            let (text, ni, nl) = scan_string(&b, i, line);
            toks.push(RawTok { text, kind: TokKind::Str, line });
            line = nl;
            i = ni;
        } else if c == '\'' {
            // lifetime ('a) vs char literal ('x', '\n', '\u{..}')
            if i + 2 < n && (is_ident_start(b[i + 1])) && b[i + 2] != '\'' {
                let mut j = i + 1;
                while j < n && is_ident(b[j]) {
                    j += 1;
                }
                i = j; // lifetime: drop it
            } else {
                let mut j = i + 1;
                while j < n {
                    if b[j] == '\\' {
                        j += 2;
                    } else if b[j] == '\'' {
                        j += 1;
                        break;
                    } else {
                        j += 1;
                    }
                }
                i = j;
            }
        } else if c.is_ascii_digit() {
            // numeric literal (no dotted floats: `1.5` lexes as num . num,
            // which is fine — numbers are dropped from the stream anyway)
            let mut j = i + 1;
            while j < n && is_ident(b[j]) {
                j += 1;
            }
            i = j;
        } else if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident(b[j]) {
                j += 1;
            }
            let text: String = b[i..j].iter().collect();
            // raw / byte string prefixes fused to a quote: r"…", r#"…"#, b"…", br#"…"#
            if (text == "r" || text == "br") && j < n && (b[j] == '"' || b[j] == '#') {
                let (ni, nl) = scan_raw_string(&b, j, line);
                toks.push(RawTok { text: String::new(), kind: TokKind::Str, line });
                line = nl;
                i = ni;
            } else if text == "b" && j < n && b[j] == '"' {
                let (s, ni, nl) = scan_string(&b, j, line);
                toks.push(RawTok { text: s, kind: TokKind::Str, line });
                line = nl;
                i = ni;
            } else {
                toks.push(RawTok { text, kind: TokKind::Ident, line });
                i = j;
            }
        } else if c == ':' && i + 1 < n && b[i + 1] == ':' {
            toks.push(RawTok { text: "::".to_string(), kind: TokKind::Punct, line });
            i += 2;
        } else {
            toks.push(RawTok { text: c.to_string(), kind: TokKind::Punct, line });
            i += 1;
        }
    }
    RawOut { toks, comments }
}

/// Scan a normal (escaped) string starting at the opening quote.
/// Returns (payload, next index, line after).
fn scan_string(b: &[char], start: usize, mut line: u32) -> (String, usize, u32) {
    let n = b.len();
    let mut j = start + 1;
    let mut s = String::new();
    while j < n {
        match b[j] {
            '\\' => {
                j += 2;
            }
            '"' => {
                j += 1;
                break;
            }
            '\n' => {
                line += 1;
                s.push('\n');
                j += 1;
            }
            c => {
                s.push(c);
                j += 1;
            }
        }
    }
    (s, j, line)
}

/// Scan a raw string starting at the `#`s or quote after the `r`.
/// Returns (next index, line after). Payload is dropped (raw strings in
/// this codebase are doc/test fixtures the lints don't inspect).
fn scan_raw_string(b: &[char], start: usize, mut line: u32) -> (usize, u32) {
    let n = b.len();
    let mut hashes = 0usize;
    let mut j = start;
    while j < n && b[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < n && b[j] == '"' {
        j += 1;
    }
    while j < n {
        if b[j] == '\n' {
            line += 1;
            j += 1;
        } else if b[j] == '"' {
            let mut k = 0usize;
            while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return (j + 1 + hashes, line);
            }
            j += 1;
        } else {
            j += 1;
        }
    }
    (j, line)
}

/// Second pass: brace depth, enclosing fn, test regions; attributes are
/// consumed here (they never reach the lints).
fn scope_pass(path: &str, raw: RawOut) -> SourceFile {
    let mut toks: Vec<Tok> = Vec::with_capacity(raw.toks.len());
    let mut fns: Vec<String> = Vec::new();

    let mut depth = 0u32;
    let mut paren = 0i32; // () and [] nesting, for `;` disambiguation
    let mut test_stack: Vec<u32> = Vec::new(); // inner depth of each test region
    let mut fn_stack: Vec<(u32, u32)> = Vec::new(); // (fn_id, inner depth)
    let mut pending_test = false;
    let mut pending_test_depth = 0u32;
    let mut pending_fn: Option<u32> = None;

    let rts = &raw.toks;
    let mut i = 0usize;
    while i < rts.len() {
        let rt = &rts[i];
        // attribute group: `#[…]` or `#![…]` — scan for a test marker,
        // then swallow the whole group
        if rt.kind == TokKind::Punct && rt.text == "#" {
            let mut j = i + 1;
            if j < rts.len() && rts[j].text == "!" {
                j += 1;
            }
            if j < rts.len() && rts[j].text == "[" {
                let mut bd = 0i32;
                let mut idents: Vec<&str> = Vec::new();
                while j < rts.len() {
                    match rts[j].text.as_str() {
                        "[" => bd += 1,
                        "]" => {
                            bd -= 1;
                            if bd == 0 {
                                break;
                            }
                        }
                        _ => {
                            if rts[j].kind == TokKind::Ident {
                                idents.push(&rts[j].text);
                            }
                        }
                    }
                    j += 1;
                }
                let has = |s: &str| idents.iter().any(|t| *t == s);
                // `#[test]` or `#[cfg(test)]`-family, but not `#[cfg(not(test))]`
                if has("test") && !has("not") {
                    pending_test = true;
                    pending_test_depth = depth;
                }
                i = j + 1;
                continue;
            }
        }

        let mut tok_depth = depth;
        match rt.text.as_str() {
            "{" if rt.kind == TokKind::Punct => {
                depth += 1;
                if let Some(id) = pending_fn.take() {
                    fn_stack.push((id, depth));
                }
                if pending_test && pending_test_depth + 1 == depth {
                    test_stack.push(depth);
                    pending_test = false;
                }
            }
            "}" if rt.kind == TokKind::Punct => {
                depth = depth.saturating_sub(1);
                tok_depth = depth;
            }
            "(" | "[" if rt.kind == TokKind::Punct => paren += 1,
            ")" | "]" if rt.kind == TokKind::Punct => paren -= 1,
            ";" if rt.kind == TokKind::Punct && paren == 0 => {
                // `#[cfg(test)] use …;` / trait method decl: the pending
                // attribute or fn never got a body — cancel it
                if pending_test && pending_test_depth == depth {
                    pending_test = false;
                }
                pending_fn = None;
            }
            "fn" if rt.kind == TokKind::Ident => {
                if i + 1 < rts.len() && rts[i + 1].kind == TokKind::Ident {
                    fns.push(rts[i + 1].text.clone());
                    pending_fn = Some((fns.len() - 1) as u32);
                }
            }
            _ => {}
        }

        let in_test = !test_stack.is_empty();
        let fn_id = fn_stack.last().map(|(id, _)| *id).unwrap_or(NO_FN);
        toks.push(Tok {
            text: rt.text.clone(),
            kind: rt.kind,
            line: rt.line,
            depth: tok_depth,
            in_test,
            fn_id,
        });

        if rt.text == "}" && rt.kind == TokKind::Punct {
            while test_stack.last().is_some_and(|d| *d > depth) {
                test_stack.pop();
            }
            while fn_stack.last().is_some_and(|(_, d)| *d > depth) {
                fn_stack.pop();
            }
        }
        i += 1;
    }

    SourceFile { path: path.to_string(), toks, comments: raw.comments, fns }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idents_depth_and_fns() {
        let f = lex_source(
            "x.rs",
            "fn outer() {\n    let a = 1;\n    fn inner() { b(); }\n    c();\n}\n",
        );
        let b_call = f.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(f.fn_name(b_call), Some("inner"));
        assert_eq!(b_call.depth, 2);
        let c_call = f.toks.iter().find(|t| t.text == "c").unwrap();
        assert_eq!(f.fn_name(c_call), Some("outer"));
        assert_eq!(c_call.line, 4);
    }

    #[test]
    fn comments_and_strings_stripped() {
        let f = lex_source(
            "x.rs",
            "// top note\nfn f() {\n    let s = \"ig{nored\"; /* block\n   across */ g();\n}\n",
        );
        assert!(f.toks.iter().all(|t| t.text != "ig"));
        assert!(f.comments.iter().any(|(l, t)| *l == 1 && t == "top note"));
        assert!(f.comments.iter().any(|(l, t)| *l == 3 && t.contains("block")));
        let g = f.toks.iter().find(|t| t.text == "g").unwrap();
        assert_eq!(g.line, 4);
        let s = f.toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, "ig{nored");
    }

    #[test]
    fn test_regions_marked() {
        let src = "fn live() { a(); }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { a(); }\n}\nfn live2() { a(); }\n";
        let f = lex_source("x.rs", src);
        let calls: Vec<&Tok> = f.toks.iter().filter(|t| t.text == "a").collect();
        assert_eq!(calls.len(), 3);
        assert!(!calls[0].in_test);
        assert!(calls[1].in_test);
        assert!(!calls[2].in_test);
    }

    #[test]
    fn cfg_not_test_is_live_and_attr_on_use_cancels() {
        let src = "#[cfg(not(test))]\nfn live() { a(); }\n#[cfg(test)]\nuse x::y;\nfn live2() { b(); }\n";
        let f = lex_source("x.rs", src);
        assert!(f.toks.iter().filter(|t| t.text == "a" || t.text == "b").all(|t| !t.in_test));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) {\n    let r = r#\"quoted \"{ brace\"#;\n    let c = '{';\n    h();\n}\n";
        let f = lex_source("x.rs", src);
        let h = f.toks.iter().find(|t| t.text == "h").unwrap();
        assert_eq!(h.depth, 1, "braces inside raw string / char literal must not nest");
        assert_eq!(f.fn_name(h), Some("f"));
    }

    #[test]
    fn array_semicolon_does_not_cancel_pending_fn() {
        let src = "fn f(x: [u8; 4]) {\n    y();\n}\n";
        let f = lex_source("x.rs", src);
        let y = f.toks.iter().find(|t| t.text == "y").unwrap();
        assert_eq!(f.fn_name(y), Some("f"));
    }
}
