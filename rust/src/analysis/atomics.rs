//! Lint `atomic-ordering`: every non-test `Ordering::` use must carry
//! an adjacent required-ordering comment (the PR 3 convention: each
//! relaxed atomic states why that ordering suffices — "stats counter,
//! no synchronization" / "Release store pairs with the Acquire load in
//! …"). `SeqCst` is held to the same bar: in engine code it is almost
//! always a missing justification, not a stronger guarantee.
//!
//! A comment covers a use if it sits on the same line or within
//! [`COMMENT_WINDOW`] lines above and mentions an ordering keyword;
//! consecutive uses within [`RUN_GAP`] lines share one comment (the
//! common `stats()`-style block of loads under a single header).

use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::{SourceFile, TokKind};

const MEMBERS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// How far above a use a justification comment may sit.
const COMMENT_WINDOW: u32 = 3;
/// Max line gap for two uses to share one justification comment.
const RUN_GAP: u32 = 2;

const KEYWORDS: &[&str] = &[
    "ordering",
    "relaxed",
    "acquire",
    "release",
    "acqrel",
    "acq-rel",
    "seqcst",
    "happens-before",
    "synchroniz",
    "fence",
    "monotonic",
];

fn comment_covers(f: &SourceFile, line: u32) -> bool {
    let lo = line.saturating_sub(COMMENT_WINDOW);
    f.comments_in(lo, line).any(|c| {
        let c = c.to_ascii_lowercase();
        KEYWORDS.iter().any(|k| c.contains(k))
    })
}

pub fn check(files: &[SourceFile]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in files {
        // (line, member, fn_name) per non-test atomic-ordering use
        let mut uses: Vec<(u32, String, String)> = Vec::new();
        let toks = &f.toks;
        for i in 0..toks.len() {
            let t = &toks[i];
            if t.kind != TokKind::Ident || t.text != "Ordering" || t.in_test {
                continue;
            }
            // skip `cmp::Ordering` paths (sort comparators, not atomics)
            if i >= 2 && toks[i - 1].text == "::" && toks[i - 2].text == "cmp" {
                continue;
            }
            let Some(sep) = toks.get(i + 1) else { continue };
            let Some(member) = toks.get(i + 2) else { continue };
            if sep.text == "::" && MEMBERS.contains(&member.text.as_str()) {
                let ctx = f.fn_name(t).unwrap_or("").to_string();
                uses.push((t.line, member.text.clone(), ctx));
            }
        }
        uses.sort();
        uses.dedup();
        let mut prev_covered_line: Option<u32> = None;
        for (line, member, ctx) in uses {
            let covered = comment_covers(f, line)
                || prev_covered_line.is_some_and(|p| line.saturating_sub(p) <= RUN_GAP);
            if covered {
                prev_covered_line = Some(line);
                continue;
            }
            prev_covered_line = None;
            let (message, hint) = if member == "SeqCst" {
                (
                    format!(
                        "Ordering::SeqCst without an adjacent justification comment (in `{}`)",
                        if ctx.is_empty() { "module scope" } else { &ctx }
                    ),
                    "relax to the weakest ordering that works and say why, or justify SeqCst \
                     in a comment within 3 lines"
                        .to_string(),
                )
            } else {
                (
                    format!(
                        "Ordering::{member} without an adjacent required-ordering comment \
                         (in `{}`)",
                        if ctx.is_empty() { "module scope" } else { &ctx }
                    ),
                    "state the pairing (what this synchronizes with) or why no \
                     synchronization is needed, within 3 lines of the use"
                        .to_string(),
                )
            };
            out.push(Diagnostic {
                lint: "atomic-ordering",
                file: f.path.clone(),
                line,
                context: ctx,
                callee: format!("Ordering::{member}"),
                message,
                hint,
            });
        }
    }
    out
}
