//! `ssdup check` — a zero-dependency static analysis pass over this
//! repository's own sources, encoding the live engine's documented
//! invariants as machine-checked lints (see `live/mod.rs` §Invariants):
//!
//! | lint              | invariant                                                |
//! |-------------------|----------------------------------------------------------|
//! | `lock-io`         | no device I/O while a shard core-lock guard is live      |
//! | `stats-wiring`    | every `ShardStats` counter reaches fold, report and emit |
//! | `stage-taxonomy`  | every `Stage` variant is booked and trace-check-required |
//! | `atomic-ordering` | every `Ordering::` use carries a required-ordering note  |
//! | `panic-free`      | no `unwrap`/`expect`/`panic!` on the fault path          |
//!
//! The pass is lexer-based ([`lexer`]): tokens with line, brace depth,
//! enclosing `fn`, and `#[cfg(test)]` region — deliberately not a type
//! checker. Exceptions live in `rust/src/analysis/allow.toml`
//! ([`allow`]): every entry carries a `note`, and entries that stop
//! matching become `allow-unused` diagnostics, so the exception list
//! can only shrink. CI runs `ssdup check` as a blocking job; the
//! self-test (`tests/analysis_selftest.rs`) pins each lint to a known-bad
//! fixture and asserts the real tree stays clean.

pub mod allow;
pub mod atomics;
pub mod diag;
pub mod lexer;
pub mod lock_io;
pub mod panic_free;
pub mod stages_lint;
pub mod stats_wiring;

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

use allow::AllowList;
use diag::Diagnostic;
use lexer::SourceFile;

/// Repo-relative location of the sources the pass scans.
const SRC_DIR: &str = "rust/src";
/// Repo-relative CI workflow parsed for `trace-check --require` lists.
const CI_FILE: &str = ".github/workflows/ci.yml";
/// Repo-relative allow-list.
const ALLOW_FILE: &str = "rust/src/analysis/allow.toml";

pub struct CheckOutcome {
    /// Diagnostics that survived the allow-list, sorted by file/line.
    pub diags: Vec<Diagnostic>,
    pub files_scanned: usize,
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lex every source under `root/rust/src`. Paths in the returned files
/// are repo-relative with forward slashes.
pub fn load_sources(root: &Path) -> Result<Vec<SourceFile>, String> {
    let src_root = root.join(SRC_DIR);
    let mut paths = Vec::new();
    rs_files(&src_root, &mut paths)?;
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let text = fs::read_to_string(&p).map_err(|e| format!("read {}: {e}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(lexer::lex_source(&rel, &text));
    }
    Ok(files)
}

/// Run every lint over the tree rooted at `root` (the repo checkout:
/// the directory holding `Cargo.toml` and `.github/`).
pub fn run_check(root: &Path) -> Result<CheckOutcome, String> {
    let files = load_sources(root)?;

    let ci_path = root.join(CI_FILE);
    let ci_text = fs::read_to_string(&ci_path)
        .map_err(|e| format!("read {} (needed for the stage drift guard): {e}", ci_path.display()))?;
    let required: BTreeSet<String> = stages_lint::parse_required_stages(&ci_text);

    let allow_path = root.join(ALLOW_FILE);
    let allow = match fs::read_to_string(&allow_path) {
        Ok(text) => AllowList::parse(ALLOW_FILE, &text)?,
        Err(_) => AllowList::empty(),
    };

    let mut raw: Vec<Diagnostic> = Vec::new();
    raw.extend(lock_io::check(&files));
    raw.extend(stats_wiring::check(&files));
    raw.extend(stages_lint::check(&files, &required));
    raw.extend(atomics::check(&files));
    raw.extend(panic_free::check(&files));

    let mut diags: Vec<Diagnostic> = raw.into_iter().filter(|d| !allow.permits(d)).collect();
    diags.extend(allow.unused());
    diag::sort(&mut diags);
    Ok(CheckOutcome { diags, files_scanned: files.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_required_stages_reads_comma_lists() {
        let yml = "run: |\n  x trace-check t.json \\\n    --require submit,route,replay\n  y trace-check u.json --require replay\n";
        let req = stages_lint::parse_required_stages(yml);
        assert!(req.contains("submit"));
        assert!(req.contains("route"));
        assert!(req.contains("replay"));
        assert_eq!(req.len(), 3);
    }
}
