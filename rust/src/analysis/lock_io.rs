//! Lint `lock-io`: no device I/O while a shard core-lock guard is live.
//!
//! The engine's ack-latency story (and the paper's log-structure
//! argument) depends on device I/O happening *outside* the per-shard
//! core mutex: ingest runs reserve→enqueue→publish, the flusher drops
//! the guard before its copy runs. A call into the backend while the
//! guard is held serializes every writer behind one device service
//! time — the exact regression this lint makes impossible to land
//! silently.
//!
//! Mechanics: device I/O entry points (`Backend::{write_at, read_at,
//! sync, …}`, `IoQueue::submit`, barrier waits) seed a taint set that
//! propagates up the same-crate call graph to a fixpoint. Call keys
//! separate method calls (`m:name`, the receiver has `self`) from
//! free/associated calls; the latter are qualified by the impl'd type
//! when the qualifier names one (`f:IoQueue::new` vs `f:Vec::new`,
//! `Self::` resolved through the enclosing `impl`), so std constructor
//! and container names don't inherit the crate's I/O taint. Same-name
//! methods still merge — an over-approximation that taints more, never
//! less.
//!
//! Guard liveness is tracked per function body: `let g =
//! …core.lock().unwrap();` bindings (the RHS must *end* with the
//! acquisition — a trailing field access or `.clone()` makes it a
//! temporary that dies at the `;`), `MutexGuard`/`&mut ShardCore`
//! parameters (the caller holds the lock; a by-value `ShardCore` is
//! just data), `drop(g)`, scope exit, liveness-preserving condvar
//! reassignment (`core = self.wait_or_err(…, core)?`), and move-out as
//! a bare call argument at the binding's own depth (deeper moves sit in
//! diverging error branches). Calls to tainted functions while a guard
//! is live — outside `#[cfg(test)]` — are diagnostics; the few
//! deliberate sites (the first-touch superblock write, `degrade`) live
//! in `allow.toml`.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::{SourceFile, Tok, TokKind, NO_FN};

/// Device-I/O entry points: taint seeds, by bare method name.
const SEEDS: &[&str] = &[
    "write_at",
    "read_at",
    "sync",
    "write_vectored_at",
    "write_vectored_raw",
    "submit",
    "barrier",
    "barrier_for",
];

/// Per-fn signature facts, aligned with [`SourceFile::fns`].
struct SigInfo {
    /// Any `self` in the parameter list — calls resolve as `m:name`.
    has_self: bool,
    /// `MutexGuard` or `&mut ShardCore` parameter: the caller holds the
    /// core lock for the whole body.
    guard_param: bool,
}

/// Scan every `fn` signature in keyword order (matching how the lexer
/// fills `fns`, which includes body-less trait method declarations).
fn sig_info(f: &SourceFile) -> Vec<SigInfo> {
    let toks = &f.toks;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].kind == TokKind::Ident
            && toks[i].text == "fn"
            && toks.get(i + 1).is_some_and(|t| t.kind == TokKind::Ident)
        {
            // find the param-list `(` outside generic `<…>` brackets
            // (`>` preceded by `-` is a return arrow, not a close)
            let mut j = i + 2;
            let mut angle = 0i32;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "<" => angle += 1,
                    ">" if toks[j - 1].text != "-" => angle = (angle - 1).max(0),
                    "(" if angle == 0 => break,
                    "{" => break,
                    ";" if angle == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            let mut has_self = false;
            let mut guard_param = false;
            if toks.get(j).is_some_and(|t| t.text == "(") {
                let mut depth = 0i32;
                while j < toks.len() {
                    let t = &toks[j];
                    if t.kind == TokKind::Punct {
                        match t.text.as_str() {
                            "(" => depth += 1,
                            ")" => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                    } else if t.kind == TokKind::Ident {
                        match t.text.as_str() {
                            "self" => has_self = true,
                            "MutexGuard" => guard_param = true,
                            "ShardCore"
                                if j >= 2
                                    && toks[j - 1].text == "mut"
                                    && toks[j - 2].text == "&" =>
                            {
                                guard_param = true
                            }
                            _ => {}
                        }
                    }
                    j += 1;
                }
            }
            out.push(SigInfo { has_self, guard_param });
            i = j;
        }
        i += 1;
    }
    debug_assert_eq!(out.len(), f.fns.len(), "sig scan out of step in {}", f.path);
    out
}

/// Parse `impl<…> Type<…>` / `impl<…> Trait<…> for Type<…>` starting at
/// the `impl` token: the impl'd type name and its `{` token index.
fn impl_target(toks: &[Tok], i: usize) -> (Option<&str>, Option<usize>) {
    let mut j = i + 1;
    let mut angle = 0i32;
    let mut first: Option<&str> = None;
    let mut target: Option<&str> = None;
    let mut after_for = false;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "<" => angle += 1,
                ">" if toks[j - 1].text != "-" => angle = (angle - 1).max(0),
                "{" | ";" if angle == 0 => break,
                _ => {}
            }
        } else if t.kind == TokKind::Ident && angle == 0 {
            match t.text.as_str() {
                "for" => after_for = true,
                "dyn" | "mut" => {}
                _ if after_for => {
                    if target.is_none() {
                        target = Some(&t.text);
                    }
                }
                _ => {
                    if first.is_none() {
                        first = Some(&t.text);
                    }
                }
            }
        }
        j += 1;
    }
    let brace = (j < toks.len() && toks[j].text == "{").then_some(j);
    (target.or(first), brace)
}

/// Every type name with an `impl` block anywhere in the crate.
fn crate_impl_types(files: &[SourceFile]) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for f in files {
        for i in 0..f.toks.len() {
            if f.toks[i].kind == TokKind::Ident && f.toks[i].text == "impl" {
                if let (Some(name), _) = impl_target(&f.toks, i) {
                    out.insert(name.to_string());
                }
            }
        }
    }
    out
}

/// Per-file call-graph facts: signature info, the taint key of each
/// defined fn, and the taint key of every call site by token index.
struct FileInfo {
    sig: Vec<SigInfo>,
    fn_keys: Vec<String>,
    calls: BTreeMap<usize, String>,
}

fn file_call_info(f: &SourceFile, impl_types: &BTreeSet<String>) -> FileInfo {
    let sig = sig_info(f);
    let toks = &f.toks;
    // (impl'd type, depth carried by its `{`/`}` tokens)
    let mut impl_stack: Vec<(String, u32)> = Vec::new();
    let mut fn_impl: Vec<Option<String>> = vec![None; f.fns.len()];
    let mut fn_idx = 0usize;
    let mut calls: BTreeMap<usize, String> = BTreeMap::new();
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Punct
            && t.text == "}"
            && impl_stack.last().is_some_and(|(_, d)| *d == t.depth)
        {
            impl_stack.pop();
            continue;
        }
        if t.kind != TokKind::Ident {
            continue;
        }
        if t.text == "impl" {
            if let (Some(name), Some(brace)) = impl_target(toks, i) {
                impl_stack.push((name.to_string(), toks[brace].depth));
            }
        } else if t.text == "fn" && toks.get(i + 1).is_some_and(|n| n.kind == TokKind::Ident) {
            if fn_idx < fn_impl.len() {
                fn_impl[fn_idx] = impl_stack.last().map(|(n, _)| n.clone());
            }
            fn_idx += 1;
        } else if toks.get(i + 1).is_some_and(|n| n.kind == TokKind::Punct && n.text == "(")
            && !(i > 0 && toks[i - 1].kind == TokKind::Ident && toks[i - 1].text == "fn")
        {
            let prev = if i > 0 { toks[i - 1].text.as_str() } else { "" };
            let key = if prev == "." {
                format!("m:{}", t.text)
            } else if prev == "::" {
                let mut qual = (i >= 2 && toks[i - 2].kind == TokKind::Ident)
                    .then(|| toks[i - 2].text.as_str());
                if qual == Some("Self") {
                    qual = impl_stack.last().map(|(n, _)| n.as_str());
                }
                match qual {
                    Some(q) if impl_types.contains(q) => format!("f:{q}::{}", t.text),
                    _ => format!("f:{}", t.text),
                }
            } else {
                format!("f:{}", t.text)
            };
            calls.insert(i, key);
        }
    }
    let mut fn_keys = Vec::with_capacity(f.fns.len());
    for (k, name) in f.fns.iter().enumerate() {
        fn_keys.push(if sig[k].has_self {
            format!("m:{name}")
        } else if let Some(ty) = &fn_impl[k] {
            format!("f:{ty}::{name}")
        } else {
            format!("f:{name}")
        });
    }
    FileInfo { sig, fn_keys, calls }
}

/// Build the tainted-function key set: seeds plus every fn whose body
/// calls a tainted key, to a fixpoint.
fn tainted_fns(files: &[SourceFile]) -> (BTreeSet<String>, Vec<FileInfo>) {
    let impl_types = crate_impl_types(files);
    let mut infos = Vec::with_capacity(files.len());
    let mut calls: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for f in files {
        let info = file_call_info(f, &impl_types);
        for (&i, key) in &info.calls {
            let fid = f.toks[i].fn_id;
            if fid != NO_FN {
                calls
                    .entry(info.fn_keys[fid as usize].clone())
                    .or_default()
                    .insert(key.clone());
            }
        }
        infos.push(info);
    }
    let mut tainted: BTreeSet<String> = SEEDS.iter().map(|s| format!("m:{s}")).collect();
    loop {
        let mut grew = false;
        for (fname, callees) in &calls {
            if !tainted.contains(fname) && callees.iter().any(|c| tainted.contains(c)) {
                tainted.insert(fname.clone());
                grew = true;
            }
        }
        if !grew {
            break;
        }
    }
    (tainted, infos)
}

struct Guard {
    name: String,
    /// Depth of the `let`; dead once depth drops below this.
    depth: u32,
    /// Token index before which moves of `name` are ignored (the RHS of
    /// a liveness-preserving reassignment like `core = wait_or_err(core)`).
    ignore_moves_until: usize,
}

/// Scan one file for tainted calls under a live core guard.
fn scan_file(f: &SourceFile, tainted: &BTreeSet<String>, info: &FileInfo, out: &mut Vec<Diagnostic>) {
    let mut guards: Vec<Guard> = Vec::new();
    let mut cur_fn = NO_FN;

    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if t.fn_id != cur_fn {
            cur_fn = t.fn_id;
            guards.clear();
        }
        guards.retain(|g| t.depth >= g.depth);
        if t.kind != TokKind::Ident {
            continue;
        }

        // new binding: `let [mut] name = …core.lock().unwrap();`
        if t.text == "let" {
            let mut j = i + 1;
            if f.toks.get(j).is_some_and(|t| t.text == "mut") {
                j += 1;
            }
            if let (Some(name_t), Some(eq)) = (f.toks.get(j), f.toks.get(j + 1)) {
                if name_t.kind == TokKind::Ident && eq.text == "=" {
                    let end = stmt_end(f, j + 2, t.depth);
                    if rhs_is_guard(&f.toks[j + 2..end]) {
                        guards.push(Guard {
                            name: name_t.text.clone(),
                            depth: t.depth,
                            ignore_moves_until: end,
                        });
                    }
                }
            }
        }

        // explicit release / liveness-preserving reassignment / move-out
        if let Some(gi) = guards.iter().position(|g| g.name == t.text) {
            let prev = i.checked_sub(1).map(|p| f.toks[p].text.as_str()).unwrap_or("");
            let next = f.toks.get(i + 1).map(|t| t.text.as_str()).unwrap_or("");
            if prev == "(" && i >= 2 && f.toks[i - 2].text == "drop" && next == ")" {
                guards.remove(gi);
            } else if next == "=" && f.toks.get(i + 2).map(|t| t.text.as_str()) != Some("=") {
                // `g = rhs;` — stays live if the rhs re-locks or re-waits
                // (condvar loops: `core = self.wait_or_err(core, …)`)
                let end = stmt_end(f, i + 2, f.toks[i].depth);
                let live = f.toks[i + 2..end].iter().any(|t| {
                    t.kind == TokKind::Ident && (t.text.starts_with("wait") || t.text == "lock")
                });
                if live {
                    guards[gi].ignore_moves_until = end;
                } else {
                    guards.remove(gi);
                }
            } else if i >= guards[gi].ignore_moves_until
                && (prev == "(" || prev == ",")
                && (next == "," || next == ")")
                && t.depth == guards[gi].depth
            {
                // moved out as a bare argument at the binding's own
                // depth: ownership (and release responsibility) went to
                // the callee. Deeper moves sit in diverging branches
                // (`return Err(self.fail_core(core, …))`) — the guard
                // stays live on the fall-through path.
                guards.remove(gi);
            }
        }

        // the actual check: tainted call while a guard is live
        let under_guard = !guards.is_empty()
            || (t.fn_id != NO_FN && info.sig[t.fn_id as usize].guard_param);
        if under_guard && !t.in_test {
            if let Some(key) = info.calls.get(&i) {
                if tainted.contains(key) {
                    let callee = key.rsplit(':').next().unwrap_or(key).to_string();
                    let ctx = f.fn_name(t).unwrap_or("?").to_string();
                    out.push(Diagnostic {
                        lint: "lock-io",
                        file: f.path.clone(),
                        line: t.line,
                        context: ctx.clone(),
                        callee: callee.clone(),
                        message: format!(
                            "`{callee}` reaches device I/O while the shard core lock is held (in `{ctx}`)"
                        ),
                        hint: "drop the core guard before device I/O (reserve under the lock, \
                               write outside it), or add an allow entry with the why"
                            .to_string(),
                    });
                }
            }
        }
    }
}

/// Index of the `;` ending the statement starting at `start`
/// (same-depth semicolon; nested parens/brackets are skipped).
fn stmt_end(f: &SourceFile, start: usize, depth: u32) -> usize {
    let mut paren = 0i32;
    for (off, t) in f.toks[start..].iter().enumerate() {
        if t.kind != TokKind::Punct {
            continue;
        }
        match t.text.as_str() {
            "(" | "[" => paren += 1,
            ")" | "]" => paren -= 1,
            ";" if paren <= 0 && t.depth == depth => return start + off,
            _ => {}
        }
    }
    f.toks.len()
}

/// Is this `let` RHS a core-lock *guard* acquisition — not a temporary?
/// It must mention `core.lock(` and **end** with the `.unwrap()` /
/// `.expect("…")` of that acquisition: `self.core.lock().unwrap().stats
/// .clone()` and `let sb = { let core = …lock().unwrap(); … }` both
/// fail the suffix test, and rightly so — their guards die at the `;`
/// (or inside the block), not at the binding's scope end.
fn rhs_is_guard(toks: &[Tok]) -> bool {
    let mentions_core_lock = toks.windows(4).any(|w| {
        w[0].kind == TokKind::Ident
            && w[0].text == "core"
            && w[1].text == "."
            && w[2].text == "lock"
            && w[3].text == "("
    });
    if !mentions_core_lock {
        return false;
    }
    let n = toks.len();
    let tx = |k: usize| toks[n - k].text.as_str();
    if n >= 5 && tx(4) == "." && tx(3) == "unwrap" && tx(2) == "(" && tx(1) == ")" && tx(5) == ")" {
        return true;
    }
    n >= 6
        && tx(6) == ")"
        && tx(5) == "."
        && tx(4) == "expect"
        && tx(3) == "("
        && toks[n - 2].kind == TokKind::Str
        && tx(1) == ")"
}

/// Run the lint: taint from all files, scan `live/` sources.
pub fn check(files: &[SourceFile]) -> Vec<Diagnostic> {
    let (tainted, infos) = tainted_fns(files);
    let mut out = Vec::new();
    for (f, info) in files.iter().zip(&infos) {
        if f.path.contains("live/") {
            scan_file(f, &tainted, info, &mut out);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex_source;

    fn lex(path: &str, src: &str) -> SourceFile {
        lex_source(path, src)
    }

    #[test]
    fn taint_propagates_through_helpers_and_guard_blocks_io() {
        let f = lex(
            "rust/src/live/x.rs",
            r#"
impl Shard {
    fn persist(&self) { self.dev.write_at(0, b""); }
    fn indirect(&self) { self.persist(); }
    fn bad(&self) {
        let mut core = self.core.lock().unwrap();
        self.indirect();
        core.n += 1;
    }
}
"#,
        );
        let diags = check(&[f]);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].callee, "indirect");
        assert_eq!(diags[0].context, "bad");
    }

    #[test]
    fn dropped_guard_and_temporaries_are_clean() {
        let f = lex(
            "rust/src/live/x.rs",
            r#"
impl Shard {
    fn persist(&self) { self.dev.write_at(0, b""); }
    fn ok(&self) {
        let mut core = self.core.lock().unwrap();
        core.n += 1;
        drop(core);
        self.persist();
        let snap = self.core.lock().unwrap().stats.clone();
        self.persist();
    }
}
"#,
        );
        assert!(check(&[f]).is_empty());
    }

    #[test]
    fn std_constructor_names_do_not_inherit_taint() {
        let f = lex(
            "rust/src/live/x.rs",
            r#"
impl IoQueue {
    fn new() -> Self { spawn(|| dev.write_at(0, b"")); Self {} }
}
impl Shard {
    fn ok(&self) {
        let mut core = self.core.lock().unwrap();
        let v = Vec::new();
        core.push(v);
    }
    fn bad(&self) {
        let mut core = self.core.lock().unwrap();
        let q = IoQueue::new();
        core.q = q;
    }
}
"#,
        );
        let diags = check(&[f]);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].context, "bad");
        assert_eq!(diags[0].callee, "new");
    }

    #[test]
    fn by_value_shard_core_param_is_not_a_guard() {
        let f = lex(
            "rust/src/live/x.rs",
            r#"
impl Shard {
    fn assemble(core: ShardCore, dev: Dev) -> Self { dev.sync(); Self { core } }
    fn degrade(&self, core: &mut ShardCore) { self.dev.sync(); }
}
"#,
        );
        let diags = check(&[f]);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].context, "degrade");
        assert_eq!(diags[0].callee, "sync");
    }
}
