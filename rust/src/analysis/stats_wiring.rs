//! Lint `stats-wiring`: every `ShardStats` counter must be wired end to
//! end — booked/folded in the shard (`fold`), surfaced in the run report
//! (`report`: `LiveReport` aggregation or the `ssdup live` per-shard
//! print), and emitted by the snapshot telemetry (`emit`:
//! `obs/snapshot.rs`). The conservation story (`buffered == flushed +
//! superseded`) only holds if a new counter cannot be declared and then
//! silently dropped on one of those paths — that exact drift happened
//! twice in review during PRs 7–9.
//!
//! Context key for the allow-list: `<field>.<check>` (e.g. `pct_sum.report`).

use std::collections::BTreeSet;

use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::{SourceFile, TokKind};

/// Where each check looks (path suffixes).
const FOLD_FILES: &[&str] = &["live/shard.rs"];
const REPORT_FILES: &[&str] = &["live/loadgen.rs", "src/main.rs"];
const EMIT_FILES: &[&str] = &["obs/snapshot.rs"];

struct Field {
    name: String,
    line: u32,
}

/// Parse `struct ShardStats { … }` field names out of the shard file.
/// Returns the fields and the token range of the declaration (so field
/// reads elsewhere in the same file can be told apart from the decl).
fn shard_stats_fields(f: &SourceFile) -> Option<(Vec<Field>, std::ops::Range<usize>)> {
    let toks = &f.toks;
    let start = toks.windows(3).position(|w| {
        w[0].kind == TokKind::Ident
            && w[0].text == "struct"
            && w[1].text == "ShardStats"
            && w[2].text == "{"
    })?;
    let body_depth = toks[start + 2].depth + 1;
    let mut fields = Vec::new();
    let mut i = start + 3;
    while i < toks.len() {
        let t = &toks[i];
        if t.text == "}" && t.depth < body_depth {
            break;
        }
        // a field is `name :` at body depth, not preceded by a path sep
        if t.kind == TokKind::Ident
            && t.depth == body_depth
            && t.text != "pub"
            && toks.get(i + 1).is_some_and(|n| n.text == ":")
            && (i == 0 || toks[i - 1].text != "::")
        {
            fields.push(Field { name: t.text.clone(), line: t.line });
        }
        i += 1;
    }
    Some((fields, start..i))
}

/// Does `name` occur as a non-test identifier in `f`, outside `skip`?
fn mentions(f: &SourceFile, name: &str, skip: Option<&std::ops::Range<usize>>) -> bool {
    f.toks.iter().enumerate().any(|(i, t)| {
        t.kind == TokKind::Ident
            && t.text == name
            && !t.in_test
            && skip.map_or(true, |r| !r.contains(&i))
    })
}

pub fn check(files: &[SourceFile]) -> Vec<Diagnostic> {
    let Some((shard, (fields, decl_range))) = files
        .iter()
        .find(|f| FOLD_FILES.iter().any(|s| f.path.ends_with(s)))
        .and_then(|f| shard_stats_fields(f).map(|r| (f, r)))
    else {
        return Vec::new();
    };

    let in_set = |f: &&SourceFile, set: &[&str]| set.iter().any(|s| f.path.ends_with(s));
    let report_files: Vec<&SourceFile> =
        files.iter().filter(|f| in_set(f, REPORT_FILES)).collect();
    let emit_files: Vec<&SourceFile> = files.iter().filter(|f| in_set(f, EMIT_FILES)).collect();

    let mut out = Vec::new();
    let mut seen = BTreeSet::new();
    for field in &fields {
        if !seen.insert(field.name.clone()) {
            continue;
        }
        let checks: [(&str, bool, &str); 3] = [
            (
                "fold",
                mentions(shard, &field.name, Some(&decl_range)),
                "book it on the hot path and sum it in `Shard::stats`",
            ),
            (
                "report",
                report_files.iter().any(|f| mentions(f, &field.name, None)),
                "aggregate it on `LiveReport` or print it in the `ssdup live` per-shard line",
            ),
            (
                "emit",
                emit_files.iter().any(|f| mentions(f, &field.name, None)),
                "fold it into `Counters::from_stats` and emit it from `Snapshotter::tick`",
            ),
        ];
        for (check, ok, hint) in checks {
            if !ok {
                out.push(Diagnostic {
                    lint: "stats-wiring",
                    file: shard.path.clone(),
                    line: field.line,
                    context: format!("{}.{check}", field.name),
                    callee: String::new(),
                    message: format!(
                        "ShardStats counter `{}` never reaches the {check} path — it would \
                         accumulate and silently vanish",
                        field.name
                    ),
                    hint: hint.to_string(),
                });
            }
        }
    }
    out
}
