//! Lint `panic-free`: the fault pipeline must degrade, not die.
//! `unwrap` / `expect` / `panic!` (and friends) are banned outside
//! `#[cfg(test)]` in the files that sit under the ack — `live/fault.rs`,
//! `live/backend.rs`, `live/shard.rs` — because a panic there poisons
//! the core mutex and turns one transient EIO into a wedged shard
//! (PR 8's typed-fault contract: every error is retried, degraded
//! around, or surfaced as `IoFault`).
//!
//! Built-in exemption: `.unwrap()` directly on `lock()` / `wait()` /
//! `wait_timeout()` results. Lock poisoning only happens after another
//! thread already panicked — unwrapping there is the idiomatic
//! poison-propagation pattern, not a new failure mode. Everything else
//! needs an `allow.toml` entry naming its why (context = enclosing fn).

use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::{SourceFile, TokKind};

const FILES: &[&str] = &["live/fault.rs", "live/backend.rs", "live/shard.rs"];

const MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Poison-propagation receivers exempt from the `.unwrap()` ban.
const POISON_FNS: &[&str] = &["lock", "wait", "wait_timeout", "wait_while"];

/// If token `i` is `.`, and `i+1`/`i+2` are `unwrap|expect (`, check the
/// receiver: exempt when it is a direct `lock()`/`wait*()` call.
fn poison_exempt(f: &SourceFile, dot: usize) -> bool {
    // receiver ends at dot-1; exempt iff it is `name( … )` with a
    // poison-returning name
    let toks = &f.toks;
    if dot == 0 || toks[dot - 1].text != ")" {
        return false;
    }
    // walk back to the matching `(`
    let mut depth = 0i32;
    let mut j = dot - 1;
    loop {
        match toks[j].text.as_str() {
            ")" | "]" if toks[j].kind == TokKind::Punct => depth += 1,
            "(" | "[" if toks[j].kind == TokKind::Punct => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
    j > 0
        && toks[j - 1].kind == TokKind::Ident
        && POISON_FNS.contains(&toks[j - 1].text.as_str())
}

pub fn check(files: &[SourceFile]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in files {
        if !FILES.iter().any(|s| f.path.ends_with(s)) {
            continue;
        }
        let toks = &f.toks;
        for i in 0..toks.len() {
            let t = &toks[i];
            if t.in_test || t.kind != TokKind::Ident {
                continue;
            }
            let ctx = || f.fn_name(t).unwrap_or("module scope").to_string();
            // `panic!(…)` and friends
            if MACROS.contains(&t.text.as_str())
                && toks.get(i + 1).is_some_and(|n| n.text == "!")
            {
                out.push(Diagnostic {
                    lint: "panic-free",
                    file: f.path.clone(),
                    line: t.line,
                    context: ctx(),
                    callee: format!("{}!", t.text),
                    message: format!(
                        "`{}!` on the fault path (in `{}`) — a panic here poisons the shard \
                         instead of degrading it",
                        t.text,
                        ctx()
                    ),
                    hint: "return a typed `IoFault`/`io::Error` and let the retry/degrade \
                           machinery absorb it"
                        .to_string(),
                });
            }
            // `.unwrap()` / `.expect(`
            if (t.text == "unwrap" || t.text == "expect")
                && i > 0
                && toks[i - 1].text == "."
                && toks.get(i + 1).is_some_and(|n| n.text == "(")
                && !poison_exempt(f, i - 1)
            {
                out.push(Diagnostic {
                    lint: "panic-free",
                    file: f.path.clone(),
                    line: t.line,
                    context: ctx(),
                    callee: t.text.clone(),
                    message: format!(
                        "`.{}()` on the fault path (in `{}`) — convert to a typed error or \
                         allow-list the invariant it asserts",
                        t.text,
                        ctx()
                    ),
                    hint: "poison-propagating `.lock()/.wait*()` unwraps are exempt; anything \
                           else returns `IoFault` or documents itself in allow.toml"
                        .to_string(),
                });
            }
        }
    }
    out
}
