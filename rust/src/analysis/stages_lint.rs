//! Lint `stage-taxonomy`: every `obs/stages.rs` `Stage::` variant must
//! (a) be booked at at least one non-test call site in the engine, and
//! (b) appear in a `trace-check --require` list in the CI workflow —
//! the drift guard that makes "added a stage, forgot the smoke"
//! a lint error instead of a review catch. Scheduling-dependent stages
//! that CI cannot require deterministically (`flush_pause`,
//! `fault_retry`) are allow-listed with their why.
//!
//! Context keys for the allow-list: `<Variant>.booked` and
//! `<snake_name>.require`.

use std::collections::BTreeSet;

use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::{SourceFile, TokKind};

const STAGES_FILE: &str = "obs/stages.rs";

struct Variant {
    name: String,
    line: u32,
    /// snake_case wire name from the `Stage::name()` match arm.
    snake: Option<String>,
}

/// Parse the `enum Stage` variants and their `name()` string mapping.
fn parse_variants(f: &SourceFile) -> Vec<Variant> {
    let toks = &f.toks;
    let mut out: Vec<Variant> = Vec::new();
    if let Some(start) = toks.windows(3).position(|w| {
        w[0].kind == TokKind::Ident
            && w[0].text == "enum"
            && w[1].text == "Stage"
            && w[2].text == "{"
    }) {
        let body_depth = toks[start + 2].depth + 1;
        for i in start + 3..toks.len() {
            let t = &toks[i];
            if t.text == "}" && t.depth < body_depth {
                break;
            }
            // a variant is `Name ,` / `Name }` / `Name = <discr>` at
            // body depth (the lexer skips number literals, so the
            // discriminant shows up as the bare `=`)
            if t.kind == TokKind::Ident && t.depth == body_depth {
                let next = toks.get(i + 1).map(|n| n.text.as_str()).unwrap_or("");
                if next == "," || next == "}" || next == "=" {
                    out.push(Variant { name: t.text.clone(), line: t.line, snake: None });
                }
            }
        }
    }
    // match arms: `Stage::Variant => "snake_name"`
    for i in 0..toks.len() {
        if toks[i].text == "Stage"
            && toks.get(i + 1).is_some_and(|t| t.text == "::")
            && toks.get(i + 2).is_some_and(|t| t.kind == TokKind::Ident)
            && toks.get(i + 3).is_some_and(|t| t.text == "=")
            && toks.get(i + 4).is_some_and(|t| t.text == ">")
            && toks.get(i + 5).is_some_and(|t| t.kind == TokKind::Str)
        {
            let vname = &toks[i + 2].text;
            let sname = &toks[i + 5].text;
            if let Some(v) = out.iter_mut().find(|v| &v.name == vname) {
                v.snake = Some(sname.clone());
            }
        }
    }
    out
}

/// Union of every `--require a,b,c` list in the CI workflow text.
pub fn parse_required_stages(ci_yml: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in ci_yml.lines() {
        let mut words = line.split_whitespace().peekable();
        while let Some(w) = words.next() {
            if w == "--require" {
                if let Some(list) = words.peek() {
                    for name in list.split(',') {
                        let name = name.trim().trim_end_matches('\\');
                        if !name.is_empty() {
                            out.insert(name.to_string());
                        }
                    }
                }
            }
        }
    }
    out
}

pub fn check(files: &[SourceFile], ci_required: &BTreeSet<String>) -> Vec<Diagnostic> {
    let Some(stages) = files.iter().find(|f| f.path.ends_with(STAGES_FILE)) else {
        return Vec::new();
    };
    let variants = parse_variants(stages);
    let mut out = Vec::new();
    for v in &variants {
        let booked = files.iter().filter(|f| !f.path.ends_with(STAGES_FILE)).any(|f| {
            f.toks.iter().enumerate().any(|(i, t)| {
                t.text == "Stage"
                    && !t.in_test
                    && f.toks.get(i + 1).is_some_and(|n| n.text == "::")
                    && f.toks.get(i + 2).is_some_and(|n| n.text == v.name)
            })
        });
        if !booked {
            out.push(Diagnostic {
                lint: "stage-taxonomy",
                file: stages.path.clone(),
                line: v.line,
                context: format!("{}.booked", v.name),
                callee: String::new(),
                message: format!(
                    "Stage::{} is declared but never booked at a non-test call site — \
                     dead taxonomy skews every per-stage report",
                    v.name
                ),
                hint: "book it with `book_spans`/`span` on the path it describes, or delete it"
                    .to_string(),
            });
        }
        match &v.snake {
            None => out.push(Diagnostic {
                lint: "stage-taxonomy",
                file: stages.path.clone(),
                line: v.line,
                context: format!("{}.booked", v.name),
                callee: String::new(),
                message: format!("Stage::{} has no `name()` match arm", v.name),
                hint: "add the snake_case wire name so traces and trace-check can see it"
                    .to_string(),
            }),
            Some(snake) => {
                if !ci_required.contains(snake) {
                    out.push(Diagnostic {
                        lint: "stage-taxonomy",
                        file: stages.path.clone(),
                        line: v.line,
                        context: format!("{snake}.require"),
                        callee: String::new(),
                        message: format!(
                            "stage `{snake}` is missing from every `trace-check --require` \
                             list in .github/workflows/ci.yml — the trace smoke would not \
                             notice it going silent"
                        ),
                        hint: "add it to the traced-live-run --require list, or allow-list it \
                               with the reason CI cannot observe it deterministically"
                            .to_string(),
                    });
                }
            }
        }
    }
    out
}
