//! Diagnostic model for `ssdup check`: stable `file:line: [lint]`
//! text rendering plus a machine-readable JSON form (`--json`).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// One lint finding, addressable by the allow-list via
/// `(lint, file, context, callee)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Lint slug: `lock-io`, `stats-wiring`, `stage-taxonomy`,
    /// `atomic-ordering`, `panic-free`, `allow-unused`.
    pub lint: &'static str,
    /// Repo-relative path of the offending file.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// Allow-list key: enclosing fn for code lints, `field.check` /
    /// `stage.check` for the wiring lints. Empty when not applicable.
    pub context: String,
    /// Allow-list key: the offending callee/token. Empty when N/A.
    pub callee: String,
    pub message: String,
    /// Suggested fix, shown under `--fix-hints` (always present in JSON).
    pub hint: String,
}

impl Diagnostic {
    pub fn render(&self, fix_hints: bool) -> String {
        let mut s = format!("{}:{}: [{}] {}", self.file, self.line, self.lint, self.message);
        if fix_hints && !self.hint.is_empty() {
            s.push_str(&format!("\n    hint: {}", self.hint));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(BTreeMap::from([
            ("lint".to_string(), Json::Str(self.lint.to_string())),
            ("file".to_string(), Json::Str(self.file.clone())),
            ("line".to_string(), Json::Num(self.line as f64)),
            ("context".to_string(), Json::Str(self.context.clone())),
            ("callee".to_string(), Json::Str(self.callee.clone())),
            ("message".to_string(), Json::Str(self.message.clone())),
            ("hint".to_string(), Json::Str(self.hint.clone())),
        ]))
    }
}

/// Sort diagnostics for stable output: file, then line, then lint.
pub fn sort(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.lint).cmp(&(b.file.as_str(), b.line, b.lint))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_json() {
        let d = Diagnostic {
            lint: "lock-io",
            file: "rust/src/live/shard.rs".into(),
            line: 42,
            context: "submit".into(),
            callee: "write_at".into(),
            message: "device I/O under the core lock".into(),
            hint: "drop the guard first".into(),
        };
        assert_eq!(
            d.render(false),
            "rust/src/live/shard.rs:42: [lock-io] device I/O under the core lock"
        );
        assert!(d.render(true).contains("hint: drop the guard first"));
        let j = d.to_json();
        assert_eq!(j.get("line").and_then(|v| v.as_i64()), Some(42));
        assert_eq!(j.get("callee").and_then(|v| v.as_str()), Some("write_at"));
    }
}
