//! # SSDUP+ — traffic-aware SSD burst buffer
//!
//! Rust + JAX/Pallas reproduction of *Optimizing the SSD Burst Buffer by
//! Traffic Detection* (Shi et al.), grown into a runnable burst-buffer
//! system. The crate hosts two execution substrates over one set of
//! mechanism components:
//!
//! * **Simulation** — a deterministic discrete-event cluster ([`sim`],
//!   [`server`], [`device`]) that reproduces the paper's tables and
//!   figures ([`experiments`]);
//! * **Live engine** ([`live`]) — a real-time, multi-threaded runtime:
//!   N shards, each with its own detector, routing policy, two-region
//!   pipelined SSD log, a background flusher implementing the
//!   traffic-aware pause gate, and a sector-ownership map that makes
//!   overwrites safe across routes (stale buffered copies are superseded
//!   and skipped at flush; reads serve the newest copy mid-burst), over
//!   pluggable in-memory or real-file storage backends (`ssdup live`).
//!
//! Both substrates share the paper's mechanisms:
//!
//! * [`detector`] — request-stream grouping + random-factor scoring
//!   (§2.2). The scoring math is authored as JAX/Pallas kernels
//!   (`python/compile/`), AOT-lowered to HLO and executed via PJRT when
//!   the `pjrt` feature is on; a bit-exact native Rust mirror covers the
//!   hot loop and offline builds;
//! * [`redirector`] — per-stream SSD/HDD routing: the paper's adaptive
//!   threshold (Algorithm 1) plus the SSDUP/OrangeFS baselines (§2.3);
//! * [`buffer`] — log-structured appends, AVL metadata, and the
//!   two-region flush pipeline (§2.4–2.5);
//! * [`fs`], [`workload`], [`util`] — OrangeFS-like striping, the
//!   paper's benchmark workloads, and the in-tree substrate (PRNG, JSON,
//!   CLI, bench harness, thread pool) the offline image can't pull from
//!   crates.io;
//! * [`obs`] — zero-dependency observability for the live engine:
//!   lock-free tracing (Chrome-trace export), per-stage ack-latency
//!   attribution, and interval snapshot telemetry;
//! * [`analysis`] — `ssdup check`, a lexer-based static analyzer that
//!   enforces the live engine's invariants (lock discipline, stats
//!   wiring, stage taxonomy, atomic-ordering notes, panic-free fault
//!   path) over this repository's own sources, run as a blocking CI job.
//!
//! Start at [`live`] for the running system, [`server`] for the simulated
//! I/O node, or [`experiments`] for the paper's tables and figures.

pub mod device;
pub mod fs;
pub mod sim;
pub mod types;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

pub mod analysis;
pub mod buffer;
pub mod detector;
pub mod experiments;
pub mod live;
pub mod obs;
pub mod redirector;
pub mod runtime;
pub mod server;
pub mod workload;
