//! # SSDUP+ — traffic-aware SSD burst buffer (paper reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *Optimizing the SSD
//! Burst Buffer by Traffic Detection* (Shi et al.). The Rust layer (L3)
//! hosts the paper's coordination contribution — request-stream detection,
//! adaptive redirection, two-region pipelined flushing, AVL-tree buffer
//! metadata — plus every substrate the evaluation needs (simulated
//! HDD/SSD, an OrangeFS-like striping layer, workload generators, a
//! deterministic DES engine). The per-stream analytics execute as an
//! AOT-compiled XLA module authored in JAX/Pallas (see `python/compile/`);
//! Python never runs on the request path.
//!
//! Start at [`server`] for the SSDUP+ I/O-node implementation, or
//! [`experiments`] for the paper's tables and figures.

pub mod device;
pub mod fs;
pub mod sim;
pub mod types;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

pub mod buffer;
pub mod detector;
pub mod redirector;
pub mod runtime;
pub mod server;
pub mod workload;
pub mod experiments;
