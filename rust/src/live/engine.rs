//! The live burst-buffer engine: N shards behind an OrangeFS-style stripe.
//!
//! Each shard is the live counterpart of one simulated I/O node (same
//! striping, same detection feed, same routing policies), so a
//! `LiveEngine` with `shards = K` is directly comparable to
//! `sim::simulate` with `nodes = K` — the parity tests lean on that.
//! Clients call [`LiveEngine::submit`] from any number of threads; each
//! logical request is split into per-shard sub-requests that carry the
//! matching slice of the payload. Requests return when every byte is on a
//! backend (SSD log or HDD), and [`LiveEngine::drain`] settles all
//! buffered data onto the HDD backends.

use std::collections::HashMap;
use std::io;
use std::path::Path;
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::device::SeekModel;
use crate::fs::StripeLayout;
use crate::live::backend::{Backend, FileBackend, MemBackend, SyntheticLatency};
use crate::live::fault::FaultSpec;
use crate::live::flushsched::FlushCoordinator;
use crate::live::payload;
use crate::live::shard::{ReadError, Shard, ShardConfig, ShardRecovery, ShardStats, SubmitError};
use crate::obs::{StageSet, TraceCollector, DEFAULT_RING_EVENTS};
use crate::server::config::SystemKind;
use crate::types::{mib_to_sectors, Request, SECTOR_BYTES};
use crate::workload::Workload;

/// Live-engine configuration. Defaults mirror the simulator's testbed
/// shape (64 KB stripes, CFQ-depth-128 streams, SSDUP+ policies) with a
/// 1 GiB per-shard SSD budget.
#[derive(Clone, Copy, Debug)]
pub struct LiveConfig {
    pub system: SystemKind,
    pub shards: usize,
    pub stripe_sectors: i32,
    pub stream_len: usize,
    /// per-shard SSD buffer capacity in sectors (two regions of half)
    pub ssd_capacity_sectors: i64,
    pub pause_below: f32,
    pub history: usize,
    pub flush_check: Duration,
    pub seek: SeekModel,
    /// group commit: concurrent publishers of a shard share device sync
    /// barriers instead of issuing one fsync per record (`false` = the
    /// ungrouped per-record-sync baseline; the durability contract is
    /// identical either way)
    pub group_commit: bool,
    /// how long an elected group-commit leader waits for in-flight
    /// writes to land before syncing. Zero (the default) batches only
    /// what naturally accumulates behind a running sync; a small window
    /// trades ack latency for bigger batches. A lone writer is never
    /// delayed — with nothing in flight the leader syncs immediately.
    pub group_commit_window: Duration,
    /// create the engine's trace collector *enabled*: every pipeline
    /// stage emits span events (`ssdup live --trace out.json`). Off by
    /// default — a disabled collector costs one atomic load per span.
    pub trace: bool,
    /// I/O worker threads per device queue (`--io-workers`): the small
    /// pool driving each shard's submission queue, N ≪ clients
    pub io_workers: usize,
    /// per-device submission-queue depth (`--io-depth`): max
    /// admitted-but-incomplete requests before enqueue backpressure
    pub io_depth: usize,
    /// how many shards may run flush copy runs concurrently against the
    /// shared HDD tier (`--flush-concurrency`). The flush coordinator
    /// grants tokens to the fullest/stalest logs first; `0` disables
    /// coordination entirely (every flusher free-runs, the pre-scheduler
    /// baseline)
    pub flush_concurrency: usize,
    /// bounded age window (`--hot-defer-window`) inside which a flusher
    /// defers a region whose queued extents are mostly *hot* (recently
    /// rewritten), betting the next rewrite supersedes them in the
    /// buffer. `Duration::ZERO` (the default) disables deferral
    pub hot_defer_window: Duration,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self::new(SystemKind::SsdupPlus)
    }
}

impl LiveConfig {
    pub fn new(system: SystemKind) -> Self {
        Self {
            system,
            shards: 4,
            stripe_sectors: 128,
            stream_len: 128,
            ssd_capacity_sectors: mib_to_sectors(1024),
            pause_below: 0.45,
            history: 64,
            flush_check: Duration::from_millis(20),
            seek: SeekModel::default(),
            group_commit: true,
            group_commit_window: Duration::ZERO,
            trace: false,
            io_workers: 4,
            io_depth: 64,
            flush_concurrency: 2,
            hot_defer_window: Duration::ZERO,
        }
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        assert!(shards >= 1, "need at least one shard");
        self.shards = shards;
        self
    }

    pub fn with_ssd_mib(mut self, mib: u64) -> Self {
        self.ssd_capacity_sectors = mib_to_sectors(mib);
        self
    }

    pub fn with_stream_len(mut self, len: usize) -> Self {
        self.stream_len = len;
        self
    }

    /// Toggle group commit (`false` = per-record fsync baseline).
    pub fn with_group_commit(mut self, on: bool) -> Self {
        self.group_commit = on;
        self
    }

    /// Batching window for elected group-commit leaders.
    pub fn with_group_commit_window(mut self, window: Duration) -> Self {
        self.group_commit_window = window;
        self
    }

    /// Enable trace-event collection from construction on (so recovery
    /// replay spans are captured too).
    pub fn with_trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    /// I/O worker threads per device queue.
    pub fn with_io_workers(mut self, workers: usize) -> Self {
        assert!(workers >= 1, "need at least one io worker");
        self.io_workers = workers;
        self
    }

    /// Per-device submission-queue depth (in-flight request bound).
    pub fn with_io_depth(mut self, depth: usize) -> Self {
        assert!(depth >= 1, "need a queue depth of at least one");
        self.io_depth = depth;
        self
    }

    /// Concurrent-flush budget over the shared HDD tier (`0` = no
    /// coordinator, uncoordinated free-running flushers).
    pub fn with_flush_concurrency(mut self, budget: usize) -> Self {
        self.flush_concurrency = budget;
        self
    }

    /// Hot-extent deferral window (`Duration::ZERO` = off).
    pub fn with_hot_defer_window(mut self, window: Duration) -> Self {
        self.hot_defer_window = window;
        self
    }

    fn shard_config(&self, shard_id: usize) -> ShardConfig {
        ShardConfig {
            system: self.system,
            shard_id: shard_id as u32,
            ssd_capacity_sectors: self.ssd_capacity_sectors,
            stream_len: self.stream_len,
            pause_below: self.pause_below,
            history: self.history,
            flush_check: self.flush_check,
            seek: self.seek,
            group_commit: self.group_commit,
            group_commit_window: self.group_commit_window,
            io_workers: self.io_workers,
            io_depth: self.io_depth,
            hot_defer_window: self.hot_defer_window,
        }
    }
}

/// Aggregate of what [`LiveEngine::open`] recovered, one entry per shard.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    pub shards: Vec<ShardRecovery>,
}

impl RecoveryReport {
    /// Every shard reopened via the clean-shutdown short circuit.
    pub fn clean(&self) -> bool {
        self.shards.iter().all(|s| s.clean)
    }

    pub fn records_replayed(&self) -> u64 {
        self.shards.iter().map(|s| s.records_replayed).sum()
    }

    pub fn records_skipped(&self) -> u64 {
        self.shards.iter().map(|s| s.records_skipped).sum()
    }

    pub fn torn_discarded(&self) -> u64 {
        self.shards.iter().map(|s| s.torn_discarded).sum()
    }

    pub fn bytes_recovered(&self) -> u64 {
        self.shards.iter().map(|s| s.bytes_recovered).sum()
    }

    pub fn sectors_scanned(&self) -> i64 {
        self.shards.iter().map(|s| s.sectors_scanned).sum()
    }

    pub fn summary(&self) -> String {
        format!(
            "recovery: {} | {} records replayed ({} MiB), {} settled-skipped, {} torn stretches \
             discarded, {} sectors scanned over {} shards",
            if self.clean() { "clean (no scan)" } else { "dirty (log replay)" },
            self.records_replayed(),
            self.bytes_recovered() / (1 << 20),
            self.records_skipped(),
            self.torn_discarded(),
            self.sectors_scanned(),
            self.shards.len(),
        )
    }
}

/// Outcome of [`LiveEngine::verify_workload`].
#[derive(Clone, Copy, Debug, Default)]
pub struct VerifyReport {
    pub checked_bytes: u64,
    pub mismatched_sectors: u64,
    /// Sub-ranges the verifier could not read back (device faults that
    /// survived the retry budget). Unreadable ≠ mismatched, but either
    /// fails [`VerifyReport::is_ok`].
    pub read_errors: u64,
}

impl VerifyReport {
    pub fn is_ok(&self) -> bool {
        self.mismatched_sectors == 0 && self.read_errors == 0
    }
}

/// Map a shard-local sector back to its logical file sector — the inverse
/// of the round-robin stripe mapping (shared by payload gather + verify).
#[inline]
fn logical_sector(stripe: &StripeLayout, node: usize, local: i64) -> i64 {
    let s = stripe.stripe_sectors as i64;
    ((local / s) * stripe.n_nodes as i64 + node as i64) * s + (local % s)
}

pub struct LiveEngine {
    shards: Vec<Arc<Shard>>,
    flushers: Vec<JoinHandle<()>>,
    stripe: StripeLayout,
    /// one collector for all shards (and their group-commit sequencers);
    /// clone the `Arc` before `shutdown` to drain events afterwards
    obs: Arc<TraceCollector>,
    /// the shared flush coordinator (`None` when `flush_concurrency = 0`)
    /// — held for telemetry: token holders and the occupancy map
    sched: Option<Arc<FlushCoordinator>>,
}

impl LiveEngine {
    fn collector(cfg: &LiveConfig) -> Arc<TraceCollector> {
        let obs = Arc::new(TraceCollector::new(DEFAULT_RING_EVENTS));
        obs.set_enabled(cfg.trace);
        obs
    }

    fn coordinator(cfg: &LiveConfig) -> Option<Arc<FlushCoordinator>> {
        (cfg.flush_concurrency > 0)
            .then(|| Arc::new(FlushCoordinator::new(cfg.flush_concurrency, cfg.shards)))
    }

    /// Build an engine over caller-provided `(ssd, hdd)` backend pairs.
    pub fn with_backends(
        cfg: &LiveConfig,
        mut backends: impl FnMut(usize) -> (Box<dyn Backend>, Box<dyn Backend>),
    ) -> Self {
        assert!(cfg.shards >= 1, "need at least one shard");
        let obs = Self::collector(cfg);
        let sched = Self::coordinator(cfg);
        let mut shards = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let (ssd, hdd) = backends(i);
            let mut shard = Shard::new_with_obs(&cfg.shard_config(i), ssd, hdd, Arc::clone(&obs));
            if let Some(co) = &sched {
                shard = shard.with_coordinator(Arc::clone(co));
            }
            shards.push(Arc::new(shard));
        }
        Self::spawn_flushers(cfg, shards, obs, sched)
    }

    /// Reopen an engine over backends holding a previous run's state —
    /// the crash-recovery path (see [`Shard::recover`]). The topology
    /// (`shards`, `ssd_capacity_sectors`) must match the run that wrote
    /// the backends: records and superblocks are stamped with their
    /// shard id, and a mismatched layout is rejected or scans empty.
    ///
    /// Clean shutdowns short-circuit (no log scan); dirty reopens replay
    /// every surviving acknowledged write, which then drains through the
    /// normal flush path. Either way the engine accepts new submits.
    pub fn open(
        cfg: &LiveConfig,
        mut backends: impl FnMut(usize) -> (Box<dyn Backend>, Box<dyn Backend>),
    ) -> io::Result<(Self, RecoveryReport)> {
        assert!(cfg.shards >= 1, "need at least one shard");
        let obs = Self::collector(cfg);
        let sched = Self::coordinator(cfg);
        let mut shards = Vec::with_capacity(cfg.shards);
        let mut report = RecoveryReport::default();
        for i in 0..cfg.shards {
            let (ssd, hdd) = backends(i);
            let (mut shard, rec) =
                Shard::recover_with_obs(&cfg.shard_config(i), ssd, hdd, Arc::clone(&obs))?;
            if let Some(co) = &sched {
                shard = shard.with_coordinator(Arc::clone(co));
            }
            report.shards.push(rec);
            shards.push(Arc::new(shard));
        }
        Ok((Self::spawn_flushers(cfg, shards, obs, sched), report))
    }

    fn spawn_flushers(
        cfg: &LiveConfig,
        shards: Vec<Arc<Shard>>,
        obs: Arc<TraceCollector>,
        sched: Option<Arc<FlushCoordinator>>,
    ) -> Self {
        let stripe = StripeLayout { stripe_sectors: cfg.stripe_sectors, n_nodes: cfg.shards };
        let mut flushers = Vec::with_capacity(shards.len());
        for (i, shard) in shards.iter().enumerate() {
            let worker = Arc::clone(shard);
            flushers.push(
                thread::Builder::new()
                    .name(format!("ssdup-flusher-{i}"))
                    .spawn(move || worker.flusher_loop())
                    .expect("spawn flusher thread"),
            );
        }
        Self { shards, flushers, stripe, obs, sched }
    }

    /// Per-shard fault seed: one base seed fans out into independent but
    /// reproducible injection streams (the SSD/HDD split happens inside
    /// [`FaultSpec::wrap_hdd`]).
    fn fault_seed(seed: u64, shard: usize) -> u64 {
        seed.wrapping_add((shard as u64).wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Wrap one shard's backend pair in scripted fault injectors
    /// (identity when `spec` has no clauses for a tier).
    fn wrap_faults(
        spec: &FaultSpec,
        seed: u64,
        shard: usize,
        ssd: Box<dyn Backend>,
        hdd: Box<dyn Backend>,
    ) -> (Box<dyn Backend>, Box<dyn Backend>) {
        let s = Self::fault_seed(seed, shard);
        (spec.wrap_ssd(ssd, s), spec.wrap_hdd(hdd, s))
    }

    /// All-in-memory engine (unit tests, benches).
    pub fn mem(cfg: &LiveConfig, ssd_latency: SyntheticLatency, hdd_latency: SyntheticLatency) -> Self {
        Self::mem_faulty(cfg, ssd_latency, hdd_latency, &FaultSpec::default(), 0)
    }

    /// [`LiveEngine::mem`] with scripted fault injection on the backends
    /// (`ssdup live --fault-spec`): every shard gets its own seeded
    /// injector pair so runs are reproducible.
    pub fn mem_faulty(
        cfg: &LiveConfig,
        ssd_latency: SyntheticLatency,
        hdd_latency: SyntheticLatency,
        spec: &FaultSpec,
        seed: u64,
    ) -> Self {
        Self::with_backends(cfg, |i| {
            Self::wrap_faults(
                spec,
                seed,
                i,
                Box::new(MemBackend::new(ssd_latency)),
                Box::new(MemBackend::new(hdd_latency)),
            )
        })
    }

    /// Real-file engine: per shard, an SSD log file and a sparse HDD image
    /// under `dir`.
    pub fn file(cfg: &LiveConfig, dir: &Path) -> io::Result<Self> {
        Self::file_faulty(cfg, dir, &FaultSpec::default(), 0)
    }

    /// [`LiveEngine::file`] with scripted fault injection on the backends.
    pub fn file_faulty(cfg: &LiveConfig, dir: &Path, spec: &FaultSpec, seed: u64) -> io::Result<Self> {
        // create all backends up front so I/O errors surface before any
        // flusher thread spawns
        let mut pairs = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let ssd = FileBackend::create(&dir.join(format!("shard{i}-ssd.log")))?;
            let hdd = FileBackend::create(&dir.join(format!("shard{i}-hdd.img")))?;
            pairs.push(Self::wrap_faults(spec, seed, i, Box::new(ssd), Box::new(hdd)));
        }
        let mut pairs = pairs.into_iter();
        Ok(Self::with_backends(cfg, move |_| pairs.next().expect("one backend pair per shard")))
    }

    /// Reopen a previous [`LiveEngine::file`] run's images under `dir`
    /// *without truncating them* and recover: `ssdup live --recover`.
    pub fn open_file(cfg: &LiveConfig, dir: &Path) -> io::Result<(Self, RecoveryReport)> {
        Self::open_file_faulty(cfg, dir, &FaultSpec::default(), 0)
    }

    /// [`LiveEngine::open_file`] with scripted fault injection — recovery
    /// itself (superblock reads, log scans) runs through the injectors
    /// too, so crash-under-faults drills exercise the replay path.
    pub fn open_file_faulty(
        cfg: &LiveConfig,
        dir: &Path,
        spec: &FaultSpec,
        seed: u64,
    ) -> io::Result<(Self, RecoveryReport)> {
        let mut pairs = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let ssd = FileBackend::open_existing(&dir.join(format!("shard{i}-ssd.log")))?;
            let hdd = FileBackend::open_existing(&dir.join(format!("shard{i}-hdd.img")))?;
            pairs.push(Self::wrap_faults(spec, seed, i, Box::new(ssd), Box::new(hdd)));
        }
        let mut pairs = pairs.into_iter();
        Self::open(cfg, move |_| pairs.next().expect("one backend pair per shard"))
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Write one logical request. Splits it across shards, handing each
    /// sub-request the matching slice of `payload`; returns when every
    /// byte is accepted by a backend (closed-loop semantics).
    ///
    /// Overwrites are fully supported, across routes and mid-burst: each
    /// shard's sector-ownership map supersedes the stale copy, the
    /// flusher skips it, and [`LiveEngine::read`] serves the newest one
    /// (see the module docs).
    ///
    /// `Ok(())` means every sub-request's bytes reached a backend —
    /// transient device faults were absorbed by retries below this
    /// return. An `Err` rejects the *request*: sub-requests already
    /// published on other shards stay durable (striping has no
    /// cross-shard rollback), but the caller must not count the request
    /// as acknowledged.
    pub fn submit(&self, req: Request, payload: &[u8]) -> Result<(), SubmitError> {
        debug_assert_eq!(payload.len() as u64, req.bytes(), "payload must match request size");
        let sector = SECTOR_BYTES as usize;
        let stripe_len = self.stripe.stripe_sectors as i64;
        let mut sub_buf: Vec<u8> = Vec::new();
        for sub in self.stripe.split(req) {
            // gather the sub's sectors out of the logical payload via the
            // stripe bijection (local -> logical is identity within a
            // stripe): stripe-sized runs appended in order, no zero-fill
            sub_buf.clear();
            let mut k = 0i64;
            while k < sub.size as i64 {
                let local = sub.local_offset as i64 + k;
                let logical = logical_sector(&self.stripe, sub.node, local);
                let run = (stripe_len - local % stripe_len).min(sub.size as i64 - k);
                let src = (logical - req.offset as i64) as usize * sector;
                let len = run as usize * sector;
                sub_buf.extend_from_slice(&payload[src..src + len]);
                k += run;
            }
            debug_assert_eq!(sub_buf.len() as u64, sub.bytes());
            self.shards[sub.node].submit(&sub, &sub_buf)?;
        }
        Ok(())
    }

    /// Read `buf.len()` bytes of `file` starting at sector `offset`,
    /// served from wherever the newest copy of each sector lives — SSD
    /// log or HDD — even mid-burst, before any drain. The inverse of
    /// [`LiveEngine::submit`]'s stripe scatter: each shard resolves its
    /// sub-range through its sector-ownership map, pins the referenced
    /// log regions, and reads its devices with no lock held — reads run
    /// concurrently with ingest, flushing, and each other.
    ///
    /// Never-written sectors read as zeros (HDD hole semantics).
    pub fn read(&self, file: u32, offset: i32, buf: &mut [u8]) -> Result<(), ReadError> {
        let sector = SECTOR_BYTES as usize;
        debug_assert_eq!(buf.len() % sector, 0, "reads are sector-aligned");
        let size = (buf.len() / sector) as i32;
        if size == 0 {
            return Ok(());
        }
        let req = Request { app: 0, proc_id: 0, file, offset, size };
        let stripe_len = self.stripe.stripe_sectors as i64;
        let mut sub_buf: Vec<u8> = Vec::new();
        for sub in self.stripe.split(req) {
            // read the whole sub-range from its shard, then scatter it
            // back through the stripe bijection (inverse of submit)
            sub_buf.resize(sub.bytes() as usize, 0);
            self.shards[sub.node].read(sub.parent.file, sub.local_offset, &mut sub_buf)?;
            let mut k = 0i64;
            while k < sub.size as i64 {
                let local = sub.local_offset as i64 + k;
                let logical = logical_sector(&self.stripe, sub.node, local);
                let run = (stripe_len - local % stripe_len).min(sub.size as i64 - k);
                let dst = (logical - offset as i64) as usize * sector;
                let src = k as usize * sector;
                let len = run as usize * sector;
                buf[dst..dst + len].copy_from_slice(&sub_buf[src..src + len]);
                k += run;
            }
        }
        Ok(())
    }

    /// Settle every buffered byte onto the HDD backends and sync them.
    /// Call after all producers have finished submitting.
    ///
    /// Draining is terminal: the flusher threads exit once their shard is
    /// clean, so the engine is one burst per instance — a submit after
    /// drain panics (its bytes could otherwise buffer forever).
    pub fn drain(&self) {
        for shard in &self.shards {
            shard.begin_drain();
        }
        for shard in &self.shards {
            shard.wait_drained();
        }
        for shard in &self.shards {
            shard.sync();
        }
    }

    /// Re-derive the deterministic payload of every request in `workload`
    /// and compare it against what the HDD backends actually hold. Only
    /// meaningful after [`LiveEngine::drain`], and only for workloads whose
    /// payloads came from [`payload::fill`] (the load generator's).
    pub fn verify_workload(&self, workload: &Workload) -> VerifyReport {
        let sector = SECTOR_BYTES as usize;
        let stripe_len = self.stripe.stripe_sectors as i64;
        let mut report = VerifyReport::default();
        let mut expect: Vec<u8> = Vec::new();
        let mut got: Vec<u8> = Vec::new();
        for proc in &workload.processes {
            for req in &proc.reqs {
                // resize without clear: fill/read_hdd overwrite fully, so
                // same-size iterations skip the redundant zeroing
                expect.resize(req.bytes() as usize, 0);
                payload::fill(req.file, req.offset as i64, &mut expect);
                for sub in self.stripe.split(*req) {
                    got.resize(sub.bytes() as usize, 0);
                    let hdd = &self.shards[sub.node];
                    if hdd.read_hdd(sub.parent.file, sub.local_offset, &mut got).is_err() {
                        report.read_errors += 1;
                        continue;
                    }
                    // compare stripe-sized runs; only a mismatching run
                    // pays the per-sector recount
                    let mut k = 0i64;
                    while k < sub.size as i64 {
                        let local = sub.local_offset as i64 + k;
                        let logical = logical_sector(&self.stripe, sub.node, local);
                        let run = (stripe_len - local % stripe_len).min(sub.size as i64 - k);
                        let src = (logical - req.offset as i64) as usize * sector;
                        let dst = k as usize * sector;
                        let len = run as usize * sector;
                        if got[dst..dst + len] != expect[src..src + len] {
                            for s in 0..run as usize {
                                let (d, e) = (dst + s * sector, src + s * sector);
                                if got[d..d + sector] != expect[e..e + sector] {
                                    report.mismatched_sectors += 1;
                                }
                            }
                        }
                        report.checked_bytes += len as u64;
                        k += run;
                    }
                }
            }
        }
        report
    }

    /// Like [`LiveEngine::verify_workload`], but for multi-version
    /// (rewrite) workloads driven with versioned payloads (the load
    /// generator's `versioned` mode, [`payload::write_gen`] per request).
    ///
    /// For every sector, the *final* writer in program order is computed
    /// — within a process by issue order, across apps by `after_app` rank
    /// ([`Workload::app_ranks`]) — and the HDD contents must match that
    /// writer's generation byte-exactly, proving no stale copy was
    /// resurrected anywhere. Only meaningful after a drain.
    ///
    /// Rank is a chain order, not a global one: it only sequences an app
    /// against its own `after_app` ancestors. Writes to the same sector
    /// from processes that are not chain-ordered (two rank-0 apps, or a
    /// rank-1 app vs. a rank-0 app outside its chain) have no defined
    /// winner at runtime; rewrite generators keep such ranges disjoint.
    /// For determinism the candidate tuple breaks remaining ties by
    /// request index, then `proc_id`.
    ///
    /// Memory note: the final-writer map is per-sector (tens of bytes
    /// per written sector) — sized for test/verify workloads, not
    /// multi-TiB runs; an extent-granular winner map is the upgrade path
    /// if verification of huge rewrite runs is ever needed.
    pub fn verify_workload_versioned(&self, workload: &Workload) -> VerifyReport {
        let sector = SECTOR_BYTES as usize;
        let ranks = workload.app_ranks();
        // final writer per (file, logical sector)
        let mut winner: HashMap<(u32, i64), (u32, u32, u32)> = HashMap::new();
        for proc in &workload.processes {
            let rank = ranks.get(&proc.app).copied().unwrap_or(0);
            for (idx, req) in proc.reqs.iter().enumerate() {
                let cand = (rank, idx as u32, proc.proc_id);
                for s in 0..req.size as i64 {
                    let key = (req.file, req.offset as i64 + s);
                    let entry = winner.entry(key).or_insert(cand);
                    if cand > *entry {
                        *entry = cand;
                    }
                }
            }
        }
        let mut report = VerifyReport::default();
        let mut got: Vec<u8> = Vec::new();
        for proc in &workload.processes {
            let rank = ranks.get(&proc.app).copied().unwrap_or(0);
            for (idx, req) in proc.reqs.iter().enumerate() {
                let me = (rank, idx as u32, proc.proc_id);
                let gen = payload::write_gen(proc.proc_id, idx as u32);
                for sub in self.stripe.split(*req) {
                    got.resize(sub.bytes() as usize, 0);
                    let hdd = &self.shards[sub.node];
                    if hdd.read_hdd(sub.parent.file, sub.local_offset, &mut got).is_err() {
                        report.read_errors += 1;
                        continue;
                    }
                    for k in 0..sub.size as i64 {
                        let local = sub.local_offset as i64 + k;
                        let logical = logical_sector(&self.stripe, sub.node, local);
                        if winner[&(req.file, logical)] != me {
                            continue; // a later write owns this sector
                        }
                        let buf = &got[k as usize * sector..(k as usize + 1) * sector];
                        if !payload::sector_matches(req.file, logical, gen, buf) {
                            report.mismatched_sectors += 1;
                        }
                        report.checked_bytes += sector as u64;
                    }
                }
            }
        }
        report
    }

    /// Snapshot per-shard statistics.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.shards.iter().map(|s| s.stats()).collect()
    }

    /// The engine's trace collector. Clone the `Arc` before
    /// [`LiveEngine::shutdown`] (which consumes the engine) to drain and
    /// export the trace afterwards.
    pub fn trace(&self) -> &Arc<TraceCollector> {
        &self.obs
    }

    /// The shared flush coordinator, if coordination is enabled
    /// (`flush_concurrency >= 1`).
    pub fn flush_coordinator(&self) -> Option<&Arc<FlushCoordinator>> {
        self.sched.as_ref()
    }

    /// Shard ids currently holding a flush token (empty when
    /// uncoordinated) — the live view of flush staggering.
    pub fn flush_token_holders(&self) -> Vec<u32> {
        self.sched.as_ref().map(|co| co.holders()).unwrap_or_default()
    }

    /// Merged per-stage ack-latency attribution across all shards.
    pub fn stage_latency(&self) -> StageSet {
        let mut total = StageSet::new();
        for shard in &self.shards {
            total.merge(&shard.stage_latency());
        }
        total
    }

    /// Fraction of ingested bytes that went through the SSD buffer.
    pub fn ssd_ratio(&self) -> f64 {
        crate::live::shard::ssd_ratio(&self.stats())
    }

    /// Drain, persist clean superblocks, stop the flusher threads, and
    /// return the final stats. This is the **orderly** shutdown: the
    /// next [`LiveEngine::open`] over the same backends short-circuits
    /// without a log scan. Dropping the engine instead (a crash) leaves
    /// the superblocks dirty, and the next open replays the logs.
    pub fn shutdown(mut self) -> Vec<ShardStats> {
        self.drain();
        let stats = self.stats();
        for shard in &self.shards {
            shard.finalize_clean();
        }
        for shard in &self.shards {
            shard.request_shutdown();
        }
        for handle in self.flushers.drain(..) {
            let _ = handle.join();
        }
        stats
    }
}

impl Drop for LiveEngine {
    fn drop(&mut self) {
        for shard in &self.shards {
            shard.request_shutdown();
        }
        for handle in self.flushers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::DEFAULT_REQ_SECTORS;

    fn fast_cfg(system: SystemKind, shards: usize) -> LiveConfig {
        let mut c = LiveConfig::new(system).with_shards(shards).with_ssd_mib(64);
        c.flush_check = Duration::from_millis(2);
        c
    }

    fn submit_pattern(engine: &LiveEngine, file: u32, offsets: &[i32]) {
        let mut buf = vec![0u8; (DEFAULT_REQ_SECTORS as u64 * SECTOR_BYTES) as usize];
        for &off in offsets {
            payload::fill(file, off as i64, &mut buf);
            let req =
                Request { app: 0, proc_id: 0, file, offset: off, size: DEFAULT_REQ_SECTORS };
            engine.submit(req, &buf).unwrap();
        }
    }

    #[test]
    fn logical_sector_inverts_striping() {
        let stripe = StripeLayout { stripe_sectors: 128, n_nodes: 3 };
        // every logical sector maps to (node, local) and back
        for logical in [0i64, 1, 127, 128, 129, 4000, 99_999] {
            let stripe_idx = logical / 128;
            let node = (stripe_idx % 3) as usize;
            let local = (stripe_idx / 3) * 128 + logical % 128;
            assert_eq!(logical_sector(&stripe, node, local), logical, "logical={logical}");
        }
    }

    #[test]
    fn contiguous_writes_land_on_hdd_directly() {
        let engine = LiveEngine::mem(
            &fast_cfg(SystemKind::SsdupPlus, 2),
            SyntheticLatency::ZERO,
            SyntheticLatency::ZERO,
        );
        let offsets: Vec<i32> = (0..256).map(|i| i * DEFAULT_REQ_SECTORS).collect();
        submit_pattern(&engine, 1, &offsets);
        engine.drain();
        assert!(
            engine.ssd_ratio() < 0.3,
            "contiguous load should bypass the SSD, got {}",
            engine.ssd_ratio()
        );
        let w = workload_from_offsets(1, &offsets);
        let report = engine.verify_workload(&w);
        assert!(report.is_ok(), "{report:?}");
        engine.shutdown();
    }

    #[test]
    fn random_writes_are_buffered_then_verifiable() {
        let engine = LiveEngine::mem(
            &fast_cfg(SystemKind::SsdupPlus, 2),
            SyntheticLatency::ZERO,
            SyntheticLatency::ZERO,
        );
        // sparse pseudo-random offsets (distinct + sector-aligned). 512
        // requests = 4 streams per shard: the first is routed by the
        // bootstrap direction (HDD), the rest must go to SSD.
        let mut rng = crate::util::prng::Prng::new(11);
        let mut offsets: Vec<i32> =
            (0..512).map(|i| (i * 97 + rng.gen_range(64) as i32) * 4096).collect();
        rng.shuffle(&mut offsets);
        submit_pattern(&engine, 1, &offsets);
        engine.drain();
        assert!(
            engine.ssd_ratio() > 0.5,
            "random load should be buffered, got {}",
            engine.ssd_ratio()
        );
        let w = workload_from_offsets(1, &offsets);
        let report = engine.verify_workload(&w);
        assert!(report.is_ok(), "{report:?}");
        let stats = engine.shutdown();
        assert!(stats.iter().map(|s| s.flushed_bytes).sum::<u64>() > 0, "flusher moved data");
    }

    #[test]
    fn read_serves_newest_copy_mid_burst_and_after_drain() {
        // OrangeFS-BB routes everything to the SSD log; with a roomy SSD
        // nothing flushes before the drain, so mid-burst reads must come
        // from the log
        let engine = LiveEngine::mem(
            &fast_cfg(SystemKind::OrangeFsBB, 2),
            SyntheticLatency::ZERO,
            SyntheticLatency::ZERO,
        );
        let s = SECTOR_BYTES as usize;
        let n = DEFAULT_REQ_SECTORS; // 512 sectors: stripes across shards
        let req = Request { app: 0, proc_id: 0, file: 1, offset: 0, size: n };
        let mut v1 = vec![0u8; n as usize * s];
        payload::fill_gen(1, 0, 1, &mut v1);
        engine.submit(req, &v1).unwrap();

        // SSD hit: served from the log, before any flush
        let mut got = vec![0u8; n as usize * s];
        engine.read(1, 0, &mut got).unwrap();
        assert_eq!(got, v1, "mid-burst read must return the buffered copy");
        let flushed: u64 = engine.stats().iter().map(|st| st.flushed_bytes).sum();
        assert_eq!(flushed, 0, "nothing flushed yet: the read was an SSD hit");

        // superseded extent: rewrite the middle 128 sectors; the newest
        // copy must win immediately, stale log slots notwithstanding
        let mid = Request { app: 0, proc_id: 0, file: 1, offset: 128, size: 128 };
        let mut v2 = vec![0u8; 128 * s];
        payload::fill_gen(1, 128, 2, &mut v2);
        engine.submit(mid, &v2).unwrap();
        engine.read(1, 0, &mut got).unwrap();
        assert_eq!(got[..128 * s], v1[..128 * s]);
        assert_eq!(got[128 * s..256 * s], v2[..]);
        assert_eq!(got[256 * s..], v1[256 * s..]);
        let superseded: u64 = engine.stats().iter().map(|st| st.superseded_bytes).sum();
        assert_eq!(superseded, 128 * SECTOR_BYTES, "stale copy superseded in the map");

        // HDD hit: after the drain the same view comes from the HDD
        let expect = got.clone();
        engine.drain();
        let flushed: u64 = engine.stats().iter().map(|st| st.flushed_bytes).sum();
        assert!(flushed > 0, "drain moved the buffered data");
        engine.read(1, 0, &mut got).unwrap();
        assert_eq!(got, expect, "post-drain read (HDD hit) must match");
        // never-written ranges read as zeros
        let mut hole = vec![0xAAu8; 2 * s];
        engine.read(1, 4096, &mut hole).unwrap();
        assert!(hole.iter().all(|&b| b == 0), "holes read as zeros");
        engine.shutdown();
    }

    fn workload_from_offsets(file: u32, offsets: &[i32]) -> Workload {
        let reqs = offsets
            .iter()
            .map(|&off| Request { app: 0, proc_id: 0, file, offset: off, size: DEFAULT_REQ_SECTORS })
            .collect();
        Workload {
            name: "unit".into(),
            processes: vec![crate::workload::ProcessWorkload {
                app: 0,
                proc_id: 0,
                reqs,
                after_app: None,
            }],
        }
    }
}
