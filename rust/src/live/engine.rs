//! The live burst-buffer engine: N shards behind an OrangeFS-style stripe.
//!
//! Each shard is the live counterpart of one simulated I/O node (same
//! striping, same detection feed, same routing policies), so a
//! `LiveEngine` with `shards = K` is directly comparable to
//! `sim::simulate` with `nodes = K` — the parity tests lean on that.
//! Clients call [`LiveEngine::submit`] from any number of threads; each
//! logical request is split into per-shard sub-requests that carry the
//! matching slice of the payload. Requests return when every byte is on a
//! backend (SSD log or HDD), and [`LiveEngine::drain`] settles all
//! buffered data onto the HDD backends.

use std::io;
use std::path::Path;
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::device::SeekModel;
use crate::fs::StripeLayout;
use crate::live::backend::{Backend, FileBackend, MemBackend, SyntheticLatency};
use crate::live::payload;
use crate::live::shard::{Shard, ShardConfig, ShardStats};
use crate::server::config::SystemKind;
use crate::types::{mib_to_sectors, Request, SECTOR_BYTES};
use crate::workload::Workload;

/// Live-engine configuration. Defaults mirror the simulator's testbed
/// shape (64 KB stripes, CFQ-depth-128 streams, SSDUP+ policies) with a
/// 1 GiB per-shard SSD budget.
#[derive(Clone, Copy, Debug)]
pub struct LiveConfig {
    pub system: SystemKind,
    pub shards: usize,
    pub stripe_sectors: i32,
    pub stream_len: usize,
    /// per-shard SSD buffer capacity in sectors (two regions of half)
    pub ssd_capacity_sectors: i64,
    pub pause_below: f32,
    pub history: usize,
    pub flush_check: Duration,
    pub seek: SeekModel,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self::new(SystemKind::SsdupPlus)
    }
}

impl LiveConfig {
    pub fn new(system: SystemKind) -> Self {
        Self {
            system,
            shards: 4,
            stripe_sectors: 128,
            stream_len: 128,
            ssd_capacity_sectors: mib_to_sectors(1024),
            pause_below: 0.45,
            history: 64,
            flush_check: Duration::from_millis(20),
            seek: SeekModel::default(),
        }
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        assert!(shards >= 1, "need at least one shard");
        self.shards = shards;
        self
    }

    pub fn with_ssd_mib(mut self, mib: u64) -> Self {
        self.ssd_capacity_sectors = mib_to_sectors(mib);
        self
    }

    pub fn with_stream_len(mut self, len: usize) -> Self {
        self.stream_len = len;
        self
    }

    fn shard_config(&self) -> ShardConfig {
        ShardConfig {
            system: self.system,
            ssd_capacity_sectors: self.ssd_capacity_sectors,
            stream_len: self.stream_len,
            pause_below: self.pause_below,
            history: self.history,
            flush_check: self.flush_check,
            seek: self.seek,
        }
    }
}

/// Outcome of [`LiveEngine::verify_workload`].
#[derive(Clone, Copy, Debug, Default)]
pub struct VerifyReport {
    pub checked_bytes: u64,
    pub mismatched_sectors: u64,
}

impl VerifyReport {
    pub fn is_ok(&self) -> bool {
        self.mismatched_sectors == 0
    }
}

/// Map a shard-local sector back to its logical file sector — the inverse
/// of the round-robin stripe mapping (shared by payload gather + verify).
#[inline]
fn logical_sector(stripe: &StripeLayout, node: usize, local: i64) -> i64 {
    let s = stripe.stripe_sectors as i64;
    ((local / s) * stripe.n_nodes as i64 + node as i64) * s + (local % s)
}

pub struct LiveEngine {
    shards: Vec<Arc<Shard>>,
    flushers: Vec<JoinHandle<()>>,
    stripe: StripeLayout,
}

impl LiveEngine {
    /// Build an engine over caller-provided `(ssd, hdd)` backend pairs.
    pub fn with_backends(
        cfg: &LiveConfig,
        mut backends: impl FnMut(usize) -> (Box<dyn Backend>, Box<dyn Backend>),
    ) -> Self {
        assert!(cfg.shards >= 1, "need at least one shard");
        let stripe = StripeLayout { stripe_sectors: cfg.stripe_sectors, n_nodes: cfg.shards };
        let shard_cfg = cfg.shard_config();
        let mut shards = Vec::with_capacity(cfg.shards);
        let mut flushers = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let (ssd, hdd) = backends(i);
            let shard = Arc::new(Shard::new(&shard_cfg, ssd, hdd));
            let worker = Arc::clone(&shard);
            flushers.push(
                thread::Builder::new()
                    .name(format!("ssdup-flusher-{i}"))
                    .spawn(move || worker.flusher_loop())
                    .expect("spawn flusher thread"),
            );
            shards.push(shard);
        }
        Self { shards, flushers, stripe }
    }

    /// All-in-memory engine (unit tests, benches).
    pub fn mem(cfg: &LiveConfig, ssd_latency: SyntheticLatency, hdd_latency: SyntheticLatency) -> Self {
        Self::with_backends(cfg, |_| {
            (
                Box::new(MemBackend::new(ssd_latency)) as Box<dyn Backend>,
                Box::new(MemBackend::new(hdd_latency)) as Box<dyn Backend>,
            )
        })
    }

    /// Real-file engine: per shard, an SSD log file and a sparse HDD image
    /// under `dir`.
    pub fn file(cfg: &LiveConfig, dir: &Path) -> io::Result<Self> {
        // create all backends up front so I/O errors surface before any
        // flusher thread spawns
        let mut pairs = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let ssd = FileBackend::create(&dir.join(format!("shard{i}-ssd.log")))?;
            let hdd = FileBackend::create(&dir.join(format!("shard{i}-hdd.img")))?;
            pairs.push((Box::new(ssd) as Box<dyn Backend>, Box::new(hdd) as Box<dyn Backend>));
        }
        let mut pairs = pairs.into_iter();
        Ok(Self::with_backends(cfg, move |_| pairs.next().expect("one backend pair per shard")))
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Write one logical request. Splits it across shards, handing each
    /// sub-request the matching slice of `payload`; returns when every
    /// byte is accepted by a backend (closed-loop semantics).
    ///
    /// Burst semantics: sectors are expected to be written once between
    /// drains (see the module docs on cross-route rewrites).
    pub fn submit(&self, req: Request, payload: &[u8]) {
        debug_assert_eq!(payload.len() as u64, req.bytes(), "payload must match request size");
        let sector = SECTOR_BYTES as usize;
        let stripe_len = self.stripe.stripe_sectors as i64;
        let mut sub_buf: Vec<u8> = Vec::new();
        for sub in self.stripe.split(req) {
            // gather the sub's sectors out of the logical payload via the
            // stripe bijection (local -> logical is identity within a
            // stripe): stripe-sized runs appended in order, no zero-fill
            sub_buf.clear();
            let mut k = 0i64;
            while k < sub.size as i64 {
                let local = sub.local_offset as i64 + k;
                let logical = logical_sector(&self.stripe, sub.node, local);
                let run = (stripe_len - local % stripe_len).min(sub.size as i64 - k);
                let src = (logical - req.offset as i64) as usize * sector;
                let len = run as usize * sector;
                sub_buf.extend_from_slice(&payload[src..src + len]);
                k += run;
            }
            debug_assert_eq!(sub_buf.len() as u64, sub.bytes());
            self.shards[sub.node].submit(&sub, &sub_buf);
        }
    }

    /// Settle every buffered byte onto the HDD backends and sync them.
    /// Call after all producers have finished submitting.
    pub fn drain(&self) {
        for shard in &self.shards {
            shard.begin_drain();
        }
        for shard in &self.shards {
            shard.wait_drained();
        }
        for shard in &self.shards {
            shard.sync();
        }
    }

    /// Re-derive the deterministic payload of every request in `workload`
    /// and compare it against what the HDD backends actually hold. Only
    /// meaningful after [`LiveEngine::drain`], and only for workloads whose
    /// payloads came from [`payload::fill`] (the load generator's).
    pub fn verify_workload(&self, workload: &Workload) -> VerifyReport {
        let sector = SECTOR_BYTES as usize;
        let stripe_len = self.stripe.stripe_sectors as i64;
        let mut report = VerifyReport::default();
        let mut expect: Vec<u8> = Vec::new();
        let mut got: Vec<u8> = Vec::new();
        for proc in &workload.processes {
            for req in &proc.reqs {
                // resize without clear: fill/read_hdd overwrite fully, so
                // same-size iterations skip the redundant zeroing
                expect.resize(req.bytes() as usize, 0);
                payload::fill(req.file, req.offset as i64, &mut expect);
                for sub in self.stripe.split(*req) {
                    got.resize(sub.bytes() as usize, 0);
                    self.shards[sub.node].read_hdd(sub.parent.file, sub.local_offset, &mut got);
                    // compare stripe-sized runs; only a mismatching run
                    // pays the per-sector recount
                    let mut k = 0i64;
                    while k < sub.size as i64 {
                        let local = sub.local_offset as i64 + k;
                        let logical = logical_sector(&self.stripe, sub.node, local);
                        let run = (stripe_len - local % stripe_len).min(sub.size as i64 - k);
                        let src = (logical - req.offset as i64) as usize * sector;
                        let dst = k as usize * sector;
                        let len = run as usize * sector;
                        if got[dst..dst + len] != expect[src..src + len] {
                            for s in 0..run as usize {
                                let (d, e) = (dst + s * sector, src + s * sector);
                                if got[d..d + sector] != expect[e..e + sector] {
                                    report.mismatched_sectors += 1;
                                }
                            }
                        }
                        report.checked_bytes += len as u64;
                        k += run;
                    }
                }
            }
        }
        report
    }

    /// Snapshot per-shard statistics.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.shards.iter().map(|s| s.stats()).collect()
    }

    /// Fraction of ingested bytes that went through the SSD buffer.
    pub fn ssd_ratio(&self) -> f64 {
        crate::live::shard::ssd_ratio(&self.stats())
    }

    /// Drain, stop the flusher threads, and return the final stats.
    pub fn shutdown(mut self) -> Vec<ShardStats> {
        self.drain();
        let stats = self.stats();
        for shard in &self.shards {
            shard.request_shutdown();
        }
        for handle in self.flushers.drain(..) {
            let _ = handle.join();
        }
        stats
    }
}

impl Drop for LiveEngine {
    fn drop(&mut self) {
        for shard in &self.shards {
            shard.request_shutdown();
        }
        for handle in self.flushers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::DEFAULT_REQ_SECTORS;

    fn fast_cfg(system: SystemKind, shards: usize) -> LiveConfig {
        let mut c = LiveConfig::new(system).with_shards(shards).with_ssd_mib(64);
        c.flush_check = Duration::from_millis(2);
        c
    }

    fn submit_pattern(engine: &LiveEngine, file: u32, offsets: &[i32]) {
        let mut buf = vec![0u8; (DEFAULT_REQ_SECTORS as u64 * SECTOR_BYTES) as usize];
        for &off in offsets {
            payload::fill(file, off as i64, &mut buf);
            let req =
                Request { app: 0, proc_id: 0, file, offset: off, size: DEFAULT_REQ_SECTORS };
            engine.submit(req, &buf);
        }
    }

    #[test]
    fn logical_sector_inverts_striping() {
        let stripe = StripeLayout { stripe_sectors: 128, n_nodes: 3 };
        // every logical sector maps to (node, local) and back
        for logical in [0i64, 1, 127, 128, 129, 4000, 99_999] {
            let stripe_idx = logical / 128;
            let node = (stripe_idx % 3) as usize;
            let local = (stripe_idx / 3) * 128 + logical % 128;
            assert_eq!(logical_sector(&stripe, node, local), logical, "logical={logical}");
        }
    }

    #[test]
    fn contiguous_writes_land_on_hdd_directly() {
        let engine = LiveEngine::mem(
            &fast_cfg(SystemKind::SsdupPlus, 2),
            SyntheticLatency::ZERO,
            SyntheticLatency::ZERO,
        );
        let offsets: Vec<i32> = (0..256).map(|i| i * DEFAULT_REQ_SECTORS).collect();
        submit_pattern(&engine, 1, &offsets);
        engine.drain();
        assert!(
            engine.ssd_ratio() < 0.3,
            "contiguous load should bypass the SSD, got {}",
            engine.ssd_ratio()
        );
        let w = workload_from_offsets(1, &offsets);
        let report = engine.verify_workload(&w);
        assert!(report.is_ok(), "{report:?}");
        engine.shutdown();
    }

    #[test]
    fn random_writes_are_buffered_then_verifiable() {
        let engine = LiveEngine::mem(
            &fast_cfg(SystemKind::SsdupPlus, 2),
            SyntheticLatency::ZERO,
            SyntheticLatency::ZERO,
        );
        // sparse pseudo-random offsets (distinct + sector-aligned). 512
        // requests = 4 streams per shard: the first is routed by the
        // bootstrap direction (HDD), the rest must go to SSD.
        let mut rng = crate::util::prng::Prng::new(11);
        let mut offsets: Vec<i32> =
            (0..512).map(|i| (i * 97 + rng.gen_range(64) as i32) * 4096).collect();
        rng.shuffle(&mut offsets);
        submit_pattern(&engine, 1, &offsets);
        engine.drain();
        assert!(
            engine.ssd_ratio() > 0.5,
            "random load should be buffered, got {}",
            engine.ssd_ratio()
        );
        let w = workload_from_offsets(1, &offsets);
        let report = engine.verify_workload(&w);
        assert!(report.is_ok(), "{report:?}");
        let stats = engine.shutdown();
        assert!(stats.iter().map(|s| s.flushed_bytes).sum::<u64>() > 0, "flusher moved data");
    }

    fn workload_from_offsets(file: u32, offsets: &[i32]) -> Workload {
        let reqs = offsets
            .iter()
            .map(|&off| Request { app: 0, proc_id: 0, file, offset: off, size: DEFAULT_REQ_SECTORS })
            .collect();
        Workload {
            name: "unit".into(),
            processes: vec![crate::workload::ProcessWorkload {
                app: 0,
                proc_id: 0,
                reqs,
                after_app: None,
            }],
        }
    }
}
