//! Per-shard **sector-ownership extent map**: which tier holds the newest
//! copy of every sector (overwrite safety for the live engine).
//!
//! The paper's log-structured buffer (§2.5) restores *order* at flush
//! time, but a rewrite can leave two copies of a sector alive — one in
//! the SSD log, one on the HDD — and without version tracking the flusher
//! may resurrect the stale one. This map, an [`AvlTree`] keyed by the
//! absolute disk LBA of each extent's first sector, is the single source
//! of truth for "where does the newest copy live":
//!
//! * ingest **claims** the written range — any overlapped part of an
//!   older buffered extent is superseded on the spot;
//! * the flusher **clips** every flush extent against the map and copies
//!   only the parts its region still owns (stale-flush suppression: the
//!   skipped sectors also never cost HDD bandwidth);
//! * the read path **resolves** a range into (SSD-slot | HDD) segments
//!   and serves each from the newest copy, even mid-burst;
//! * when a region's flush completes, its surviving extents are
//!   **released** — the newest copy is now the HDD one.
//!
//! Only SSD-resident extents are stored: a range with no entry is
//! HDD-owned by definition (settled by a flush, written directly, or a
//! never-written hole that reads as zeros). That keeps the map
//! proportional to *currently buffered* data, not to history.

use crate::buffer::avl::AvlTree;

/// Which tier holds the newest copy of a sector range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// newest copy is settled on the HDD backend (or never written)
    Hdd,
    /// newest copy sits in the SSD log: pipeline region + sector slot
    /// within that region's log
    Ssd { region: usize, ssd_offset: i64 },
}

/// Stored per live extent: length plus the SSD slot of the newest copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct SsdExtent {
    size: i64,
    region: usize,
    ssd_offset: i64,
}

/// Extent map over absolute disk LBAs (sectors). See the module docs.
#[derive(Clone, Debug, Default)]
pub struct OwnershipMap {
    map: AvlTree<SsdExtent>,
}

impl OwnershipMap {
    pub fn new() -> Self {
        Self { map: AvlTree::new() }
    }

    /// Number of live (SSD-resident) extents.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total SSD-resident sectors (test/debug visibility).
    pub fn ssd_sectors(&self) -> i64 {
        self.map.in_order().map(|(_, e)| e.size).sum()
    }

    /// Stored extents overlapping `[lba, end)`, ascending, unclipped:
    /// everything in `range(lba, end)` plus at most one run that starts
    /// left of `lba` and reaches into it.
    fn overlapping(&self, lba: i64, end: i64) -> Vec<(i64, SsdExtent)> {
        let mut out = Vec::new();
        if let Some((k, e)) = self.map.below(lba) {
            if k + e.size > lba {
                out.push((k, *e));
            }
        }
        out.extend(self.map.range(lba, end));
        out
    }

    /// Does any part of `[lba, lba+size)` currently live in the SSD log?
    /// Allocation-free: this guards every direct-route write.
    pub fn overlaps_ssd(&self, lba: i64, size: i64) -> bool {
        if let Some((k, e)) = self.map.below(lba) {
            if k + e.size > lba {
                return true;
            }
        }
        self.map.any_in_range(lba, lba + size)
    }

    /// Does any part of `[lba, lba+size)` live in `region`'s log
    /// specifically? (The valve path asks before forcing a residual
    /// flush of the active region: overlaps held by a pending/flushing
    /// region clear on their own.)
    pub fn overlaps_ssd_region(&self, lba: i64, size: i64, region: usize) -> bool {
        self.overlapping(lba, lba + size).iter().any(|(_, e)| e.region == region)
    }

    /// Record that the newest copy of `[lba, lba+size)` now lives at
    /// `tier`, superseding the overlapped parts of any older extents
    /// (they are trimmed or removed, with their slot offsets adjusted).
    /// Returns the number of sectors whose previously-newest copy sat in
    /// the SSD log — exactly the stale sectors a flush will now skip.
    pub fn claim(&mut self, lba: i64, size: i64, tier: Tier) -> i64 {
        debug_assert!(size > 0, "empty claim");
        let end = lba + size;
        let mut superseded = 0;
        for (k, e) in self.overlapping(lba, end) {
            self.map.remove(k);
            let e_end = k + e.size;
            if k < lba {
                // left remainder keeps its slot start
                self.map.insert(k, SsdExtent { size: lba - k, ..e });
            }
            if e_end > end {
                // right remainder: slot offset advances by the cut length
                let cut = end - k;
                self.map.insert(
                    end,
                    SsdExtent { size: e_end - end, region: e.region, ssd_offset: e.ssd_offset + cut },
                );
            }
            superseded += e_end.min(end) - k.max(lba);
        }
        if let Tier::Ssd { region, ssd_offset } = tier {
            self.map.insert(lba, SsdExtent { size, region, ssd_offset });
        }
        superseded
    }

    /// Cover `[lba, lba+size)` with ascending non-overlapping segments
    /// `(seg_lba, seg_size, tier)`; ranges with no SSD-resident copy come
    /// back as [`Tier::Hdd`]. The SSD slot offsets are adjusted to each
    /// segment's start, so a segment can be served with one backend read.
    pub fn resolve(&self, lba: i64, size: i64) -> Vec<(i64, i64, Tier)> {
        let end = lba + size;
        let mut out = Vec::new();
        let mut cursor = lba;
        for (k, e) in self.overlapping(lba, end) {
            let s = k.max(lba);
            let e_end = (k + e.size).min(end);
            if s > cursor {
                out.push((cursor, s - cursor, Tier::Hdd));
            }
            let delta = s - k;
            out.push((s, e_end - s, Tier::Ssd { region: e.region, ssd_offset: e.ssd_offset + delta }));
            cursor = e_end;
        }
        if cursor < end {
            out.push((cursor, end - cursor, Tier::Hdd));
        }
        out
    }

    /// Everything a flush of `region` must copy: the extents whose newest
    /// copy lives in that region's log, as `(lba, size, ssd_offset)`
    /// ascending by LBA (the sequential HDD order — LBAs embed the
    /// per-file base extents), with log-adjacent neighbors merged into
    /// single runs. Superseded ranges are simply *absent*: the map tracks
    /// newest copies only, so stale-flush suppression falls out of
    /// iterating it instead of the region's raw append metadata. (The
    /// region metadata alone would also lose data here: a same-offset
    /// rewrite with a shorter size replaces its tree entry whole, while
    /// the map correctly keeps the surviving tail as its own extent.)
    pub fn region_extents(&self, region: usize) -> Vec<(i64, i64, i64)> {
        let mut out: Vec<(i64, i64, i64)> = Vec::new();
        for (k, e) in self.map.in_order() {
            if e.region != region {
                continue;
            }
            match out.last_mut() {
                Some(prev) if prev.0 + prev.1 == k && prev.2 + prev.1 == e.ssd_offset => {
                    prev.1 += e.size;
                }
                _ => out.push((k, e.size, e.ssd_offset)),
            }
        }
        out
    }

    /// A region's flush completed: every extent it still owns is settled
    /// on the HDD now. Removing them keeps "absent = HDD" true before the
    /// region is recycled for new appends. Returns the settled sector
    /// count — the flusher's `flushed_bytes` accounting (extents
    /// superseded mid-copy are absent here, already booked at claim).
    pub fn release_region(&mut self, region: usize) -> i64 {
        let settled: Vec<(i64, i64)> = self
            .map
            .in_order()
            .filter(|(_, e)| e.region == region)
            .map(|(k, e)| (k, e.size))
            .collect();
        let mut sectors = 0;
        for (k, size) in settled {
            self.map.remove(k);
            sectors += size;
        }
        sectors
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ssd(region: usize, ssd_offset: i64) -> Tier {
        Tier::Ssd { region, ssd_offset }
    }

    #[test]
    fn claim_then_resolve_round_trips() {
        let mut m = OwnershipMap::new();
        assert_eq!(m.claim(100, 50, ssd(0, 0)), 0, "nothing superseded yet");
        assert_eq!(m.resolve(100, 50), vec![(100, 50, ssd(0, 0))]);
        // gaps around it resolve as HDD
        assert_eq!(
            m.resolve(90, 70),
            vec![(90, 10, Tier::Hdd), (100, 50, ssd(0, 10)), (150, 10, Tier::Hdd)]
        );
        assert!(m.overlaps_ssd(149, 1));
        assert!(!m.overlaps_ssd(150, 100));
    }

    #[test]
    fn resolve_adjusts_slot_offset_to_segment_start() {
        let mut m = OwnershipMap::new();
        m.claim(1000, 100, ssd(1, 400));
        // reading the tail of the extent must point into the middle of
        // the SSD run, not its start
        assert_eq!(m.resolve(1040, 20), vec![(1040, 20, ssd(1, 440))]);
    }

    #[test]
    fn exact_overwrite_supersedes_fully() {
        let mut m = OwnershipMap::new();
        m.claim(0, 64, ssd(0, 0));
        assert_eq!(m.claim(0, 64, ssd(0, 64)), 64, "whole old copy superseded");
        assert_eq!(m.resolve(0, 64), vec![(0, 64, ssd(0, 64))]);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn partial_overlap_trims_and_adjusts_offsets() {
        let mut m = OwnershipMap::new();
        m.claim(0, 100, ssd(0, 0));
        // overwrite the middle from the other region
        assert_eq!(m.claim(30, 40, ssd(1, 500)), 40);
        assert_eq!(
            m.resolve(0, 100),
            vec![(0, 30, ssd(0, 0)), (30, 40, ssd(1, 500)), (70, 30, ssd(0, 70))]
        );
        assert_eq!(m.ssd_sectors(), 100);
    }

    #[test]
    fn hdd_claim_evicts_buffered_copies() {
        let mut m = OwnershipMap::new();
        m.claim(0, 100, ssd(0, 0));
        // direct-to-HDD rewrite of the tail: the buffered copy of those
        // sectors is stale now
        assert_eq!(m.claim(60, 80, Tier::Hdd), 40);
        assert_eq!(m.resolve(0, 140), vec![(0, 60, ssd(0, 0)), (60, 80, Tier::Hdd)]);
    }

    #[test]
    fn claim_spanning_multiple_extents() {
        let mut m = OwnershipMap::new();
        m.claim(0, 10, ssd(0, 0));
        m.claim(20, 10, ssd(0, 10));
        m.claim(40, 10, ssd(0, 20));
        // one big rewrite covering all three plus the gaps
        assert_eq!(m.claim(5, 40, ssd(1, 0)), 10 + 5 + 5);
        assert_eq!(
            m.resolve(0, 50),
            vec![(0, 5, ssd(0, 0)), (5, 40, ssd(1, 0)), (45, 5, ssd(0, 25))]
        );
    }

    #[test]
    fn region_extents_merge_runs_and_skip_superseded_and_foreign() {
        let mut m = OwnershipMap::new();
        // three consecutive appends into region 0: adjacent in LBA + log
        m.claim(0, 10, ssd(0, 0));
        m.claim(10, 10, ssd(0, 10));
        m.claim(20, 10, ssd(0, 20));
        m.claim(100, 10, ssd(1, 0)); // other region
        assert_eq!(m.region_extents(0), vec![(0, 30, 0)], "one merged sequential run");
        assert_eq!(m.region_extents(1), vec![(100, 10, 0)]);
        // supersede the middle: the run splits and the hole is skipped
        m.claim(12, 6, ssd(1, 10));
        assert_eq!(m.region_extents(0), vec![(0, 12, 0), (18, 12, 18)]);
        // same-offset shorter rewrite: the surviving tail stays flushable
        let mut m2 = OwnershipMap::new();
        m2.claim(0, 64, ssd(0, 0));
        m2.claim(0, 16, ssd(0, 64));
        assert_eq!(m2.region_extents(0), vec![(0, 16, 64), (16, 48, 16)]);
    }

    #[test]
    fn release_region_settles_only_that_region() {
        let mut m = OwnershipMap::new();
        m.claim(0, 10, ssd(0, 0));
        m.claim(100, 10, ssd(1, 0));
        m.claim(200, 10, ssd(0, 10));
        assert_eq!(m.release_region(0), 20, "both region-0 extents settle");
        assert_eq!(m.len(), 1);
        assert_eq!(m.resolve(100, 10), vec![(100, 10, ssd(1, 0))]);
        assert_eq!(m.resolve(0, 10), vec![(0, 10, Tier::Hdd)]);
        assert_eq!(m.release_region(1), 10);
        assert!(m.is_empty());
        assert_eq!(m.release_region(0), 0, "idempotent on an empty map");
    }

    #[test]
    fn superseded_accounting_is_exact_under_churn() {
        // conservation: claimed SSD sectors == live + superseded, always
        let mut m = OwnershipMap::new();
        let mut rng = crate::util::prng::Prng::new(31);
        let mut claimed = 0i64;
        let mut superseded = 0i64;
        for i in 0..500usize {
            let lba = rng.gen_range(2000) as i64;
            let size = 1 + rng.gen_range(64) as i64;
            if rng.chance(0.25) {
                superseded += m.claim(lba, size, Tier::Hdd);
            } else {
                claimed += size;
                superseded += m.claim(lba, size, Tier::Ssd { region: i % 2, ssd_offset: i as i64 * 64 });
            }
            assert_eq!(m.ssd_sectors() + superseded, claimed, "step {i}");
        }
    }
}
