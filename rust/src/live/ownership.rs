//! Per-shard **sector-ownership extent map**: which tier holds the newest
//! copy of every sector (overwrite safety for the live engine), plus the
//! in-flight state that makes lock-free device I/O safe.
//!
//! The paper's log-structured buffer (§2.5) restores *order* at flush
//! time, but a rewrite can leave two copies of a sector alive — one in
//! the SSD log, one on the HDD — and without version tracking the flusher
//! may resurrect the stale one. This map, an [`AvlTree`] keyed by the
//! absolute disk LBA of each extent's first sector, is the single source
//! of truth for "where does the newest copy live":
//!
//! * ingest **reserves** the written range under the shard's core lock
//!   (the claim supersedes any overlapped older buffered extent on the
//!   spot), then writes the device bytes with no lock held, then
//!   **publishes** the claim. A reserved-but-unpublished extent is
//!   *pending*: readers wait it out and the flusher refuses to snapshot
//!   its region, because the log slot's bytes are not on the backend yet;
//! * direct-to-HDD writes register the same way in a small side list of
//!   **in-flight direct extents** ([`OwnershipMap::claim_direct`]): any
//!   later claim overlapping one waits for it to land first, which is
//!   what keeps an in-flight HDD write from surfacing *after* a newer
//!   buffered copy was flushed over the same sectors;
//! * the flusher copies exactly the map's surviving extents for its
//!   region (stale-flush suppression: superseded ranges are simply
//!   absent, and skipped sectors never cost HDD bandwidth);
//! * the read path **resolves** a range into (SSD-slot | HDD) segments
//!   and serves each from the newest copy, even mid-burst;
//! * when a region's flush completes, its surviving extents are
//!   **released** — the newest copy is now the HDD one.
//!
//! Only SSD-resident extents are stored in the tree: a range with no
//! entry is HDD-owned by definition (settled by a flush, written
//! directly, or a never-written hole that reads as zeros). That keeps the
//! map proportional to *currently buffered* data, not to history.
//!
//! Pending claims are identified by **tickets** (monotonic `u64`s handed
//! out at reserve time): a claim can be partially superseded by a newer
//! claim while its device write is still in flight, so publishing flips
//! exactly the surviving fragments that still carry the publisher's
//! ticket — never a newer claim that landed inside the same range.

use crate::buffer::avl::AvlTree;
use std::time::{Duration, Instant};

/// Which tier holds the newest copy of a sector range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// newest copy is settled on the HDD backend (or never written)
    Hdd,
    /// newest copy sits in the SSD log: pipeline region + sector slot
    /// within that region's log
    Ssd { region: usize, ssd_offset: i64 },
}

/// Published marker in [`SsdExtent::pending`] (real tickets start at 1).
const PUBLISHED: u64 = 0;

/// Stored per live extent: length plus the SSD slot of the newest copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct SsdExtent {
    size: i64,
    region: usize,
    ssd_offset: i64,
    /// [`PUBLISHED`], or the reserving write's ticket while its device
    /// write is still in flight. Trims preserve this, so every surviving
    /// fragment of a pending claim stays attributable to its writer.
    pending: u64,
    /// Rewrite heat: how many buffered generations of this LBA range
    /// this copy has superseded (0 for a first write). Carried onto the
    /// newest copy at supersede time and preserved by trims, so the
    /// flusher can tell a churning checkpoint range from cold data.
    heat: u32,
    /// When this copy last superseded an older one (`None` for a first
    /// write). Bounds hot/cold deferral: a hot extent older than the
    /// defer window flushes like any other.
    hot_since: Option<Instant>,
}

/// Extent map over absolute disk LBAs (sectors). See the module docs.
#[derive(Clone, Debug)]
pub struct OwnershipMap {
    map: AvlTree<SsdExtent>,
    /// in-flight direct-to-HDD writes as `(lba, size, ticket)`. Disjoint
    /// by construction: the shard waits out any overlap before claiming.
    /// A Vec because it only ever holds the handful of direct writes
    /// currently between claim and device-write completion.
    direct: Vec<(i64, i64, u64)>,
    /// next reserve/claim ticket (0 is reserved for "published")
    next_ticket: u64,
}

impl Default for OwnershipMap {
    // not derived: tickets must start at 1 (0 is the PUBLISHED sentinel)
    fn default() -> Self {
        Self::new()
    }
}

impl OwnershipMap {
    pub fn new() -> Self {
        Self { map: AvlTree::new(), direct: Vec::new(), next_ticket: 1 }
    }

    /// Crash recovery: rebuild the map by replaying surviving log records
    /// in **sequence order** — each `(lba, size, region, ssd_offset)`
    /// claim supersedes the overlapped parts of earlier ones, exactly as
    /// the original reserve order did (claim order is fixed under the
    /// shard's core lock, and the on-SSD record sequence captures it).
    /// Every replayed claim is published: recovery only replays records
    /// whose device bytes passed their checksum.
    ///
    /// Returns the map plus the sectors superseded *during replay*
    /// (rewrites whose stale copy also survived in the log) — the shard
    /// books them so `buffered == flushed + superseded` stays exact
    /// across a recovery drain.
    pub fn rebuild_from_replay(
        records: impl IntoIterator<Item = (u64, i64, i64, usize, i64)>,
    ) -> (Self, i64) {
        let mut map = Self::new();
        let mut superseded = 0;
        let mut last_seq = 0;
        for (seq, lba, size, region, ssd_offset) in records {
            debug_assert!(seq > last_seq, "replay must be in strict sequence order");
            last_seq = seq;
            superseded += map.claim(lba, size, Tier::Ssd { region, ssd_offset });
        }
        (map, superseded)
    }

    /// Number of live (SSD-resident) extents.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total SSD-resident sectors, pending claims included (test/debug
    /// visibility).
    pub fn ssd_sectors(&self) -> i64 {
        self.map.in_order().map(|(_, e)| e.size).sum()
    }

    fn alloc_ticket(&mut self) -> u64 {
        let t = self.next_ticket;
        self.next_ticket += 1;
        t
    }

    /// Stored extents overlapping `[lba, end)`, ascending, unclipped:
    /// everything in `range(lba, end)` plus at most one run that starts
    /// left of `lba` and reaches into it.
    fn overlapping(&self, lba: i64, end: i64) -> Vec<(i64, SsdExtent)> {
        let mut out = Vec::new();
        if let Some((k, e)) = self.map.below(lba) {
            if k + e.size > lba {
                out.push((k, *e));
            }
        }
        out.extend(self.map.range(lba, end));
        out
    }

    /// Does any part of `[lba, lba+size)` currently live in the SSD log
    /// (pending claims included)? Allocation-free: this guards every
    /// direct-route write.
    pub fn overlaps_ssd(&self, lba: i64, size: i64) -> bool {
        if let Some((k, e)) = self.map.below(lba) {
            if k + e.size > lba {
                return true;
            }
        }
        self.map.any_in_range(lba, lba + size)
    }

    /// Does any part of `[lba, lba+size)` live in `region`'s log
    /// specifically? (The valve path asks before forcing a residual
    /// flush of the active region: overlaps held by a pending/flushing
    /// region clear on their own.)
    pub fn overlaps_ssd_region(&self, lba: i64, size: i64, region: usize) -> bool {
        self.overlapping(lba, lba + size).iter().any(|(_, e)| e.region == region)
    }

    /// Is any part of `[lba, lba+size)` claimed by a write whose device
    /// bytes are still in flight — a reserved-but-unpublished SSD slot or
    /// an in-flight direct-to-HDD write? Readers wait this out before
    /// resolving, and new claims wait out the direct component.
    pub fn pending_overlaps(&self, lba: i64, size: i64) -> bool {
        if self.direct_overlaps(lba, size) {
            return true;
        }
        // allocation-free like `overlaps_ssd`: this guards every live
        // read (and re-runs on each wakeup while a reader waits)
        if let Some((k, e)) = self.map.below(lba) {
            if k + e.size > lba && e.pending != PUBLISHED {
                return true;
            }
        }
        self.map.any_in_range_where(lba, lba + size, |e| e.pending != PUBLISHED)
    }

    /// Is any part of `[lba, lba+size)` covered by an in-flight
    /// direct-to-HDD write?
    pub fn direct_overlaps(&self, lba: i64, size: i64) -> bool {
        let end = lba + size;
        self.direct.iter().any(|&(d_lba, d_size, _)| d_lba < end && d_lba + d_size > lba)
    }

    /// Supersede the overlapped parts of any extents in `[lba, end)`:
    /// they are trimmed or removed, with slot offsets (and pending
    /// tickets) carried onto the remainders. Returns the superseded
    /// sector count — exactly the stale sectors a flush will now skip —
    /// plus the hottest superseded extent's rewrite heat, so the caller
    /// can carry the range's churn history onto the newest copy.
    fn supersede(&mut self, lba: i64, end: i64) -> (i64, u32) {
        let mut superseded = 0;
        let mut heat = 0;
        for (k, e) in self.overlapping(lba, end) {
            self.map.remove(k);
            let e_end = k + e.size;
            if k < lba {
                // left remainder keeps its slot start
                self.map.insert(k, SsdExtent { size: lba - k, ..e });
            }
            if e_end > end {
                // right remainder: slot offset advances by the cut length
                let cut = end - k;
                self.map.insert(
                    end,
                    SsdExtent { size: e_end - end, ssd_offset: e.ssd_offset + cut, ..e },
                );
            }
            superseded += e_end.min(end) - k.max(lba);
            heat = heat.max(e.heat);
        }
        (superseded, heat)
    }

    /// Heat for a claim that just superseded `superseded` sectors whose
    /// hottest prior copy had `prior` rewrites: a rewrite bumps the
    /// count and stamps the moment; a first write is cold.
    fn next_heat(superseded: i64, prior: u32) -> (u32, Option<Instant>) {
        if superseded > 0 {
            (prior.saturating_add(1), Some(Instant::now()))
        } else {
            (0, None)
        }
    }

    /// Record that the newest copy of `[lba, lba+size)` now lives at
    /// `tier`, superseding the overlapped parts of any older extents.
    /// The claim is **published** immediately — the caller asserts the
    /// bytes are already on the backend (tests, and any future
    /// synchronous path). Returns the superseded sector count.
    pub fn claim(&mut self, lba: i64, size: i64, tier: Tier) -> i64 {
        debug_assert!(size > 0, "empty claim");
        let (superseded, prior) = self.supersede(lba, lba + size);
        if let Tier::Ssd { region, ssd_offset } = tier {
            let (heat, hot_since) = Self::next_heat(superseded, prior);
            self.map.insert(
                lba,
                SsdExtent { size, region, ssd_offset, pending: PUBLISHED, heat, hot_since },
            );
        }
        superseded
    }

    /// Reserve `[lba, lba+size)` for an SSD-log write whose device bytes
    /// are **not yet written**: supersedes older copies exactly like
    /// [`OwnershipMap::claim`], but the new extent is pending until
    /// [`OwnershipMap::publish`] is called with the returned ticket.
    /// Returns `(superseded sectors, ticket)`.
    pub fn reserve(&mut self, lba: i64, size: i64, region: usize, ssd_offset: i64) -> (i64, u64) {
        debug_assert!(size > 0, "empty reserve");
        debug_assert!(!self.direct_overlaps(lba, size), "reserve over in-flight direct write");
        let (superseded, prior) = self.supersede(lba, lba + size);
        let ticket = self.alloc_ticket();
        let (heat, hot_since) = Self::next_heat(superseded, prior);
        self.map
            .insert(lba, SsdExtent { size, region, ssd_offset, pending: ticket, heat, hot_since });
        (superseded, ticket)
    }

    /// A reserved write's device bytes landed: flip every surviving
    /// fragment of `ticket`'s claim in `[lba, lba+size)` to published.
    /// Fragments superseded while the write was in flight are simply
    /// gone — publishing never touches extents claimed by other writes.
    /// Returns the published sector count (0 if fully superseded).
    pub fn publish(&mut self, ticket: u64, lba: i64, size: i64) -> i64 {
        debug_assert!(ticket != PUBLISHED, "publish without a ticket");
        let mut published = 0;
        for (k, e) in self.overlapping(lba, lba + size) {
            if e.pending != ticket {
                continue;
            }
            self.map.remove(k);
            self.map.insert(k, SsdExtent { pending: PUBLISHED, ..e });
            published += e.size;
        }
        published
    }

    /// A reserved write's device bytes will **never** land (the SSD slot
    /// write failed for good): remove every surviving fragment of
    /// `ticket`'s claim in `[lba, lba+size)` instead of publishing it.
    /// The range reverts to "absent = HDD-owned", so a degraded-mode
    /// re-route can claim it for the direct path immediately. Fragments
    /// already superseded by newer claims are untouched, exactly like
    /// [`OwnershipMap::publish`]. Returns the aborted sector count.
    pub fn abort(&mut self, ticket: u64, lba: i64, size: i64) -> i64 {
        debug_assert!(ticket != PUBLISHED, "abort without a ticket");
        let mut aborted = 0;
        for (k, e) in self.overlapping(lba, lba + size) {
            if e.pending != ticket {
                continue;
            }
            self.map.remove(k);
            aborted += e.size;
        }
        aborted
    }

    /// Register an in-flight direct-to-HDD write of `[lba, lba+size)`.
    /// The caller must have waited out any overlap first (no SSD-resident
    /// copy, no other in-flight direct write); the returned ticket is
    /// handed back to [`OwnershipMap::finish_direct`] once the device
    /// write completed.
    pub fn claim_direct(&mut self, lba: i64, size: i64) -> u64 {
        debug_assert!(size > 0, "empty direct claim");
        debug_assert!(!self.overlaps_ssd(lba, size), "direct write over live buffer");
        debug_assert!(!self.direct_overlaps(lba, size), "overlapping in-flight direct writes");
        let ticket = self.alloc_ticket();
        self.direct.push((lba, size, ticket));
        ticket
    }

    /// An in-flight direct write's device bytes landed: drop its entry.
    /// The range has no tree entry (absent = HDD-owned), so nothing else
    /// changes. Panics on an unknown ticket — that is a caller bug, and
    /// silently ignoring it would leave readers waiting on a ghost write.
    pub fn finish_direct(&mut self, ticket: u64) {
        let i = self.direct.iter().position(|&(_, _, t)| t == ticket).expect("unknown direct ticket");
        self.direct.swap_remove(i);
    }

    /// In-flight direct writes currently registered (test visibility).
    pub fn direct_in_flight(&self) -> usize {
        self.direct.len()
    }

    /// Cover `[lba, lba+size)` with ascending non-overlapping segments
    /// `(seg_lba, seg_size, tier)`; ranges with no SSD-resident copy come
    /// back as [`Tier::Hdd`]. The SSD slot offsets are adjusted to each
    /// segment's start, so a segment can be served with one backend read.
    ///
    /// Callers must have waited until [`OwnershipMap::pending_overlaps`]
    /// is false for the range: a pending claim has no readable copy
    /// anywhere (the old one is superseded, the new bytes are still in
    /// flight).
    pub fn resolve(&self, lba: i64, size: i64) -> Vec<(i64, i64, Tier)> {
        let end = lba + size;
        let mut out = Vec::new();
        let mut cursor = lba;
        for (k, e) in self.overlapping(lba, end) {
            debug_assert_eq!(e.pending, PUBLISHED, "resolve across a pending claim");
            let s = k.max(lba);
            let e_end = (k + e.size).min(end);
            if s > cursor {
                out.push((cursor, s - cursor, Tier::Hdd));
            }
            let delta = s - k;
            out.push((s, e_end - s, Tier::Ssd { region: e.region, ssd_offset: e.ssd_offset + delta }));
            cursor = e_end;
        }
        if cursor < end {
            out.push((cursor, end - cursor, Tier::Hdd));
        }
        out
    }

    /// Everything a flush of `region` must copy: the extents whose newest
    /// copy lives in that region's log, as `(lba, size, ssd_offset)`
    /// ascending by LBA (the sequential HDD order — LBAs embed the
    /// per-file base extents), with log-adjacent neighbors merged into
    /// single runs. Superseded ranges are simply *absent*: the map tracks
    /// newest copies only, so stale-flush suppression falls out of
    /// iterating it instead of the region's raw append metadata. (The
    /// region metadata alone would also lose data here: a same-offset
    /// rewrite with a shorter size replaces its tree entry whole, while
    /// the map correctly keeps the surviving tail as its own extent.)
    ///
    /// The caller (the shard's flusher) waits until the region has no
    /// pending claims first — the region stopped accepting appends when
    /// it was queued, so that state is final.
    pub fn region_extents(&self, region: usize) -> Vec<(i64, i64, i64)> {
        let mut out: Vec<(i64, i64, i64)> = Vec::new();
        for (k, e) in self.map.in_order() {
            if e.region != region {
                continue;
            }
            debug_assert_eq!(e.pending, PUBLISHED, "flush snapshot across a pending claim");
            match out.last_mut() {
                Some(prev) if prev.0 + prev.1 == k && prev.2 + prev.1 == e.ssd_offset => {
                    prev.1 += e.size;
                }
                _ => out.push((k, e.size, e.ssd_offset)),
            }
        }
        out
    }

    /// Hot/cold split of a region's queued data, in sectors: `(total,
    /// hot)` where *hot* means the extent has superseded at least one
    /// older buffered copy (`heat > 0`) and did so within `window`. The
    /// flusher defers a predominantly hot region briefly so churn keeps
    /// superseding in the buffer instead of costing HDD copies; the age
    /// bound keeps a once-hot extent from dodging the flush forever.
    /// `window == 0` classifies nothing as hot (deferral disabled).
    pub fn region_heat(&self, region: usize, window: Duration) -> (i64, i64) {
        let mut total = 0;
        let mut hot = 0;
        for (_, e) in self.map.in_order() {
            if e.region != region {
                continue;
            }
            total += e.size;
            if e.heat > 0 && e.hot_since.is_some_and(|t| t.elapsed() < window) {
                hot += e.size;
            }
        }
        (total, hot)
    }

    /// A region's flush completed: every extent it still owns is settled
    /// on the HDD now. Removing them keeps "absent = HDD" true before the
    /// region is recycled for new appends. Returns the settled sector
    /// count — the flusher's `flushed_bytes` accounting (extents
    /// superseded mid-copy are absent here, already booked at claim).
    pub fn release_region(&mut self, region: usize) -> i64 {
        let settled: Vec<(i64, i64)> = self
            .map
            .in_order()
            .filter(|(_, e)| e.region == region)
            .map(|(k, e)| (k, e.size))
            .collect();
        let mut sectors = 0;
        for (k, size) in settled {
            self.map.remove(k);
            sectors += size;
        }
        sectors
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ssd(region: usize, ssd_offset: i64) -> Tier {
        Tier::Ssd { region, ssd_offset }
    }

    #[test]
    fn claim_then_resolve_round_trips() {
        let mut m = OwnershipMap::new();
        assert_eq!(m.claim(100, 50, ssd(0, 0)), 0, "nothing superseded yet");
        assert_eq!(m.resolve(100, 50), vec![(100, 50, ssd(0, 0))]);
        // gaps around it resolve as HDD
        assert_eq!(
            m.resolve(90, 70),
            vec![(90, 10, Tier::Hdd), (100, 50, ssd(0, 10)), (150, 10, Tier::Hdd)]
        );
        assert!(m.overlaps_ssd(149, 1));
        assert!(!m.overlaps_ssd(150, 100));
    }

    #[test]
    fn resolve_adjusts_slot_offset_to_segment_start() {
        let mut m = OwnershipMap::new();
        m.claim(1000, 100, ssd(1, 400));
        // reading the tail of the extent must point into the middle of
        // the SSD run, not its start
        assert_eq!(m.resolve(1040, 20), vec![(1040, 20, ssd(1, 440))]);
    }

    #[test]
    fn exact_overwrite_supersedes_fully() {
        let mut m = OwnershipMap::new();
        m.claim(0, 64, ssd(0, 0));
        assert_eq!(m.claim(0, 64, ssd(0, 64)), 64, "whole old copy superseded");
        assert_eq!(m.resolve(0, 64), vec![(0, 64, ssd(0, 64))]);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn partial_overlap_trims_and_adjusts_offsets() {
        let mut m = OwnershipMap::new();
        m.claim(0, 100, ssd(0, 0));
        // overwrite the middle from the other region
        assert_eq!(m.claim(30, 40, ssd(1, 500)), 40);
        assert_eq!(
            m.resolve(0, 100),
            vec![(0, 30, ssd(0, 0)), (30, 40, ssd(1, 500)), (70, 30, ssd(0, 70))]
        );
        assert_eq!(m.ssd_sectors(), 100);
    }

    #[test]
    fn hdd_claim_evicts_buffered_copies() {
        let mut m = OwnershipMap::new();
        m.claim(0, 100, ssd(0, 0));
        // direct-to-HDD rewrite of the tail: the buffered copy of those
        // sectors is stale now
        assert_eq!(m.claim(60, 80, Tier::Hdd), 40);
        assert_eq!(m.resolve(0, 140), vec![(0, 60, ssd(0, 0)), (60, 80, Tier::Hdd)]);
    }

    #[test]
    fn claim_spanning_multiple_extents() {
        let mut m = OwnershipMap::new();
        m.claim(0, 10, ssd(0, 0));
        m.claim(20, 10, ssd(0, 10));
        m.claim(40, 10, ssd(0, 20));
        // one big rewrite covering all three plus the gaps
        assert_eq!(m.claim(5, 40, ssd(1, 0)), 10 + 5 + 5);
        assert_eq!(
            m.resolve(0, 50),
            vec![(0, 5, ssd(0, 0)), (5, 40, ssd(1, 0)), (45, 5, ssd(0, 25))]
        );
    }

    #[test]
    fn region_extents_merge_runs_and_skip_superseded_and_foreign() {
        let mut m = OwnershipMap::new();
        // three consecutive appends into region 0: adjacent in LBA + log
        m.claim(0, 10, ssd(0, 0));
        m.claim(10, 10, ssd(0, 10));
        m.claim(20, 10, ssd(0, 20));
        m.claim(100, 10, ssd(1, 0)); // other region
        assert_eq!(m.region_extents(0), vec![(0, 30, 0)], "one merged sequential run");
        assert_eq!(m.region_extents(1), vec![(100, 10, 0)]);
        // supersede the middle: the run splits and the hole is skipped
        m.claim(12, 6, ssd(1, 10));
        assert_eq!(m.region_extents(0), vec![(0, 12, 0), (18, 12, 18)]);
        // same-offset shorter rewrite: the surviving tail stays flushable
        let mut m2 = OwnershipMap::new();
        m2.claim(0, 64, ssd(0, 0));
        m2.claim(0, 16, ssd(0, 64));
        assert_eq!(m2.region_extents(0), vec![(0, 16, 64), (16, 48, 16)]);
    }

    #[test]
    fn release_region_settles_only_that_region() {
        let mut m = OwnershipMap::new();
        m.claim(0, 10, ssd(0, 0));
        m.claim(100, 10, ssd(1, 0));
        m.claim(200, 10, ssd(0, 10));
        assert_eq!(m.release_region(0), 20, "both region-0 extents settle");
        assert_eq!(m.len(), 1);
        assert_eq!(m.resolve(100, 10), vec![(100, 10, ssd(1, 0))]);
        assert_eq!(m.resolve(0, 10), vec![(0, 10, Tier::Hdd)]);
        assert_eq!(m.release_region(1), 10);
        assert!(m.is_empty());
        assert_eq!(m.release_region(0), 0, "idempotent on an empty map");
    }

    #[test]
    fn superseded_accounting_is_exact_under_churn() {
        // conservation: claimed SSD sectors == live + superseded, always
        let mut m = OwnershipMap::new();
        let mut rng = crate::util::prng::Prng::new(31);
        let mut claimed = 0i64;
        let mut superseded = 0i64;
        for i in 0..500usize {
            let lba = rng.gen_range(2000) as i64;
            let size = 1 + rng.gen_range(64) as i64;
            if rng.chance(0.25) {
                superseded += m.claim(lba, size, Tier::Hdd);
            } else {
                claimed += size;
                superseded += m.claim(lba, size, Tier::Ssd { region: i % 2, ssd_offset: i as i64 * 64 });
            }
            assert_eq!(m.ssd_sectors() + superseded, claimed, "step {i}");
        }
    }

    #[test]
    fn reserve_is_pending_until_published() {
        let mut m = OwnershipMap::new();
        let (stale, ticket) = m.reserve(100, 20, 0, 0);
        assert_eq!(stale, 0);
        assert!(m.pending_overlaps(110, 1), "reserved range is pending");
        assert!(m.overlaps_ssd(110, 1), "pending claims still count as SSD-resident");
        assert!(!m.pending_overlaps(120, 10), "outside the claim is clear");
        assert_eq!(m.publish(ticket, 100, 20), 20);
        assert!(!m.pending_overlaps(100, 20));
        assert_eq!(m.resolve(100, 20), vec![(100, 20, ssd(0, 0))]);
    }

    #[test]
    fn publish_flips_only_surviving_fragments_of_its_ticket() {
        let mut m = OwnershipMap::new();
        let (_, a) = m.reserve(0, 100, 0, 0);
        // a newer claim lands inside A's range while A is in flight
        let (stale, b) = m.reserve(30, 40, 1, 500);
        assert_eq!(stale, 40, "mid-flight supersede is booked to the newer claim");
        // A publishes: only its two surviving fragments flip; B's claim
        // inside the same range stays pending
        assert_eq!(m.publish(a, 0, 100), 30 + 30);
        assert!(m.pending_overlaps(30, 40), "B is still in flight");
        assert!(!m.pending_overlaps(0, 30));
        assert!(!m.pending_overlaps(70, 30));
        assert_eq!(m.publish(b, 30, 40), 40);
        assert_eq!(
            m.resolve(0, 100),
            vec![(0, 30, ssd(0, 0)), (30, 40, ssd(1, 500)), (70, 30, ssd(0, 70))]
        );
    }

    #[test]
    fn fully_superseded_pending_claim_publishes_nothing() {
        let mut m = OwnershipMap::new();
        let (_, a) = m.reserve(0, 10, 0, 0);
        let (stale, b) = m.reserve(0, 10, 0, 10);
        assert_eq!(stale, 10);
        assert_eq!(m.publish(a, 0, 10), 0, "nothing of A survived");
        assert_eq!(m.publish(b, 0, 10), 10);
        assert_eq!(m.resolve(0, 10), vec![(0, 10, ssd(0, 10))]);
        assert_eq!(m.ssd_sectors(), 10);
    }

    #[test]
    fn rebuild_from_replay_applies_newest_wins_in_sequence_order() {
        // the same stream the live path would produce: a rewrite (seq 3)
        // landing inside an earlier extent (seq 1), plus a disjoint one
        let records = vec![
            (1u64, 0i64, 100i64, 0usize, 1i64),
            (2, 500, 10, 0, 102),
            (3, 30, 40, 1, 1),
        ];
        let (m, superseded) = OwnershipMap::rebuild_from_replay(records);
        assert_eq!(superseded, 40, "the rewritten middle is booked as superseded");
        assert_eq!(
            m.resolve(0, 100),
            vec![(0, 30, ssd(0, 1)), (30, 40, ssd(1, 1)), (70, 30, ssd(0, 71))]
        );
        assert_eq!(m.resolve(500, 10), vec![(500, 10, ssd(0, 102))]);
        assert!(!m.pending_overlaps(0, 600), "replayed claims are published");
        assert_eq!(m.ssd_sectors() + superseded, 150);
    }

    #[test]
    fn abort_removes_surviving_fragments_and_spares_newer_claims() {
        let mut m = OwnershipMap::new();
        let (_, a) = m.reserve(0, 100, 0, 0);
        // a newer claim lands inside A's range while A is in flight
        let (_, b) = m.reserve(30, 40, 1, 500);
        // A's device write failed permanently: its fragments must vanish
        assert_eq!(m.abort(a, 0, 100), 30 + 30);
        assert!(!m.pending_overlaps(0, 30), "aborted head is HDD-owned again");
        assert!(!m.pending_overlaps(70, 30), "aborted tail is HDD-owned again");
        assert!(m.pending_overlaps(30, 40), "B's in-flight claim is untouched");
        assert_eq!(m.publish(b, 30, 40), 40);
        assert_eq!(
            m.resolve(0, 100),
            vec![(0, 30, Tier::Hdd), (30, 40, ssd(1, 500)), (70, 30, Tier::Hdd)]
        );
        // a fully superseded claim aborts to nothing
        let (_, c) = m.reserve(200, 10, 0, 0);
        let (stale, d) = m.reserve(200, 10, 0, 10);
        assert_eq!(stale, 10);
        assert_eq!(m.abort(c, 200, 10), 0, "nothing of C survived to abort");
        assert_eq!(m.publish(d, 200, 10), 10);
        assert_eq!(m.resolve(200, 10), vec![(200, 10, ssd(0, 10))]);
    }

    #[test]
    fn direct_claims_track_in_flight_hdd_writes() {
        let mut m = OwnershipMap::new();
        let t = m.claim_direct(1000, 50);
        assert_eq!(m.direct_in_flight(), 1);
        assert!(m.direct_overlaps(1040, 20));
        assert!(m.pending_overlaps(990, 11), "tail overlap is pending");
        assert!(!m.direct_overlaps(1050, 10), "end is exclusive");
        assert!(!m.direct_overlaps(990, 10));
        // the tree is untouched: direct writes are HDD-owned (absent)
        assert!(m.is_empty());
        assert_eq!(m.resolve(1000, 50), vec![(1000, 50, Tier::Hdd)]);
        m.finish_direct(t);
        assert_eq!(m.direct_in_flight(), 0);
        assert!(!m.pending_overlaps(1000, 50));
    }

    #[test]
    fn rewrite_heat_rides_the_newest_copy() {
        let hour = Duration::from_secs(3600);
        let mut m = OwnershipMap::new();
        m.claim(0, 100, ssd(0, 0));
        assert_eq!(m.region_heat(0, hour), (100, 0), "first write is cold");
        // full rewrite: the new copy carries heat 1
        m.claim(0, 100, ssd(0, 100));
        assert_eq!(m.region_heat(0, hour), (100, 100));
        assert_eq!(m.region_heat(0, Duration::ZERO), (100, 0), "zero window disables heat");
        // rewrite the middle into the other region: the remainders keep
        // their heat, the middle gets hotter still
        m.claim(30, 40, ssd(1, 0));
        assert_eq!(m.region_heat(0, hour), (60, 60));
        assert_eq!(m.region_heat(1, hour), (40, 40));
        // a disjoint first write stays cold next to the hot extents
        m.claim(500, 10, ssd(1, 40));
        assert_eq!(m.region_heat(1, hour), (50, 40));
    }

    #[test]
    fn heat_survives_reserve_publish_and_release_clears_it() {
        let hour = Duration::from_secs(3600);
        let mut m = OwnershipMap::new();
        let (_, a) = m.reserve(0, 20, 0, 0);
        m.publish(a, 0, 20);
        let (stale, b) = m.reserve(0, 20, 0, 20);
        assert_eq!(stale, 20);
        assert_eq!(m.region_heat(0, hour), (20, 20), "pending rewrites count as hot");
        m.publish(b, 0, 20);
        assert_eq!(m.region_heat(0, hour), (20, 20), "publish preserves heat");
        assert_eq!(m.release_region(0), 20);
        assert_eq!(m.region_heat(0, hour), (0, 0));
        // the settled range starts cold again on its next buffered write
        m.claim(0, 20, ssd(0, 40));
        assert_eq!(m.region_heat(0, hour), (20, 0));
    }

    #[test]
    fn conservation_holds_across_reserve_publish_churn() {
        // the shard's invariant, at map level: sectors booked at reserve
        // == live + superseded, no matter how publishes interleave
        let mut m = OwnershipMap::new();
        let mut rng = crate::util::prng::Prng::new(77);
        let mut reserved = 0i64;
        let mut superseded = 0i64;
        let mut in_flight: Vec<(u64, i64, i64)> = Vec::new();
        for i in 0..400usize {
            if !in_flight.is_empty() && rng.chance(0.4) {
                let (t, lba, size) = in_flight.swap_remove(rng.gen_range(in_flight.len() as u64) as usize);
                m.publish(t, lba, size);
            } else {
                let lba = rng.gen_range(1500) as i64;
                let size = 1 + rng.gen_range(48) as i64;
                let (stale, t) = m.reserve(lba, size, i % 2, i as i64 * 48);
                reserved += size;
                superseded += stale;
                in_flight.push((t, lba, size));
            }
            assert_eq!(m.ssd_sectors() + superseded, reserved, "step {i}");
        }
    }
}
