//! The **live** burst-buffer engine: a real-time, multi-threaded SSDUP+
//! runtime built from the same detector / redirector / buffer components
//! the discrete-event simulator evaluates — the repo's first step from
//! *reproducing* the paper to *being* the system it describes.
//!
//! Architecture (one engine = N shards = N live I/O nodes):
//!
//! ```text
//!  clients ──► LiveEngine::submit ──stripe──► Shard 0..N-1
//!                                              │  ingest: detect → route
//!                                              │    ├─ HDD  (direct write)
//!                                              │    └─ SSD  (two-region log append)
//!                                              └─ flusher thread: traffic-aware
//!                                                 pause gate, SSD→HDD drain
//! ```
//!
//! * [`backend`] — pluggable byte stores with **concurrent positional
//!   (`&self`) I/O**: in-memory (tests/benches, with synthetic device
//!   latency, bounded-concurrency knee, and sharded page locks) and real
//!   files (`pwrite`/`pread`, `ssdup live --backend file`) — plus the
//!   **submission/completion queue** ([`backend::IoQueue`]): batched
//!   submit, vectored coalescing, worker-pool drivers, completion
//!   tokens;
//! * [`commit`] — the **group-commit sequencer** ([`GroupSync`]): wraps
//!   each backend so concurrent publishers share device sync barriers —
//!   one elected leader runs the fsync, a synced-up-to watermark
//!   releases every waiter the barrier covers — instead of issuing one
//!   fsync per record;
//! * [`shard`] — one live I/O node: detector + policy + two-region
//!   pipeline + SSD/HDD backend pair + background flusher with the
//!   paper's traffic-aware pause gate (§2.4.2);
//! * [`engine`] — N shards behind OrangeFS-style striping, wall-clock
//!   drain, and byte-exact verification;
//! * [`flushsched`] — the **array-level flush coordinator**
//!   ([`FlushCoordinator`]): a token budget over the shared HDD tier
//!   that staggers the per-shard flushers instead of letting them
//!   collide (see *Flushing* below);
//! * [`loadgen`] — closed-loop concurrent load generator over the
//!   `workload::*` patterns, recording p50/p95/p99 request latency;
//! * [`ownership`] — the per-shard **sector-ownership extent map**: which
//!   tier (SSD log slot or HDD) holds the newest copy of every sector,
//!   including claims whose device bytes are still in flight;
//! * [`payload`] — deterministic sector contents (optionally versioned
//!   per write) so every byte on the HDD backends can be re-derived and
//!   checked after a run — including *which* copy of a rewritten sector
//!   survived;
//! * [`record`] — the **crash-consistent log format**: self-describing
//!   record frames (magic, shard, region, LBA, length, monotone
//!   sequence, CRC-32C over header + payload), the per-shard superblock
//!   (epoch, clean-shutdown flag, flush watermarks, file table), and the
//!   recovery scanner that validates frames, discards torn stretches,
//!   and re-synchronizes past them.
//!
//! Concurrency model: a shard has exactly one lock — its core mutex —
//! and **no thread ever holds it across device I/O**. Ingest runs
//! **reserve → enqueue → complete → barrier → publish**: route + slot +
//! ownership claim under the lock, then the client thread *enqueues* its
//! device write onto the shard's per-device submission queue
//! ([`backend::IoQueue`]) and parks on a completion token instead of
//! performing the I/O inline. A small pool of I/O workers (N ≪ clients,
//! `--io-workers`) drains the queue — coalescing byte-adjacent requests
//! into single vectored device writes — and delivers each completion
//! with the group-commit ticket its batch advanced; the woken client
//! waits out a barrier covering that ticket and briefly re-acquires the
//! lock to publish. Queue depth (`--io-depth`) is therefore decoupled
//! from thread count: many clients keep many writes in flight through
//! few workers. Reads run resolve→pin→read inline (the flusher waits
//! out a region's reader pins before recycling its slots), and the
//! flusher snapshots its copy set under the lock but moves every byte
//! through the same HDD queue, windowing several copy runs into one
//! batch. Many clients submitting to one shard therefore overlap their
//! device transfers, and mid-burst reads proceed concurrently with
//! ingest and flushing.
//!
//! Semantics note: overwrites are fully supported, across routes and
//! mid-burst. Every ingest claims its sector range in the shard's
//! ownership map; a rewrite supersedes the older buffered copy (the
//! flusher skips it — stale-flush suppression), and a direct-to-HDD
//! write that would overlap live buffered data is absorbed into the SSD
//! log so it can never race the flusher for the same HDD sectors. Reads
//! ([`LiveEngine::read`]) resolve through the same map and always serve
//! the newest copy, even while a burst is still buffered; a read
//! overlapping a claim whose device bytes are still in flight waits for
//! that claim to publish first. Claim order — fixed under the core lock
//! at reserve time, before any bytes move — is the engine's write order:
//! two *concurrent* writers to the same sector are unordered as ever
//! (the map keeps the engine consistent; the workload decides whether
//! that order is meaningful), but once a claim is made, no older write
//! can resurface under it — in-flight direct writes are waited out
//! rather than raced.
//!
//! # Durability contract
//!
//! The engine distinguishes three states per write, in order:
//!
//! 1. **Submitted** — `LiveEngine::submit` was called but has not
//!    returned. Nothing is promised: a crash may keep all, part (at
//!    sector granularity), or none of the bytes. A torn record frame is
//!    detected by its checksum at recovery and discarded whole. In
//!    particular, a write frozen **between its device write and its
//!    covering barrier** is still only submitted — its bytes sit in the
//!    device cache and are allowed to vanish.
//! 2. **Acknowledged (published)** — `submit` returned. The write is
//!    **durable**: its framed record (SSD route) or its HDD bytes
//!    (direct route) are covered by a **completed group-commit barrier**
//!    — a device sync that started after the bytes landed finished
//!    before the claim published — and for the first write of each file
//!    the file-table superblock was barriered before that.
//!    "Covered by a completed barrier" rather than "ran its own fsync"
//!    is the group-commit refinement ([`commit::GroupSync`]): N
//!    concurrent publishers of a shard are released by one shared
//!    device sync (a sync is a device-global barrier, so one covers
//!    them all), cutting the publish path's fsync count by the batching
//!    factor (`ShardStats::writes_per_sync`) without weakening the
//!    promise. [`LiveEngine::open`] restores every acknowledged write
//!    byte-exactly after a crash, however ungraceful — this is what the
//!    crash-injection tests kill-and-check, including freezes injected
//!    between a record's device write and its barrier.
//!    A `group_commit_window > 0` lets an elected barrier leader wait
//!    (boundedly) for in-flight writes to land before syncing: bigger
//!    batches, at the cost of up to one window of added ack latency
//!    under concurrency — a lone writer always syncs immediately.
//! 3. **Flushed** — the flusher settled the (surviving) buffered copy
//!    onto the HDD, waited out a covering HDD barrier, and only then
//!    persisted the superblock's flush watermark — all *before* the log
//!    region recycles, so recovery never replays a settled record over
//!    newer data, and never loses one that had not settled. After
//!    [`LiveEngine::shutdown`] (drain + clean superblock), reopening
//!    short-circuits without any log scan.
//!
//! # Flushing
//!
//! Each shard runs one flusher thread, but the HDD tier they drain into
//! is *shared* — uncoordinated, N flushers opening their gates at once
//! interfere on it exactly the way unsynchronized per-device maintenance
//! wrecks array throughput. Three mechanisms keep the array side sane:
//!
//! * **Coordinator** ([`flushsched::FlushCoordinator`], on by default
//!   with `--flush-concurrency 2`): before a flush cycle's copy runs, a
//!   flusher acquires an HDD-bandwidth token; at most the budget's worth
//!   of shards copy concurrently, and among waiters the coordinator
//!   grants strictly by need — highest SSD-log occupancy first, then
//!   longest wait, so the fullest/stalest log always drains next. The
//!   wait is booked as the `flush_token_wait` stage. The token covers
//!   copy runs and the covering HDD barrier only; superblock writes and
//!   settling happen after release. `--flush-concurrency 0` disables
//!   coordination (free-running flushers, the pre-scheduler baseline).
//! * **Starvation bound**: a waiter whose log occupancy crosses the
//!   starvation threshold (default 85%) or that has waited past the
//!   starvation window (default 250 ms) is granted a token *beyond* the
//!   budget — a nearly-full log is never blocked behind it (counted in
//!   `FlushCoordinator::beyond_budget_grants`, asserted zero in tests
//!   that expect the budget to hold). The same occupancy map closes the
//!   loop on the ingest side: a shard whose log is markedly fuller than
//!   the array mean stops *attracting new* SSD-routed streams (they are
//!   biased to the HDD route; stable assignment of existing streams is
//!   preserved — `ShardStats::biased_streams`).
//! * **Hot/cold deferral** (`--hot-defer-window MS`, off by default):
//!   the ownership map tracks per-extent rewrite heat; when a queued
//!   region's surviving extents are mostly *hot* (recently superseded
//!   LBAs — likely to be rewritten again), the flusher defers the region
//!   within the bounded window, betting the next rewrite supersedes them
//!   in the buffer so the HDD never sees the doomed copy. Deferral ends
//!   early on drain, ingest backpressure, or high occupancy — it trades
//!   *idle* time only, never blocks a writer. Effectiveness is measured
//!   by `ShardStats::superseded_at_flush` (bytes superseded while
//!   queued-for-flush / bytes queued): the flush-amplification the
//!   deferral removed.
//!
//! Recovery replays surviving records in their claim (sequence) order,
//! so the newest-copy-wins semantics above carry across a restart:
//! rewrites recover to exactly the version an uncrashed run would have
//! settled. Detection/routing state is deliberately soft — a recovered
//! shard starts with a fresh detector and policy history.
//!
//! Limit: the file→extent table is persisted in one superblock sector,
//! so a live shard supports at most [`record::MAX_SB_FILES`] distinct
//! files; the 58th first-touch fails the shard with a named error (the
//! paper's workloads use one shared file per application).
//!
//! # Failure semantics
//!
//! Device errors are typed ([`fault::IoFault`]) and handled where they
//! are cheapest to handle — the engine never panics on an I/O error:
//!
//! * **Transient faults** (EINTR/EIO blips, timeouts) are absorbed
//!   *below* the acknowledgement: the I/O-queue workers, the group-sync
//!   leaders, and the read paths re-drive the operation under a bounded
//!   exponential-backoff budget ([`fault::RetryPolicy`]). A write that
//!   published went through a completed barrier on its *final,
//!   successful* attempt, so "acknowledged" means exactly what it means
//!   in the durability contract above — faults or no faults. Retries
//!   surface as `ShardStats::{io_retries, transient_faults}` and as the
//!   `fault_retry` stage, never as client errors.
//! * **Permanent SSD faults and SSD ENOSPC** flip the shard into sticky
//!   **degraded mode**: the claim is aborted (bookkeeping rolled back),
//!   the flag is persisted in the superblock, and every new write —
//!   including the failed one, which re-enters the claim loop — routes
//!   direct to HDD. Buffered data still settles through the flusher
//!   (SSD *reads* still work after a write-side failure) and reads still
//!   serve the newest copy. A degraded write that overlaps live buffered
//!   data waits for those claims to settle rather than racing them, so
//!   no stale copy can resurface. Recovery restores the degraded flag.
//! * **Permanent HDD faults** fail the shard: the HDD is the home tier,
//!   there is nothing left to route around. Every blocked and future
//!   `submit`/`read` on the shard returns a typed rejection
//!   ([`shard::SubmitError::Failed`] / [`shard::ReadError`]) naming the
//!   original cause; acknowledged writes remain durable.
//! * **Shutdown** is its own fault kind, not an `io::Error` string:
//!   submits and reads racing a shutdown return
//!   [`shard::SubmitError::Shutdown`] / [`shard::ReadError::Shutdown`].
//!
//! Fault injection is built in: `ssdup live --fault-spec` wraps every
//! backend in a seeded, deterministic [`fault::FaultBackend`]. The
//! grammar is comma-separated clauses of
//! `device:kind[@op=N][:p=F][:op=N][:transient=K][:delay_us=N][:min_off=N][:max_off=N]`
//! with `device` ∈ {`ssd`, `hdd`} and `kind` ∈ {`eio`, `enospc`,
//! `slow`, `dead`} — e.g. `ssd:eio:p=0.01:transient=3` (1% of SSD ops
//! fail EIO, each healing after 3 attempts), `hdd:dead@op=5000` (HDD
//! dies permanently at its 5000th op), `ssd:enospc:p=0.02`. The
//! fault-matrix suite (`tests/fault_injection.rs`) drives these scripts
//! end to end and checks the promises above, crash-and-recover included.
//!
//! # Observability
//!
//! The engine is instrumented end to end by [`crate::obs`] — zero
//! dependencies, like everything else in the crate:
//!
//! * **Stage taxonomy** ([`crate::obs::Stage`]) — every pipeline stage
//!   is named and timed: `submit` (whole ack path) decomposes into
//!   `route` → `reserve` → `io_submit` → `queue_wait` →
//!   `ssd_write`/`hdd_write` → `barrier_wait` → `publish`; reads into
//!   `read_resolve` → `read_device`; the flusher
//!   reports `flush_run` (SSD→HDD copy time), `flush_pause` (gate
//!   time), and `flush_token_wait` (coordinator queueing); `sb_write`
//!   and `replay` cover superblock rewrites and recovery.
//! * **Per-stage latency attribution** — each shard folds every span
//!   into per-stage [`crate::server::metrics::LatencyHistogram`]s;
//!   [`LiveReport::stage_summary`] prints the p50/p95/p99 decomposition
//!   of ack latency and names the dominant stage. Attribution is always
//!   on: its cost is a handful of `Instant::now` reads plus one
//!   uncontended leaf-mutex fold per operation.
//! * **Tracing** ([`crate::obs::TraceCollector`]) — `ssdup live --trace
//!   out.json` records every span into lock-free per-thread rings
//!   (overflow drops events, never blocks the data path) and exports
//!   Chrome `chrome://tracing` / Perfetto JSON. Disabled tracing costs
//!   one relaxed atomic load per span — the overhead contract
//!   `bench_live` asserts.
//! * **Snapshots** ([`crate::obs::Snapshotter`]) — `ssdup live
//!   --stats-interval MS` emits one JSON line per interval (throughput,
//!   writes/sync, blocked waits, flusher duty cycle, SSD occupancy) from
//!   a sampler thread that only reads counters.
//!
//! # Invariants
//!
//! The rules this module's design hangs on, stated once. Each is
//! machine-checked by `ssdup check` ([`crate::analysis`], a blocking CI
//! job), so violating one is a lint error before it is a review comment:
//!
//! 1. **No device I/O under the core lock** (`lock-io`). A shard's core
//!    mutex orders bookkeeping, never device service time: ingest is
//!    reserve → *unlock* → enqueue/wait → relock → publish, and the
//!    flusher snapshots its copy set under the lock but copies outside
//!    it. The deliberate exceptions — the first-touch superblock write
//!    and the `degrade` transition, where the flip must be atomic with
//!    the failure observation — are enumerated in
//!    `rust/src/analysis/allow.toml` with their reasons.
//! 2. **Acknowledged ⇒ durable** (the durability contract above), with
//!    its bookkeeping corollary **conservation**: per shard,
//!    `ssd_bytes_buffered == flushed_bytes + superseded_bytes` after a
//!    drain. Checked dynamically by the integration/property suites; the
//!    static side is rule 3.
//! 3. **Every `ShardStats` counter is wired end to end**
//!    (`stats-wiring`): booked on the hot path, folded in
//!    `Shard::stats`, surfaced in the run report, and emitted by the
//!    snapshotter — a counter that silently vanishes on one path is how
//!    conservation drifted twice during review in PRs 7–9.
//! 4. **Every stage is booked and smoke-required** (`stage-taxonomy`):
//!    a [`crate::obs::Stage`] variant must have a live call site and
//!    appear in CI's `trace-check --require` list, so a stage going
//!    silent fails the build instead of skewing attribution.
//! 5. **Atomics state their ordering contract** (`atomic-ordering`):
//!    every non-test `Ordering::` use carries an adjacent comment naming
//!    the pairing (or why none is needed). `SeqCst` is held to the same
//!    bar — in this engine it is almost always a missing justification,
//!    not a stronger guarantee.
//! 6. **The fault path degrades, never dies** (`panic-free`):
//!    `unwrap`/`expect`/`panic!` are banned in [`fault`], [`backend`]
//!    and [`shard`] outside tests — a panic under the ack poisons the
//!    core mutex and turns one transient EIO into a wedged shard.
//!    Poison-propagating `.lock()/.wait*()` unwraps are exempt; the few
//!    real invariant assertions live in allow.toml, each with its why.

pub mod backend;
pub mod commit;
pub mod engine;
pub mod fault;
pub mod flushsched;
pub mod loadgen;
pub mod ownership;
pub mod payload;
pub mod record;
pub mod shard;

pub use backend::{
    Backend, Completion, CompletionToken, FileBackend, IoQueue, IoQueueStats, IoReq, MemBackend,
    MemStore, SyntheticLatency,
};
pub use commit::GroupSync;
pub use engine::{LiveConfig, LiveEngine, RecoveryReport, VerifyReport};
pub use fault::{FaultBackend, FaultSpec, IoFault, RetryPolicy};
pub use flushsched::{FlushCoordinator, FlushToken};
pub use loadgen::{
    run as run_load, run_reported as run_load_reported, run_with as run_load_with, LiveReport,
    SnapshotOptions,
};
pub use ownership::{OwnershipMap, Tier};
pub use record::{LiveRecord, RecordHeader, Superblock};
pub use shard::{ReadError, Shard, ShardConfig, ShardRecovery, ShardStats, SubmitError};
