//! The **live** burst-buffer engine: a real-time, multi-threaded SSDUP+
//! runtime built from the same detector / redirector / buffer components
//! the discrete-event simulator evaluates — the repo's first step from
//! *reproducing* the paper to *being* the system it describes.
//!
//! Architecture (one engine = N shards = N live I/O nodes):
//!
//! ```text
//!  clients ──► LiveEngine::submit ──stripe──► Shard 0..N-1
//!                                              │  ingest: detect → route
//!                                              │    ├─ HDD  (direct write)
//!                                              │    └─ SSD  (two-region log append)
//!                                              └─ flusher thread: traffic-aware
//!                                                 pause gate, SSD→HDD drain
//! ```
//!
//! * [`backend`] — pluggable byte stores: in-memory (tests/benches, with
//!   synthetic device latency) and real files (`ssdup live --backend file`);
//! * [`shard`] — one live I/O node: detector + policy + two-region
//!   pipeline + SSD/HDD backend pair + background flusher with the
//!   paper's traffic-aware pause gate (§2.4.2);
//! * [`engine`] — N shards behind OrangeFS-style striping, wall-clock
//!   drain, and byte-exact verification;
//! * [`loadgen`] — closed-loop concurrent load generator over the
//!   `workload::*` patterns, recording p50/p95/p99 request latency;
//! * [`ownership`] — the per-shard **sector-ownership extent map**: which
//!   tier (SSD log slot or HDD) holds the newest copy of every sector;
//! * [`payload`] — deterministic sector contents (optionally versioned
//!   per write) so every byte on the HDD backends can be re-derived and
//!   checked after a run — including *which* copy of a rewritten sector
//!   survived.
//!
//! Semantics note: overwrites are fully supported, across routes and
//! mid-burst. Every ingest claims its sector range in the shard's
//! ownership map; a rewrite supersedes the older buffered copy (the
//! flusher skips it — stale-flush suppression), and a direct-to-HDD
//! write that would overlap live buffered data is absorbed into the SSD
//! log so it can never race the flusher for the same HDD sectors. Reads
//! ([`LiveEngine::read`]) resolve through the same map and always serve
//! the newest copy, even while a burst is still buffered. The one
//! remaining caveat is *concurrent* writers to the same sector: with no
//! ordering between two in-flight client writes, "newest" is whichever
//! claim lands last (the map keeps the engine consistent; the workload
//! decides whether that order is meaningful).

pub mod backend;
pub mod engine;
pub mod loadgen;
pub mod ownership;
pub mod payload;
pub mod shard;

pub use backend::{Backend, FileBackend, MemBackend, SyntheticLatency};
pub use engine::{LiveConfig, LiveEngine, VerifyReport};
pub use loadgen::{run as run_load, run_with as run_load_with, LiveReport};
pub use ownership::{OwnershipMap, Tier};
pub use shard::{Shard, ShardConfig, ShardStats};
