//! The **live** burst-buffer engine: a real-time, multi-threaded SSDUP+
//! runtime built from the same detector / redirector / buffer components
//! the discrete-event simulator evaluates — the repo's first step from
//! *reproducing* the paper to *being* the system it describes.
//!
//! Architecture (one engine = N shards = N live I/O nodes):
//!
//! ```text
//!  clients ──► LiveEngine::submit ──stripe──► Shard 0..N-1
//!                                              │  ingest: detect → route
//!                                              │    ├─ HDD  (direct write)
//!                                              │    └─ SSD  (two-region log append)
//!                                              └─ flusher thread: traffic-aware
//!                                                 pause gate, SSD→HDD drain
//! ```
//!
//! * [`backend`] — pluggable byte stores with **concurrent positional
//!   (`&self`) I/O**: in-memory (tests/benches, with synthetic device
//!   latency and sharded page locks) and real files (`pwrite`/`pread`,
//!   `ssdup live --backend file`);
//! * [`shard`] — one live I/O node: detector + policy + two-region
//!   pipeline + SSD/HDD backend pair + background flusher with the
//!   paper's traffic-aware pause gate (§2.4.2);
//! * [`engine`] — N shards behind OrangeFS-style striping, wall-clock
//!   drain, and byte-exact verification;
//! * [`loadgen`] — closed-loop concurrent load generator over the
//!   `workload::*` patterns, recording p50/p95/p99 request latency;
//! * [`ownership`] — the per-shard **sector-ownership extent map**: which
//!   tier (SSD log slot or HDD) holds the newest copy of every sector,
//!   including claims whose device bytes are still in flight;
//! * [`payload`] — deterministic sector contents (optionally versioned
//!   per write) so every byte on the HDD backends can be re-derived and
//!   checked after a run — including *which* copy of a rewritten sector
//!   survived.
//!
//! Concurrency model: a shard has exactly one lock — its core mutex —
//! and **no thread ever holds it across device I/O**. Ingest runs
//! reserve→publish (route + slot + ownership claim under the lock,
//! device write unlocked, brief re-acquire to publish), reads run
//! resolve→pin→read (the flusher waits out a region's reader pins before
//! recycling its slots), and the flusher snapshots its copy set under
//! the lock but moves every byte without it. Many clients submitting to
//! one shard therefore overlap their device transfers, and mid-burst
//! reads proceed concurrently with ingest and flushing.
//!
//! Semantics note: overwrites are fully supported, across routes and
//! mid-burst. Every ingest claims its sector range in the shard's
//! ownership map; a rewrite supersedes the older buffered copy (the
//! flusher skips it — stale-flush suppression), and a direct-to-HDD
//! write that would overlap live buffered data is absorbed into the SSD
//! log so it can never race the flusher for the same HDD sectors. Reads
//! ([`LiveEngine::read`]) resolve through the same map and always serve
//! the newest copy, even while a burst is still buffered; a read
//! overlapping a claim whose device bytes are still in flight waits for
//! that claim to publish first. Claim order — fixed under the core lock
//! at reserve time, before any bytes move — is the engine's write order:
//! two *concurrent* writers to the same sector are unordered as ever
//! (the map keeps the engine consistent; the workload decides whether
//! that order is meaningful), but once a claim is made, no older write
//! can resurface under it — in-flight direct writes are waited out
//! rather than raced.

pub mod backend;
pub mod engine;
pub mod loadgen;
pub mod ownership;
pub mod payload;
pub mod shard;

pub use backend::{Backend, FileBackend, MemBackend, SyntheticLatency};
pub use engine::{LiveConfig, LiveEngine, VerifyReport};
pub use loadgen::{run as run_load, run_with as run_load_with, LiveReport};
pub use ownership::{OwnershipMap, Tier};
pub use shard::{Shard, ShardConfig, ShardStats};
