//! The **live** burst-buffer engine: a real-time, multi-threaded SSDUP+
//! runtime built from the same detector / redirector / buffer components
//! the discrete-event simulator evaluates — the repo's first step from
//! *reproducing* the paper to *being* the system it describes.
//!
//! Architecture (one engine = N shards = N live I/O nodes):
//!
//! ```text
//!  clients ──► LiveEngine::submit ──stripe──► Shard 0..N-1
//!                                              │  ingest: detect → route
//!                                              │    ├─ HDD  (direct write)
//!                                              │    └─ SSD  (two-region log append)
//!                                              └─ flusher thread: traffic-aware
//!                                                 pause gate, SSD→HDD drain
//! ```
//!
//! * [`backend`] — pluggable byte stores: in-memory (tests/benches, with
//!   synthetic device latency) and real files (`ssdup live --backend file`);
//! * [`shard`] — one live I/O node: detector + policy + two-region
//!   pipeline + SSD/HDD backend pair + background flusher with the
//!   paper's traffic-aware pause gate (§2.4.2);
//! * [`engine`] — N shards behind OrangeFS-style striping, wall-clock
//!   drain, and byte-exact verification;
//! * [`loadgen`] — closed-loop concurrent load generator over the
//!   `workload::*` patterns, recording p50/p95/p99 request latency;
//! * [`payload`] — deterministic sector contents so every byte on the HDD
//!   backends can be re-derived and checked after a run.
//!
//! Semantics note: like the simulator (and the paper's write-burst
//! evaluation), the engine models a write-only burst path with no
//! cross-route overwrite tracking. A sector rewritten *after* the route
//! flipped from SSD to HDD still has its older buffered copy flushed at
//! drain, which would then win. HPC checkpoint bursts never rewrite a
//! sector within a burst; a general-purpose store would need buffered-
//! extent invalidation on the direct path (future PR, together with the
//! read path).

pub mod backend;
pub mod engine;
pub mod loadgen;
pub mod payload;
pub mod shard;

pub use backend::{Backend, FileBackend, MemBackend, SyntheticLatency};
pub use engine::{LiveConfig, LiveEngine, VerifyReport};
pub use loadgen::{run as run_load, LiveReport};
pub use shard::{Shard, ShardConfig, ShardStats};
