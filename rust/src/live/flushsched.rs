//! Array-aware flush scheduling: a global token budget over the HDD
//! tier's bandwidth.
//!
//! Each shard's flusher owns its own SSD log, but every flusher drains
//! into the *same* HDD array. Left uncoordinated, they open their
//! traffic gates at once and their copy runs interleave on the disk —
//! exactly the unsynchronized-maintenance collapse Zheng et al. describe
//! for GC in SSD arrays. The [`FlushCoordinator`] is the array-wide
//! antidote: one instance is shared by every shard of a
//! [`crate::live::LiveEngine`], and a flusher must hold a [`FlushToken`]
//! before it starts a flush cycle's copy runs. At most `budget` tokens
//! are outstanding at a time, so flush cycles stagger instead of
//! colliding.
//!
//! # Grant order
//!
//! When a token frees up it goes to the *most urgent* waiter, not the
//! first one: higher SSD-log occupancy wins, ties break toward the
//! waiter that has been queued longest (staleness), then toward the
//! lower shard id for determinism. A waiter that gives up a timed
//! [`FlushCoordinator::acquire`] slice (to re-check its own shutdown
//! flag) stays registered, so seniority survives the caller's retry
//! loop; a flusher that stops trying altogether must call
//! [`FlushCoordinator::abandon`] so it cannot shadow-block the queue.
//!
//! # Starvation bound
//!
//! A strict budget could wedge a nearly-full log behind a slow peer:
//! the shard would stall ingest (writers block on log space) while its
//! token request sits in queue. Two escape hatches bound that wait —
//! a waiter whose reported occupancy is at or above
//! `starve_occupancy`, or one that has waited at least `starve_wait`,
//! is granted *beyond* the budget. Such grants are counted
//! ([`FlushCoordinator::beyond_budget_grants`]) so tests and telemetry
//! can tell a healthy stagger from a budget that is simply too small.
//!
//! # Ingest-side signal
//!
//! Shards report their log occupancy on every acquire, so the
//! coordinator doubles as the array's cheapest load map. The ingest
//! path uses [`FlushCoordinator::is_hot`] to steer *new* streams on a
//! standout-full shard away from its SSD log (LBICA's load-balancer
//! framing): existing streams keep their stable route, but a shard
//! whose log is both meaningfully full and clearly above the array
//! mean starts new streams direct-to-HDD until it cools off.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Occupancy floor below which a shard is never considered hot for the
/// ingest-bias signal, no matter how idle its peers are: steering
/// streams off a half-empty log would only throw buffer hits away.
const HOT_FLOOR: f32 = 0.5;

/// A registered token request. `since` is the waiter's first enqueue
/// for its current flush cycle and persists across timed-out acquire
/// slices — it is the staleness half of the grant priority.
#[derive(Clone, Copy, Debug)]
struct Waiter {
    shard: u32,
    occupancy: f32,
    since: Instant,
}

#[derive(Debug)]
struct State {
    /// Shards currently holding a token (length may exceed the budget
    /// only via the starvation escape hatch).
    holders: Vec<u32>,
    /// Registered waiters, unordered (priority is computed at grant
    /// time so occupancy refreshes take effect immediately).
    waiters: Vec<Waiter>,
    /// Last log occupancy each shard reported, in `[0, 1]`; indexed by
    /// shard id. Drives both grant priority and the ingest-bias map.
    occupancy: Vec<f32>,
    /// Escape-hatch grants issued while the budget was exhausted.
    beyond_budget_grants: u64,
}

/// Shared token/budget scheduler over the HDD tier's bandwidth. See the
/// module docs for the model; see [`FlushToken`] for the RAII grant.
#[derive(Debug)]
pub struct FlushCoordinator {
    budget: usize,
    starve_occupancy: f32,
    starve_wait: Duration,
    state: Mutex<State>,
    grants: Condvar,
}

impl FlushCoordinator {
    /// A coordinator for `shards` shards granting at most `budget`
    /// concurrent flush tokens. The starvation bound defaults to
    /// occupancy ≥ 0.85 or 250 ms of queueing, whichever trips first.
    pub fn new(budget: usize, shards: usize) -> Self {
        assert!(budget >= 1, "flush budget must admit at least one shard");
        Self {
            budget,
            starve_occupancy: 0.85,
            starve_wait: Duration::from_millis(250),
            state: Mutex::new(State {
                holders: Vec::new(),
                waiters: Vec::new(),
                occupancy: vec![0.0; shards],
                beyond_budget_grants: 0,
            }),
            grants: Condvar::new(),
        }
    }

    /// Override the starvation escape hatch (tests pin it; `--ssd-mib`
    /// extremes may want a different occupancy trip point).
    pub fn with_starvation(mut self, occupancy: f32, wait: Duration) -> Self {
        self.starve_occupancy = occupancy;
        self.starve_wait = wait;
        self
    }

    /// Wait up to `patience` for a flush token. `occupancy` is the
    /// caller's current SSD-log fill fraction; it is recorded for the
    /// load map and used as this waiter's grant priority. Returns
    /// `None` on timeout — the waiter *stays queued* (seniority kept),
    /// so callers loop `acquire` in short slices around their own
    /// shutdown checks and call [`FlushCoordinator::abandon`] if they
    /// stop trying.
    pub fn acquire(self: &Arc<Self>, shard: u32, occupancy: f32, patience: Duration) -> Option<FlushToken> {
        let deadline = Instant::now() + patience;
        let mut st = self.state.lock().unwrap();
        st.occupancy[shard as usize] = occupancy;
        match st.waiters.iter_mut().find(|w| w.shard == shard) {
            Some(w) => w.occupancy = occupancy,
            None => {
                let since = Instant::now();
                st.waiters.push(Waiter { shard, occupancy, since });
            }
        }
        loop {
            if self.grantable(&st, shard) {
                st.waiters.retain(|w| w.shard != shard);
                if st.holders.len() >= self.budget {
                    st.beyond_budget_grants += 1;
                }
                st.holders.push(shard);
                // a grant can free the "best waiter" slot for the next
                // queued shard while budget remains — wake them to check
                self.grants.notify_all();
                return Some(FlushToken { co: Arc::clone(self), shard });
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            st = self.grants.wait_timeout(st, deadline - now).unwrap().0;
        }
    }

    /// Deregister `shard`'s pending token request (no-op when absent).
    /// Required when a flusher exits its acquire loop without a grant —
    /// a shut-down shard left in the queue would out-rank live waiters
    /// forever.
    pub fn abandon(&self, shard: u32) {
        let mut st = self.state.lock().unwrap();
        st.waiters.retain(|w| w.shard != shard);
        self.grants.notify_all();
    }

    /// Grant check, under the state lock. Within budget only the single
    /// highest-priority waiter may take the token (its grant re-wakes
    /// the rest, so multiple free slots drain the queue in priority
    /// order); past budget only the starvation escape hatch applies.
    fn grantable(&self, st: &State, shard: u32) -> bool {
        let Some(me) = st.waiters.iter().find(|w| w.shard == shard) else {
            return false;
        };
        if st.holders.len() < self.budget {
            let best = st.waiters.iter().min_by(|a, b| Self::rank(a, b));
            best.map(|w| w.shard) == Some(shard)
        } else {
            me.occupancy >= self.starve_occupancy || me.since.elapsed() >= self.starve_wait
        }
    }

    /// Priority order: `Less` = granted first. Fullest log, then the
    /// longest-queued waiter, then the lowest shard id.
    fn rank(a: &Waiter, b: &Waiter) -> std::cmp::Ordering {
        b.occupancy
            .total_cmp(&a.occupancy)
            .then(a.since.cmp(&b.since))
            .then(a.shard.cmp(&b.shard))
    }

    fn release(&self, shard: u32) {
        let mut st = self.state.lock().unwrap();
        if let Some(i) = st.holders.iter().position(|&h| h == shard) {
            st.holders.swap_remove(i);
        }
        self.grants.notify_all();
    }

    /// Update the load map outside an acquire (e.g. after a flush cycle
    /// settles, when occupancy just dropped).
    pub fn report_occupancy(&self, shard: u32, occupancy: f32) {
        self.state.lock().unwrap().occupancy[shard as usize] = occupancy;
    }

    /// Last occupancy `shard` reported (0.0 until its first report).
    pub fn occupancy_of(&self, shard: u32) -> f32 {
        self.state.lock().unwrap().occupancy[shard as usize]
    }

    /// Mean of the last-reported occupancies across all shards.
    pub fn mean_occupancy(&self) -> f32 {
        let st = self.state.lock().unwrap();
        if st.occupancy.is_empty() {
            return 0.0;
        }
        st.occupancy.iter().sum::<f32>() / st.occupancy.len() as f32
    }

    /// Ingest-bias signal: is `shard`'s log both meaningfully full
    /// (≥ 0.5) and at least `margin` above the array mean? New streams
    /// arriving on a hot shard are started direct-to-HDD.
    pub fn is_hot(&self, shard: u32, margin: f32) -> bool {
        let st = self.state.lock().unwrap();
        let occ = st.occupancy[shard as usize];
        let mean = st.occupancy.iter().sum::<f32>() / st.occupancy.len().max(1) as f32;
        occ >= HOT_FLOOR && occ >= mean + margin
    }

    /// Shards currently holding a flush token (snapshot, telemetry).
    pub fn holders(&self) -> Vec<u32> {
        self.state.lock().unwrap().holders.clone()
    }

    /// Number of outstanding tokens (snapshot, telemetry).
    pub fn holder_count(&self) -> usize {
        self.state.lock().unwrap().holders.len()
    }

    /// Grants issued past the budget by the starvation escape hatch.
    pub fn beyond_budget_grants(&self) -> u64 {
        self.state.lock().unwrap().beyond_budget_grants
    }

    /// The configured concurrency budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    #[cfg(test)]
    fn waiter_count(&self) -> usize {
        self.state.lock().unwrap().waiters.len()
    }
}

/// RAII flush grant: holding one entitles the shard's flusher to run
/// its copy runs against the HDD tier; dropping it releases the token
/// and wakes the queue.
#[derive(Debug)]
pub struct FlushToken {
    co: Arc<FlushCoordinator>,
    shard: u32,
}

impl FlushToken {
    /// The shard this token was granted to.
    pub fn shard(&self) -> u32 {
        self.shard
    }
}

impl Drop for FlushToken {
    fn drop(&mut self) {
        self.co.release(self.shard);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    const SLICE: Duration = Duration::from_millis(5);
    const LONG: Duration = Duration::from_secs(10);

    /// A starvation bound far beyond test runtimes, so only the budget
    /// and priority rules are in play.
    fn strict(budget: usize, shards: usize) -> Arc<FlushCoordinator> {
        Arc::new(FlushCoordinator::new(budget, shards).with_starvation(2.0, LONG))
    }

    /// Spin until `pred` holds (10 s cap — the suite's poll-deadline
    /// idiom for cross-thread state).
    fn wait_for(mut pred: impl FnMut() -> bool) {
        let deadline = Instant::now() + LONG;
        while !pred() {
            assert!(Instant::now() < deadline, "condition never held");
            thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn uncontended_acquire_is_immediate_and_drop_releases() {
        let co = strict(2, 4);
        let tok = co.acquire(3, 0.1, Duration::ZERO).expect("budget free");
        assert_eq!(tok.shard(), 3);
        assert_eq!(co.holders(), vec![3]);
        assert_eq!(co.holder_count(), 1);
        drop(tok);
        assert_eq!(co.holder_count(), 0);
        assert_eq!(co.beyond_budget_grants(), 0);
    }

    #[test]
    fn budget_caps_concurrent_holders() {
        let co = strict(1, 2);
        let held = co.acquire(0, 0.5, Duration::ZERO).expect("first grant");
        // the second shard cannot get in while the token is held ...
        assert!(co.acquire(1, 0.5, SLICE).is_none());
        assert_eq!(co.holder_count(), 1);
        let (tx, rx) = mpsc::channel();
        let co2 = Arc::clone(&co);
        let waiter = thread::spawn(move || {
            let tok = loop {
                if let Some(t) = co2.acquire(1, 0.5, SLICE) {
                    break t;
                }
            };
            tx.send(()).unwrap();
            tok
        });
        // ... and stays blocked until the holder releases
        assert!(rx.recv_timeout(Duration::from_millis(50)).is_err());
        drop(held);
        rx.recv_timeout(LONG).expect("waiter granted after release");
        assert_eq!(co.holders(), vec![1]);
        drop(waiter.join().unwrap());
        assert_eq!(co.holder_count(), 0);
    }

    #[test]
    fn fullest_log_wins_the_next_token() {
        let co = strict(1, 3);
        let held = co.acquire(0, 0.3, Duration::ZERO).unwrap();
        let (tx, rx) = mpsc::channel();
        let mut threads = Vec::new();
        for (shard, occ) in [(1u32, 0.2f32), (2, 0.9)] {
            let co = Arc::clone(&co);
            let tx = tx.clone();
            threads.push(thread::spawn(move || {
                let tok = loop {
                    if let Some(t) = co.acquire(shard, occ, SLICE) {
                        break t;
                    }
                };
                tx.send(shard).unwrap();
                // hold briefly so the grants arrive strictly in turn
                thread::sleep(Duration::from_millis(10));
                drop(tok);
            }));
        }
        wait_for(|| co.waiter_count() == 2);
        drop(held);
        assert_eq!(rx.recv_timeout(LONG).unwrap(), 2, "fullest log first");
        assert_eq!(rx.recv_timeout(LONG).unwrap(), 1);
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn timed_out_waiter_keeps_seniority() {
        let co = strict(1, 3);
        let held = co.acquire(0, 0.5, Duration::ZERO).unwrap();
        // shard 1 starts waiting first and keeps timing out in slices
        let (tx1, rx1) = mpsc::channel();
        let co1 = Arc::clone(&co);
        let t1 = thread::spawn(move || {
            let tok = loop {
                if let Some(t) = co1.acquire(1, 0.5, SLICE) {
                    break t;
                }
            };
            tx1.send(()).unwrap();
            tok
        });
        wait_for(|| co.waiter_count() == 1);
        thread::sleep(Duration::from_millis(25)); // let at least one slice expire
        // shard 2 joins later with the same occupancy
        let (tx2, rx2) = mpsc::channel();
        let co2 = Arc::clone(&co);
        let t2 = thread::spawn(move || {
            let tok = loop {
                if let Some(t) = co2.acquire(2, 0.5, SLICE) {
                    break t;
                }
            };
            tx2.send(()).unwrap();
            tok
        });
        wait_for(|| co.waiter_count() == 2);
        drop(held);
        // seniority survived shard 1's timed-out slices: it wins the tie
        rx1.recv_timeout(LONG).expect("senior waiter granted first");
        assert!(rx2.recv_timeout(Duration::from_millis(50)).is_err());
        drop(t1.join().unwrap());
        rx2.recv_timeout(LONG).expect("junior waiter granted after release");
        drop(t2.join().unwrap());
    }

    #[test]
    fn abandon_unblocks_junior_waiters() {
        let co = strict(1, 3);
        let held = co.acquire(0, 0.5, Duration::ZERO).unwrap();
        // shard 1 queues with the higher occupancy, then gives up
        assert!(co.acquire(1, 0.9, SLICE).is_none());
        assert_eq!(co.waiter_count(), 1);
        let (tx, rx) = mpsc::channel();
        let co2 = Arc::clone(&co);
        let t = thread::spawn(move || {
            let tok = loop {
                if let Some(t) = co2.acquire(2, 0.1, SLICE) {
                    break t;
                }
            };
            tx.send(()).unwrap();
            tok
        });
        wait_for(|| co.waiter_count() == 2);
        drop(held);
        // shard 1 out-ranks shard 2 but is not actually waiting: until
        // it abandons, shard 2 must not be granted
        assert!(rx.recv_timeout(Duration::from_millis(50)).is_err());
        co.abandon(1);
        rx.recv_timeout(LONG).expect("granted once the senior ghost left");
        drop(t.join().unwrap());
    }

    #[test]
    fn starving_shard_is_granted_beyond_the_budget() {
        let co =
            Arc::new(FlushCoordinator::new(1, 2).with_starvation(0.85, Duration::from_secs(60)));
        let _held = co.acquire(0, 0.5, Duration::ZERO).unwrap();
        // occupancy at the trip point bypasses the exhausted budget
        let tok = co.acquire(1, 0.9, SLICE).expect("escape hatch fires");
        assert_eq!(co.holder_count(), 2);
        assert_eq!(co.beyond_budget_grants(), 1);
        drop(tok);
        assert_eq!(co.holders(), vec![0]);
    }

    #[test]
    fn long_wait_trips_the_starvation_hatch_too() {
        let co =
            Arc::new(FlushCoordinator::new(1, 2).with_starvation(2.0, Duration::from_millis(20)));
        let _held = co.acquire(0, 0.5, Duration::ZERO).unwrap();
        let t0 = Instant::now();
        let tok = loop {
            if let Some(t) = co.acquire(1, 0.1, SLICE) {
                break t;
            }
            assert!(t0.elapsed() < LONG, "wait-based hatch never fired");
        };
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert_eq!(co.beyond_budget_grants(), 1);
        drop(tok);
    }

    #[test]
    fn occupancy_map_feeds_the_ingest_bias() {
        let co = strict(1, 4);
        for (shard, occ) in [(0u32, 0.9f32), (1, 0.2), (2, 0.2), (3, 0.2)] {
            co.report_occupancy(shard, occ);
        }
        assert_eq!(co.occupancy_of(0), 0.9);
        assert!((co.mean_occupancy() - 0.375).abs() < 1e-6);
        // shard 0 stands out above the mean and above the 0.5 floor
        assert!(co.is_hot(0, 0.25));
        assert!(!co.is_hot(1, 0.25), "cold shard is never hot");
        // a full-but-uniform array has no standout to steer away from
        for shard in 0..4 {
            co.report_occupancy(shard, 0.9);
        }
        assert!(!co.is_hot(0, 0.25));
        // below the floor, standing out is not enough
        for shard in 0..4 {
            co.report_occupancy(shard, 0.05);
        }
        co.report_occupancy(0, 0.45);
        assert!(!co.is_hot(0, 0.25));
    }
}
