//! Deterministic request payloads.
//!
//! Every sector a client writes carries content that is a pure function of
//! `(file, logical sector, generation)`, so after a run *any* byte on the
//! HDD backends can be re-derived and verified — the live engine's
//! end-to-end proof that buffering, flushing, and striping moved data to
//! the right place.
//!
//! Generation 0 is the classic write-once pattern: rewrites of the same
//! sector produce the same bytes, so verification is insensitive to write
//! order. Multi-version (rewrite) workloads instead stamp each request
//! with a unique [`write_gen`] so *which* copy survived is checkable too
//! — that is what lets the tests prove the flusher never resurrects a
//! stale buffered copy.

use crate::types::SECTOR_BYTES;
use crate::util::prng::SplitMix64;

/// The 8-byte pattern repeated through sector `sector` of `file` at write
/// generation `gen` (`gen == 0` is the unversioned pattern).
#[inline]
pub fn sector_pattern_gen(file: u32, sector: i64, gen: u64) -> [u8; 8] {
    let seed = ((file as u64) << 40)
        ^ (sector as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ gen.wrapping_mul(0xD1B5_4A32_D192_ED03);
    SplitMix64::new(seed).next_u64().to_le_bytes()
}

/// The unversioned (generation-0) pattern for sector `sector` of `file`.
#[inline]
pub fn sector_pattern(file: u32, sector: i64) -> [u8; 8] {
    sector_pattern_gen(file, sector, 0)
}

/// Generation tag for the `idx`-th request of process `proc_id`: unique
/// per (process, request), so any two writes of the same sector produce
/// different bytes. The `+ 1` keeps generation 0 — the unversioned
/// pattern — out of the versioned space entirely.
#[inline]
pub fn write_gen(proc_id: u32, idx: u32) -> u64 {
    ((proc_id as u64 + 1) << 32) | idx as u64
}

/// Fill `buf` (a whole number of sectors) with the generation-`gen`
/// payload for the extent starting at `(file, start_sector)`.
pub fn fill_gen(file: u32, start_sector: i64, gen: u64, buf: &mut [u8]) {
    let sector_bytes = SECTOR_BYTES as usize;
    debug_assert_eq!(buf.len() % sector_bytes, 0, "payload must be sector-aligned");
    for (k, sector_buf) in buf.chunks_mut(sector_bytes).enumerate() {
        let pat = sector_pattern_gen(file, start_sector + k as i64, gen);
        for chunk in sector_buf.chunks_mut(8) {
            chunk.copy_from_slice(&pat[..chunk.len()]);
        }
    }
}

/// Fill `buf` with the unversioned payload for `(file, start_sector)`.
pub fn fill(file: u32, start_sector: i64, buf: &mut [u8]) {
    fill_gen(file, start_sector, 0, buf);
}

/// Does `sector_buf` (one sector) hold exactly the pattern for
/// `(file, sector, gen)`?
#[inline]
pub fn sector_matches(file: u32, sector: i64, gen: u64, sector_buf: &[u8]) -> bool {
    let pat = sector_pattern_gen(file, sector, gen);
    sector_buf.chunks(8).all(|chunk| chunk == &pat[..chunk.len()])
}

/// Count the sectors of `buf` that do NOT hold the expected unversioned
/// payload for the extent starting at `(file, start_sector)`. 0 means
/// fully verified.
pub fn mismatched_sectors(file: u32, start_sector: i64, buf: &[u8]) -> u64 {
    let sector_bytes = SECTOR_BYTES as usize;
    debug_assert_eq!(buf.len() % sector_bytes, 0, "payload must be sector-aligned");
    buf.chunks(sector_bytes)
        .enumerate()
        .filter(|(k, sector_buf)| !sector_matches(file, start_sector + *k as i64, 0, sector_buf))
        .count() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_then_verify_round_trips() {
        let mut buf = vec![0u8; 4 * SECTOR_BYTES as usize];
        fill(7, 1000, &mut buf);
        assert_eq!(mismatched_sectors(7, 1000, &buf), 0);
    }

    #[test]
    fn corruption_is_detected_per_sector() {
        let mut buf = vec![0u8; 4 * SECTOR_BYTES as usize];
        fill(7, 1000, &mut buf);
        buf[SECTOR_BYTES as usize + 3] ^= 0xFF; // corrupt sector 1 only
        assert_eq!(mismatched_sectors(7, 1000, &buf), 1);
    }

    #[test]
    fn patterns_differ_across_files_and_sectors() {
        assert_ne!(sector_pattern(1, 0), sector_pattern(2, 0));
        assert_ne!(sector_pattern(1, 0), sector_pattern(1, 1));
        assert_eq!(sector_pattern(3, 9), sector_pattern(3, 9));
    }

    #[test]
    fn shifted_extent_is_a_mismatch() {
        let mut buf = vec![0u8; 2 * SECTOR_BYTES as usize];
        fill(1, 50, &mut buf);
        // claiming the same bytes came from sector 51 must fail
        assert_eq!(mismatched_sectors(1, 51, &buf), 2);
    }

    #[test]
    fn generations_produce_distinct_verifiable_bytes() {
        let s = SECTOR_BYTES as usize;
        let mut v1 = vec![0u8; s];
        let mut v2 = vec![0u8; s];
        fill_gen(1, 10, write_gen(0, 0), &mut v1);
        fill_gen(1, 10, write_gen(0, 1), &mut v2);
        assert_ne!(v1, v2, "rewrites must be distinguishable");
        assert!(sector_matches(1, 10, write_gen(0, 0), &v1));
        assert!(!sector_matches(1, 10, write_gen(0, 1), &v1));
        assert!(sector_matches(1, 10, write_gen(0, 1), &v2));
    }

    #[test]
    fn generation_zero_is_the_unversioned_pattern() {
        assert_eq!(sector_pattern_gen(5, 77, 0), sector_pattern(5, 77));
        // and write_gen never collides with it
        assert_ne!(write_gen(0, 0), 0);
    }

    #[test]
    fn write_gens_are_unique_per_process_and_index() {
        let a = write_gen(0, 0);
        let b = write_gen(0, 1);
        let c = write_gen(1, 0);
        assert!(a != b && a != c && b != c);
    }
}
