//! Deterministic request payloads.
//!
//! Every sector a client writes carries content that is a pure function of
//! `(file, logical sector)`, so after a run *any* byte on the HDD backends
//! can be re-derived and verified — the live engine's end-to-end proof
//! that buffering, flushing, and striping moved data to the right place.
//! Rewrites of the same sector produce the same bytes, so verification is
//! insensitive to write order.

use crate::types::SECTOR_BYTES;
use crate::util::prng::SplitMix64;

/// The 8-byte pattern repeated through sector `sector` of `file`.
#[inline]
pub fn sector_pattern(file: u32, sector: i64) -> [u8; 8] {
    let seed = ((file as u64) << 40) ^ (sector as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    SplitMix64::new(seed).next_u64().to_le_bytes()
}

/// Fill `buf` (a whole number of sectors) with the payload for the extent
/// starting at `(file, start_sector)`.
pub fn fill(file: u32, start_sector: i64, buf: &mut [u8]) {
    let sector_bytes = SECTOR_BYTES as usize;
    debug_assert_eq!(buf.len() % sector_bytes, 0, "payload must be sector-aligned");
    for (k, sector_buf) in buf.chunks_mut(sector_bytes).enumerate() {
        let pat = sector_pattern(file, start_sector + k as i64);
        for chunk in sector_buf.chunks_mut(8) {
            chunk.copy_from_slice(&pat[..chunk.len()]);
        }
    }
}

/// Count the sectors of `buf` that do NOT hold the expected payload for
/// the extent starting at `(file, start_sector)`. 0 means fully verified.
pub fn mismatched_sectors(file: u32, start_sector: i64, buf: &[u8]) -> u64 {
    let sector_bytes = SECTOR_BYTES as usize;
    debug_assert_eq!(buf.len() % sector_bytes, 0, "payload must be sector-aligned");
    let mut bad = 0;
    for (k, sector_buf) in buf.chunks(sector_bytes).enumerate() {
        let pat = sector_pattern(file, start_sector + k as i64);
        let ok = sector_buf.chunks(8).all(|chunk| chunk == &pat[..chunk.len()]);
        if !ok {
            bad += 1;
        }
    }
    bad
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_then_verify_round_trips() {
        let mut buf = vec![0u8; 4 * SECTOR_BYTES as usize];
        fill(7, 1000, &mut buf);
        assert_eq!(mismatched_sectors(7, 1000, &buf), 0);
    }

    #[test]
    fn corruption_is_detected_per_sector() {
        let mut buf = vec![0u8; 4 * SECTOR_BYTES as usize];
        fill(7, 1000, &mut buf);
        buf[SECTOR_BYTES as usize + 3] ^= 0xFF; // corrupt sector 1 only
        assert_eq!(mismatched_sectors(7, 1000, &buf), 1);
    }

    #[test]
    fn patterns_differ_across_files_and_sectors() {
        assert_ne!(sector_pattern(1, 0), sector_pattern(2, 0));
        assert_ne!(sector_pattern(1, 0), sector_pattern(1, 1));
        assert_eq!(sector_pattern(3, 9), sector_pattern(3, 9));
    }

    #[test]
    fn shifted_extent_is_a_mismatch() {
        let mut buf = vec![0u8; 2 * SECTOR_BYTES as usize];
        fill(1, 50, &mut buf);
        // claiming the same bytes came from sector 51 must fail
        assert_eq!(mismatched_sectors(1, 51, &buf), 2);
    }
}
