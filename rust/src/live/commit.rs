//! Group commit: one device sync barrier for many concurrent publishers.
//!
//! The durability contract (acknowledged ⇒ durable) requires every
//! publish path to put a `sync()` between its device writes and its
//! acknowledgment. Doing that literally — one `sync` per record — means
//! N concurrent clients of a shard issue N fsyncs where one device
//! barrier would cover all of them: the classic fsync amplification that
//! batched burst buffers coalesce away. [`GroupSync`] is that
//! coalescing layer, wrapped around each backend:
//!
//! * every completed [`Backend::write_at`] advances a **completed-writes
//!   watermark** — a publisher's *ticket* is the watermark value when it
//!   enters [`GroupSync::barrier`], i.e. "everything I wrote is below
//!   this". Queued I/O ([`IoQueue`]) drives the same watermark
//!   *completion-side*: a worker books its batch with
//!   [`GroupSync::begin_write`], performs the raw device writes, and
//!   advances the watermark with [`GroupSync::note_write`], whose return
//!   value is exactly the ticket covering the batch — the parked client
//!   then waits on [`GroupSync::barrier_for`] with that ticket, so
//!   barriers cover queued writes precisely (not merely "everything
//!   completed by the time I woke up");
//! * the first waiter not yet covered becomes the **leader**: it
//!   snapshots the watermark (the cutoff), runs the one real
//!   `inner.sync()`, and publishes the cutoff as the new **synced-up-to
//!   watermark**;
//! * every waiter whose ticket the cutoff covers is released by that
//!   single sync; waiters that ticketed later wait for the next leader
//!   (at most one extra sync — while a sync is in flight, arrivals
//!   accumulate behind it, which is where the batching comes from even
//!   with a zero window).
//!
//! This is sound because a device `sync` is a *global* barrier: it makes
//! every write completed before it **started** durable, not just the
//! caller's (`fdatasync` flushes the file, [`MemStore`'s] snapshot sync
//! merges the whole overlay). So a sync whose start-snapshot covers a
//! ticket covers all of that ticket's writes.
//!
//! The optional **batching window** trades ack latency for bigger
//! batches: an elected leader waits up to the window for *in-flight*
//! writes to land (and ticket) before issuing its sync. A lone writer is
//! never delayed — with nothing in flight, the leader syncs immediately
//! — and the wait is bounded by the window regardless.
//!
//! Sync failures are first classified ([`IoFault`]): transient faults
//! are retried with bounded backoff ([`RetryPolicy::io_default`],
//! counted in [`GroupSync::sync_retries`]) before the failure counts. A
//! failure that survives the retries is **sticky**: every current and
//! future waiter gets the error (their writes may not be durable, so
//! releasing them as "covered" would forge acknowledgments). The shard
//! turns that into a typed submit failure — or degraded-mode routing
//! when the SSD tier is the one that died.
//!
//! [`MemStore`'s]: crate::live::backend::MemStore
//! [`IoQueue`]: crate::live::backend::IoQueue

use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::live::backend::Backend;
use crate::live::fault::{retry_transient, IoFault, RetryPolicy};
use crate::obs::{Stage, TraceCollector};

/// State under the sequencer mutex. The counters are monotone: `synced`
/// chases `completed`, and a barrier with ticket `t` may return as soon
/// as `synced >= t`.
struct CommitState {
    /// `write_at` calls currently inside the device (started, not done)
    in_flight: u64,
    /// `write_at` calls completed — the ticket source
    completed: u64,
    /// highest completed-watermark covered by a finished sync
    synced: u64,
    /// a leader is running (or about to run) the device sync
    leader: bool,
    /// first sync error, sticky: no later barrier may claim coverage
    failed: Option<String>,
}

/// A [`Backend`] wrapper that coalesces concurrent publishers' sync
/// barriers into single device syncs (see the module docs). All the
/// positional I/O passes straight through; only [`GroupSync::barrier`]
/// adds behavior.
pub struct GroupSync {
    inner: Box<dyn Backend>,
    state: Mutex<CommitState>,
    cv: Condvar,
    /// max time an elected leader waits for in-flight writes to land
    window: Duration,
    /// `false` = per-record sync (the ungrouped baseline, for the bench
    /// A/B and as an escape hatch): every barrier runs its own sync
    enabled: bool,
    /// transient sync faults are retried with this backoff before the
    /// failure is allowed to go sticky
    retry: RetryPolicy,
    /// device syncs actually issued (leaders + passthrough `sync` calls;
    /// a retried sync still counts once)
    syncs: AtomicU64,
    /// barriers requested (≈ acknowledged publishes); `barriers / syncs`
    /// is the batching factor
    barriers: AtomicU64,
    /// sync re-attempts taken after transient faults
    sync_retries: AtomicU64,
    /// transient faults observed during device syncs
    sync_transient_faults: AtomicU64,
    /// trace sink for barrier-wait spans: every `barrier()` — publisher,
    /// flusher, or superblock — shows up on the shard's timeline
    trace: Option<(Arc<TraceCollector>, u32)>,
}

impl GroupSync {
    pub fn new(inner: Box<dyn Backend>, enabled: bool, window: Duration) -> Self {
        Self {
            inner,
            state: Mutex::new(CommitState {
                in_flight: 0,
                completed: 0,
                synced: 0,
                leader: false,
                failed: None,
            }),
            cv: Condvar::new(),
            window,
            enabled,
            retry: RetryPolicy::io_default(),
            syncs: AtomicU64::new(0),
            barriers: AtomicU64::new(0),
            sync_retries: AtomicU64::new(0),
            sync_transient_faults: AtomicU64::new(0),
            trace: None,
        }
    }

    /// Attach a trace collector: barrier calls emit `barrier_wait` spans
    /// tagged with `shard` while the collector is enabled.
    pub fn with_trace(mut self, obs: Arc<TraceCollector>, shard: u32) -> Self {
        self.trace = Some((obs, shard));
        self
    }

    /// Device syncs issued so far.
    pub fn syncs(&self) -> u64 {
        // Relaxed: stats counter read, no synchronization implied
        self.syncs.load(Ordering::Relaxed)
    }

    /// Barriers requested so far (each a would-be fsync without grouping).
    pub fn barriers(&self) -> u64 {
        // Relaxed: stats counter read, no synchronization implied
        self.barriers.load(Ordering::Relaxed)
    }

    /// Sync re-attempts taken after transient faults.
    pub fn sync_retries(&self) -> u64 {
        // Relaxed: stats counter read, no synchronization implied
        self.sync_retries.load(Ordering::Relaxed)
    }

    /// Transient faults observed during device syncs.
    pub fn sync_transient_faults(&self) -> u64 {
        // Relaxed: stats counter read, no synchronization implied
        self.sync_transient_faults.load(Ordering::Relaxed)
    }

    /// One logical device sync with transient faults retried per the
    /// policy; the `syncs` counter advances once whatever the attempt
    /// count, so the sync-amplification metric stays comparable.
    fn sync_retried(&self) -> io::Result<()> {
        // Relaxed: sync-amplification counter; durability ordering comes
        // from the device sync itself, not from these stats
        self.syncs.fetch_add(1, Ordering::Relaxed);
        let (result, retries) = retry_transient(&self.retry, || self.inner.sync());
        let mut faults = u64::from(retries);
        if let Err(e) = &result {
            if IoFault::classify(e).is_transient() {
                faults += 1;
            }
        }
        if retries > 0 {
            // Relaxed: fault-accounting counter (as above)
            self.sync_retries.fetch_add(u64::from(retries), Ordering::Relaxed);
        }
        if faults > 0 {
            // Relaxed: fault-accounting counter (as above)
            self.sync_transient_faults.fetch_add(faults, Ordering::Relaxed);
        }
        result
    }

    /// Book `n` writes as in flight **before** they reach the device —
    /// the submission half of the completion-driven entry point used by
    /// queued I/O. A leader sitting in its batching window sees queued
    /// traffic exactly like inline writers' and waits for it (boundedly).
    /// Must be balanced by a [`GroupSync::note_write`] of the same count.
    /// No-op in ungrouped mode.
    pub fn begin_write(&self, n: u64) {
        if !self.enabled || n == 0 {
            return;
        }
        self.state.lock().unwrap().in_flight += n;
    }

    /// Completion half: `n` booked writes finished on the device. Moves
    /// them in-flight → completed and returns the new completed
    /// watermark — the **ticket** a [`GroupSync::barrier_for`] needs to
    /// cover exactly those writes. Returns 0 in ungrouped mode (tickets
    /// are meaningless there; every barrier runs its own sync).
    pub fn note_write(&self, n: u64) -> u64 {
        if !self.enabled {
            return 0;
        }
        let mut st = self.state.lock().unwrap();
        st.in_flight -= n;
        st.completed += n;
        let ticket = st.completed;
        // a leader may be sitting in its batching window waiting for
        // exactly these writes to land
        let wake = st.leader;
        drop(st);
        if wake {
            self.cv.notify_all();
        }
        ticket
    }

    /// Raw passthrough gather write with **no sequencer bookkeeping** —
    /// for queue workers, whose batches are booked via
    /// [`GroupSync::begin_write`] / [`GroupSync::note_write`] instead
    /// (one booking may cover a whole vectored transfer).
    pub fn write_vectored_raw(&self, offset: u64, bufs: &[&[u8]]) -> io::Result<()> {
        self.inner.write_vectored_at(offset, bufs)
    }

    /// Block until every `write_at` this thread completed before the call
    /// is covered by a **finished** device sync, running that sync itself
    /// if it is elected leader. Returns the sticky sync error if any
    /// covering sync failed — the caller's bytes may not be durable.
    pub fn barrier(&self) -> io::Result<()> {
        self.barrier_traced(None)
    }

    /// Like [`GroupSync::barrier`], but waits for coverage of an explicit
    /// `ticket` (a [`GroupSync::note_write`] return value) instead of
    /// stamping the watermark at entry — the precise form for queued
    /// writes, immune to unrelated completions inflating the wait.
    pub fn barrier_for(&self, ticket: u64) -> io::Result<()> {
        self.barrier_traced(Some(ticket))
    }

    fn barrier_traced(&self, ticket: Option<u64>) -> io::Result<()> {
        let t0 = match &self.trace {
            Some((obs, _)) if obs.is_enabled() => Some(Instant::now()),
            _ => None,
        };
        let result = self.barrier_inner(ticket);
        if let (Some(t0), Some((obs, shard))) = (t0, &self.trace) {
            obs.emit(Stage::BarrierWait, *shard, t0, Instant::now());
        }
        result
    }

    fn barrier_inner(&self, ticket: Option<u64>) -> io::Result<()> {
        // Relaxed: stats counter; the barrier's ordering guarantees come
        // from the ticket watermark + device sync below
        self.barriers.fetch_add(1, Ordering::Relaxed);
        if !self.enabled {
            // ungrouped baseline: the caller pays its own fsync
            return self.sync_retried();
        }
        let mut st = self.state.lock().unwrap();
        let ticket = ticket.unwrap_or(st.completed);
        loop {
            if let Some(msg) = &st.failed {
                return Err(io::Error::other(msg.clone()));
            }
            if st.synced >= ticket {
                return Ok(());
            }
            if st.leader {
                st = self.cv.wait(st).unwrap();
                continue;
            }
            // ---- elected leader ----
            st.leader = true;
            if !self.window.is_zero() {
                // bounded batching window: let in-flight writes land (and
                // their publishers ticket) so this sync covers them too.
                // With nothing in flight a lone writer skips this wait.
                let deadline = Instant::now() + self.window;
                while st.in_flight > 0 && st.failed.is_none() {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    st = self.cv.wait_timeout(st, deadline - now).unwrap().0;
                }
            }
            let cutoff = st.completed; // >= ticket: the leader covers itself
            drop(st);
            // transient faults retried here, before the failure can go
            // sticky and poison every future barrier on this device
            let result = self.sync_retried();
            st = self.state.lock().unwrap();
            st.leader = false;
            match result {
                Ok(()) => st.synced = st.synced.max(cutoff),
                Err(e) => {
                    st.failed.get_or_insert(format!("group sync: {e}"));
                }
            }
            self.cv.notify_all();
            // loop re-checks: covered (ticket <= cutoff) or sticky error
        }
    }
}

impl Backend for GroupSync {
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        if !self.enabled {
            // ungrouped mode never consults the counters: keep the
            // baseline's write path free of sequencer lock traffic
            return self.inner.write_at(offset, data);
        }
        self.state.lock().unwrap().in_flight += 1;
        let result = self.inner.write_at(offset, data);
        let mut st = self.state.lock().unwrap();
        st.in_flight -= 1;
        st.completed += 1;
        // a leader may be sitting in its batching window waiting for
        // exactly this write to land
        let wake = st.leader;
        drop(st);
        if wake {
            self.cv.notify_all();
        }
        result
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        self.inner.read_at(offset, buf)
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    /// Plain passthrough sync (drain/shutdown paths that are not
    /// publisher barriers). Counted, so `syncs` is the device fsync
    /// total; transient faults are retried like a leader's sync.
    fn sync(&self) -> io::Result<()> {
        self.sync_retried()
    }

    fn kind(&self) -> &'static str {
        self.inner.kind()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;
    use std::sync::Arc;

    use super::*;

    /// Mock device with exact fsync semantics: a sync snapshots the set
    /// of written-but-uncovered offsets at its **start** and marks them
    /// durable at its **end** — precisely the claim a real barrier
    /// makes, no more. `gate` (when armed) parks the first sync until
    /// released, so tests can pile followers behind a leader
    /// deterministically.
    struct MockDevice {
        state: Mutex<MockState>,
        cv: Condvar,
        fail_syncs: bool,
    }

    struct MockState {
        /// offsets written, not yet covered by a finished sync
        pending: Vec<u64>,
        durable: HashSet<u64>,
        writes: u64,
        /// 0 = open, 1 = armed, 2 = armed and reached (sync parked)
        gate: u8,
        /// this many syncs fail transiently (before covering anything)
        transient_left: u64,
    }

    impl MockDevice {
        fn new() -> Self {
            Self {
                state: Mutex::new(MockState {
                    pending: Vec::new(),
                    durable: HashSet::new(),
                    writes: 0,
                    gate: 0,
                    transient_left: 0,
                }),
                cv: Condvar::new(),
                fail_syncs: false,
            }
        }

        /// First sync will park until [`MockDevice::release`].
        fn armed() -> Self {
            let b = Self::new();
            b.state.lock().unwrap().gate = 1;
            b
        }

        fn failing() -> Self {
            let mut b = Self::new();
            b.fail_syncs = true;
            b
        }

        /// The next `n` syncs fail with a transient fault.
        fn transient_failing(n: u64) -> Self {
            let b = Self::new();
            b.state.lock().unwrap().transient_left = n;
            b
        }

        fn wait_sync_parked(&self) {
            let mut st = self.state.lock().unwrap();
            while st.gate != 2 {
                st = self.cv.wait(st).unwrap();
            }
        }

        fn release(&self) {
            self.state.lock().unwrap().gate = 0;
            self.cv.notify_all();
        }

        fn is_durable(&self, offset: u64) -> bool {
            self.state.lock().unwrap().durable.contains(&offset)
        }
    }

    impl Backend for MockDevice {
        fn write_at(&self, offset: u64, _data: &[u8]) -> io::Result<()> {
            let mut st = self.state.lock().unwrap();
            st.writes += 1;
            st.pending.push(offset);
            Ok(())
        }

        fn read_at(&self, _offset: u64, buf: &mut [u8]) -> io::Result<()> {
            buf.fill(0);
            Ok(())
        }

        fn bytes_written(&self) -> u64 {
            self.state.lock().unwrap().writes
        }

        fn sync(&self) -> io::Result<()> {
            // a sync covers exactly the writes completed before it
            // started: snapshot first, then (maybe) park on the gate —
            // writes landing while parked are NOT covered
            let mut st = self.state.lock().unwrap();
            if st.transient_left > 0 {
                // fails before covering anything: pending stays pending
                st.transient_left -= 1;
                return Err(IoFault::Transient.error("injected transient sync failure"));
            }
            let snap: Vec<u64> = st.pending.drain(..).collect();
            if st.gate == 1 {
                st.gate = 2;
                self.cv.notify_all();
                while st.gate != 0 {
                    st = self.cv.wait(st).unwrap();
                }
            }
            if self.fail_syncs {
                // a failed sync promises nothing: its snapshot is lost
                return Err(io::Error::other("injected sync failure"));
            }
            st.durable.extend(snap);
            Ok(())
        }

        fn kind(&self) -> &'static str {
            "mock"
        }
    }

    /// `Arc<MockDevice>` is itself a `Backend` (blanket impl in
    /// `backend.rs`), so the sequencer can own one handle while the
    /// test keeps another.
    fn grouped(mock: &Arc<MockDevice>, window: Duration) -> GroupSync {
        GroupSync::new(Box::new(Arc::clone(mock)), true, window)
    }

    #[test]
    fn one_leader_sync_releases_every_queued_follower() {
        // deterministic leader/follower choreography: A leads and parks
        // inside the device sync; B, C, D write + barrier behind it; one
        // more sync covers all three. 4 publishers, exactly 2 fsyncs.
        let mock = Arc::new(MockDevice::armed());
        let gs = Arc::new(grouped(&mock, Duration::ZERO));
        std::thread::scope(|s| {
            let leader = {
                let gs = Arc::clone(&gs);
                let mock = Arc::clone(&mock);
                s.spawn(move || {
                    gs.write_at(100, b"a").unwrap();
                    gs.barrier().unwrap();
                    assert!(mock.is_durable(100), "leader released without its own coverage");
                })
            };
            mock.wait_sync_parked(); // A is leader, inside inner.sync()
            let followers: Vec<_> = (0..3u64)
                .map(|i| {
                    let gs = Arc::clone(&gs);
                    let mock = Arc::clone(&mock);
                    s.spawn(move || {
                        gs.write_at(i, b"f").unwrap();
                        gs.barrier().unwrap();
                        // released only by a sync that started after this
                        // write completed — and *finished*
                        assert!(
                            mock.is_durable(i),
                            "follower {i} released before a covering barrier completed"
                        );
                    })
                })
                .collect();
            // all four ticketed (A parked + 3 followers queued behind it)
            while gs.barriers() < 4 {
                std::thread::sleep(Duration::from_micros(100));
            }
            mock.release();
            leader.join().unwrap();
            for f in followers {
                f.join().unwrap();
            }
        });
        assert_eq!(gs.barriers(), 4);
        assert_eq!(gs.syncs(), 2, "leader's sync + one follower-elected sync");
    }

    #[test]
    fn concurrent_publishers_never_release_early_and_syncs_stay_bounded() {
        // property run: every barrier must find its own offset durable on
        // release, and total syncs can never exceed total barriers (each
        // sync has exactly one leader, and a leader leads at most once
        // per barrier). Exercised with and without a batching window.
        for window_us in [0u64, 300] {
            let mock = Arc::new(MockDevice::new());
            let gs = grouped(&mock, Duration::from_micros(window_us));
            const THREADS: u64 = 8;
            const ROUNDS: u64 = 25;
            std::thread::scope(|s| {
                for t in 0..THREADS {
                    let gs = &gs;
                    let mock = &mock;
                    s.spawn(move || {
                        for r in 0..ROUNDS {
                            let offset = t * ROUNDS + r; // globally unique
                            gs.write_at(offset, b"x").unwrap();
                            gs.barrier().unwrap();
                            assert!(
                                mock.is_durable(offset),
                                "t{t} r{r}: barrier returned before a sync covered the write"
                            );
                        }
                    });
                }
            });
            assert_eq!(gs.barriers(), THREADS * ROUNDS);
            assert!(
                gs.syncs() <= gs.barriers(),
                "window {window_us}us: {} syncs > {} barriers",
                gs.syncs(),
                gs.barriers()
            );
            assert!(gs.syncs() >= 1);
        }
    }

    #[test]
    fn lone_writer_is_not_delayed_by_the_batching_window() {
        // nothing in flight at election: the leader must skip the window
        // wait entirely, not burn it down
        let mock = Arc::new(MockDevice::new());
        let gs = grouped(&mock, Duration::from_secs(5));
        let t0 = Instant::now();
        gs.write_at(0, b"solo").unwrap();
        gs.barrier().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "lone barrier waited the batching window: {:?}",
            t0.elapsed()
        );
        assert!(mock.is_durable(0));
        assert_eq!(gs.syncs(), 1);
    }

    #[test]
    fn disabled_mode_syncs_once_per_barrier() {
        let mock = Arc::new(MockDevice::new());
        let gs = GroupSync::new(Box::new(Arc::clone(&mock)), false, Duration::ZERO);
        for i in 0..5u64 {
            gs.write_at(i, b"x").unwrap();
            gs.barrier().unwrap();
            assert!(mock.is_durable(i));
        }
        assert_eq!(gs.syncs(), 5, "ungrouped baseline is one fsync per barrier");
        assert_eq!(gs.barriers(), 5);
    }

    #[test]
    fn note_write_ticket_is_covered_exactly_by_barrier_for() {
        let mock = Arc::new(MockDevice::new());
        let gs = grouped(&mock, Duration::ZERO);
        // completion-driven path: book, raw-write (a 2-buffer gather),
        // complete, then wait on the returned ticket
        gs.begin_write(2);
        gs.write_vectored_raw(10, &[b"a", b"b"]).unwrap();
        let ticket = gs.note_write(2);
        assert_eq!(ticket, 2, "two completions advance the watermark to 2");
        gs.barrier_for(ticket).unwrap();
        assert!(mock.is_durable(10) && mock.is_durable(11));
        // the same ticket is already covered: no second device sync
        let syncs = gs.syncs();
        gs.barrier_for(ticket).unwrap();
        assert_eq!(gs.syncs(), syncs, "a covered ticket must not elect a new leader");
    }

    #[test]
    fn leader_window_covers_queued_writes_and_is_cut_short_by_note_write() {
        let mock = Arc::new(MockDevice::new());
        let gs = Arc::new(grouped(&mock, Duration::from_secs(5)));
        gs.write_at(0, b"x").unwrap();
        gs.begin_write(1); // one queued write is in flight
        let t0 = Instant::now();
        std::thread::scope(|s| {
            let leader = {
                let gs = Arc::clone(&gs);
                s.spawn(move || gs.barrier().unwrap())
            };
            std::thread::sleep(Duration::from_millis(20));
            // the "worker" completes the queued write inside the leader's
            // window; its ticket lands under the same cutoff
            gs.write_vectored_raw(7, &[b"q"]).unwrap();
            let ticket = gs.note_write(1);
            gs.barrier_for(ticket).unwrap();
            leader.join().unwrap();
        });
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "note_write must cut the window short, not burn it down: {:?}",
            t0.elapsed()
        );
        assert!(mock.is_durable(0) && mock.is_durable(7));
        assert_eq!(gs.syncs(), 1, "one sync covered the inline and the queued write");
    }

    #[test]
    fn ungrouped_mode_note_write_is_inert_and_barrier_for_still_syncs() {
        let mock = Arc::new(MockDevice::new());
        let gs = GroupSync::new(Box::new(Arc::clone(&mock)), false, Duration::ZERO);
        gs.begin_write(1);
        gs.write_vectored_raw(3, &[b"z"]).unwrap();
        let ticket = gs.note_write(1);
        assert_eq!(ticket, 0, "no tickets in the per-record-fsync baseline");
        gs.barrier_for(ticket).unwrap();
        assert!(mock.is_durable(3), "baseline barrier_for pays its own fsync");
        assert_eq!(gs.syncs(), 1);
    }

    #[test]
    fn transient_sync_faults_are_retried_before_going_sticky() {
        let mock = Arc::new(MockDevice::transient_failing(2));
        let gs = grouped(&mock, Duration::ZERO);
        gs.write_at(0, b"x").unwrap();
        gs.barrier().unwrap();
        assert!(mock.is_durable(0), "the barrier rode out both transient faults");
        assert_eq!(gs.sync_retries(), 2);
        assert_eq!(gs.sync_transient_faults(), 2);
        assert_eq!(gs.syncs(), 1, "one logical sync despite the retries");
        // a later clean barrier is unaffected — nothing went sticky
        gs.write_at(1, b"y").unwrap();
        gs.barrier().unwrap();
        assert!(mock.is_durable(1));
        // the passthrough sync path retries the same way
        let gs = grouped(&Arc::new(MockDevice::transient_failing(1)), Duration::ZERO);
        gs.sync().unwrap();
        assert_eq!(gs.sync_retries(), 1);
    }

    #[test]
    fn sync_failure_is_sticky_for_every_later_barrier() {
        let mock = Arc::new(MockDevice::failing());
        let gs = grouped(&mock, Duration::ZERO);
        gs.write_at(0, b"x").unwrap();
        assert!(gs.barrier().is_err(), "leader must surface its own sync failure");
        assert!(!mock.is_durable(0));
        gs.write_at(1, b"y").unwrap();
        assert!(
            gs.barrier().is_err(),
            "a failed sync may never be forgotten: later writes are not durable either"
        );
    }
}
