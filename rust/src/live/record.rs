//! Self-describing on-SSD record frames, per-shard superblocks, and the
//! crash-recovery log scanner.
//!
//! The paper's log structure (§2.5) already makes the SSD a sequential
//! journal of random writes; this module makes that journal
//! **crash-consistent**. Every buffered extent is persisted as a framed
//! record — one header sector followed by the payload sectors:
//!
//! ```text
//!  [ magic | shard | region | sector len | disk LBA | sequence | slot | CRC32C ]
//!  [ payload … (len sectors) ]
//! ```
//!
//! `slot` is the frame's own region-relative log position, under the
//! CRC — a frame is only valid where it was written, so a copy of one
//! embedded in another record's payload can never be mistaken for a
//! real record during a torn-stretch hunt.
//!
//! * the **monotone sequence** is assigned under the shard's core lock in
//!   the same critical section that claims the write's sector range, so
//!   replaying surviving records in sequence order rebuilds exactly the
//!   ownership map's newest-copy-wins outcome;
//! * the **CRC-32C** covers header + payload, so a torn record (crash
//!   mid-write) is distinguishable from a complete one;
//! * records are *self-describing*: the scanner needs no external index
//!   to walk the log, and can re-synchronize past a torn record by
//!   hunting sector-by-sector for the next valid frame (a torn record
//!   must never hide an acknowledged one written after it by a
//!   concurrent client).
//!
//! The per-shard **superblock** lives past the two region logs, in two
//! slots. The writer (`live::shard`) alternates the slot on every
//! *physical* write — not by epoch parity, since epochs can be skipped
//! when a newer snapshot already reached the device — so two consecutive
//! durable superblocks always occupy different slots and a torn write
//! can only damage the slot being written, never the newest surviving
//! one. The reader validates both slots and takes the highest epoch.
//! It carries the
//! clean-shutdown flag, the per-region **flush watermarks** (records with
//! `seq <= watermark[region]` are settled on the HDD and must be skipped
//! at replay — the flusher persists the watermark *before* recycling a
//! region), the last assigned sequence, and the shard's file table
//! (file → extent slot; the mapping decides where every file's bytes
//! live on the HDD, so it must survive a restart).

use std::io;

use crate::live::backend::Backend;
use crate::live::fault::{retry_transient, RetryPolicy};
use crate::types::SECTOR_BYTES;
use crate::util::crc::Crc32c;

/// Recovery-path read with transient faults retried: a recovery running
/// under an EIO storm must not mistake a blip for data loss.
fn read_retried(dev: &dyn Backend, offset: u64, buf: &mut [u8]) -> io::Result<()> {
    retry_transient(&RetryPolicy::io_default(), || dev.read_at(offset, buf)).0
}

/// Record-frame magic ("SSDR").
pub const RECORD_MAGIC: u32 = 0x5353_4452;

/// Superblock magic ("SSBS").
pub const SUPERBLOCK_MAGIC: u32 = 0x5353_4253;

/// Header sectors per record frame (the header is one sector so payload
/// slots stay sector-aligned and the flusher's copy math is unchanged).
pub const HEADER_SECTORS: i64 = 1;

/// Superblock slots (A/B, alternated by epoch parity).
pub const SUPERBLOCK_SECTORS: u64 = 2;

/// Bytes of the record header covered by the CRC (the CRC field follows
/// them; the rest of the sector is padding).
const RECORD_CRC_COVER: usize = 40;

/// Max file-table entries a superblock sector can hold.
pub const MAX_SB_FILES: usize = (508 - 48) / 8;

/// Scanner read granularity (bytes).
const SCAN_CHUNK: usize = 1 << 20;

fn sector_usize() -> usize {
    SECTOR_BYTES as usize
}

/// One record frame's header fields (the payload follows on the device).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecordHeader {
    pub shard: u32,
    pub region: u32,
    /// payload length in sectors
    pub size: i64,
    /// absolute disk LBA of the payload's first sector
    pub lba: i64,
    /// shard-monotone sequence, assigned at claim time
    pub seq: u64,
    /// region-relative log slot of this frame's *header* sector. Under
    /// the CRC, so a byte-exact copy of a frame embedded in some other
    /// record's payload (and exposed by a torn stretch) self-invalidates:
    /// the scanner only accepts a frame found at its own position —
    /// standard journal practice.
    pub pos: i64,
}

impl RecordHeader {
    /// Serialize into one header sector, with the CRC computed over the
    /// header fields and `payload`.
    pub fn encode(&self, payload: &[u8]) -> Vec<u8> {
        debug_assert_eq!(payload.len(), self.size as usize * sector_usize());
        let mut sector = vec![0u8; sector_usize()];
        sector[0..4].copy_from_slice(&RECORD_MAGIC.to_le_bytes());
        sector[4..8].copy_from_slice(&self.shard.to_le_bytes());
        sector[8..12].copy_from_slice(&self.region.to_le_bytes());
        sector[12..16].copy_from_slice(&(self.size as u32).to_le_bytes());
        sector[16..24].copy_from_slice(&self.lba.to_le_bytes());
        sector[24..32].copy_from_slice(&self.seq.to_le_bytes());
        sector[32..40].copy_from_slice(&self.pos.to_le_bytes());
        let mut crc = Crc32c::new();
        crc.update(&sector[..RECORD_CRC_COVER]).update(payload);
        sector[40..44].copy_from_slice(&crc.finish().to_le_bytes());
        sector
    }

    /// Parse the header sector found at log slot `pos`. Returns the
    /// header and its stored CRC if the frame *plausibly* belongs to
    /// `(shard, region)`, sits at its own recorded position, and its
    /// payload fits in the `max_payload` sectors remaining; the caller
    /// still has to check the CRC against the payload bytes.
    pub fn decode(
        sector: &[u8],
        shard: u32,
        region: u32,
        max_payload: i64,
        pos: i64,
    ) -> Option<(Self, u32)> {
        let magic = u32::from_le_bytes(sector[0..4].try_into().unwrap());
        if magic != RECORD_MAGIC {
            return None;
        }
        let h = RecordHeader {
            shard: u32::from_le_bytes(sector[4..8].try_into().unwrap()),
            region: u32::from_le_bytes(sector[8..12].try_into().unwrap()),
            size: u32::from_le_bytes(sector[12..16].try_into().unwrap()) as i64,
            lba: i64::from_le_bytes(sector[16..24].try_into().unwrap()),
            seq: u64::from_le_bytes(sector[24..32].try_into().unwrap()),
            pos: i64::from_le_bytes(sector[32..40].try_into().unwrap()),
        };
        if h.shard != shard
            || h.region != region
            || h.pos != pos
            || h.size < 1
            || h.size > max_payload
            || h.lba < 0
        {
            return None;
        }
        let crc = u32::from_le_bytes(sector[40..44].try_into().unwrap());
        Some((h, crc))
    }
}

/// Per-shard superblock contents. See the module docs for the role of
/// each field.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Superblock {
    pub shard: u32,
    /// bumped on every rewrite; parity picks the slot, max wins at read
    pub epoch: u64,
    /// highest sequence ever assigned (recovery resumes past it)
    pub last_seq: u64,
    /// records with `seq <= watermark[region]` are settled on the HDD
    pub watermark: [u64; 2],
    /// set only by an orderly shutdown after a full drain: a clean
    /// reopen skips the log scan entirely
    pub clean: bool,
    /// the shard entered sticky degraded mode (SSD tier failed): new
    /// writes route direct-to-HDD, and a recovery must come back up
    /// degraded instead of trusting the dead tier again
    pub degraded: bool,
    /// the shard's file table as `(file, extent slot)` pairs
    pub files: Vec<(u32, u32)>,
}

impl Superblock {
    pub fn fresh(shard: u32) -> Self {
        Self {
            shard,
            epoch: 0,
            last_seq: 0,
            watermark: [0, 0],
            clean: false,
            degraded: false,
            files: Vec::new(),
        }
    }

    /// Byte offset of slot `slot` (0 or 1) relative to the superblock
    /// base.
    pub fn slot_byte(slot: usize) -> u64 {
        debug_assert!(slot < SUPERBLOCK_SECTORS as usize);
        slot as u64 * SECTOR_BYTES
    }

    pub fn encode(&self) -> Vec<u8> {
        assert!(
            self.files.len() <= MAX_SB_FILES,
            "live shard file table exceeds one superblock sector ({} > {MAX_SB_FILES} files)",
            self.files.len()
        );
        let mut sector = vec![0u8; sector_usize()];
        sector[0..4].copy_from_slice(&SUPERBLOCK_MAGIC.to_le_bytes());
        sector[4..8].copy_from_slice(&self.shard.to_le_bytes());
        sector[8..16].copy_from_slice(&self.epoch.to_le_bytes());
        sector[16..24].copy_from_slice(&self.last_seq.to_le_bytes());
        sector[24..32].copy_from_slice(&self.watermark[0].to_le_bytes());
        sector[32..40].copy_from_slice(&self.watermark[1].to_le_bytes());
        sector[40] = self.clean as u8;
        sector[41] = self.degraded as u8;
        sector[44..48].copy_from_slice(&(self.files.len() as u32).to_le_bytes());
        for (i, &(file, slot)) in self.files.iter().enumerate() {
            let at = 48 + i * 8;
            sector[at..at + 4].copy_from_slice(&file.to_le_bytes());
            sector[at + 4..at + 8].copy_from_slice(&slot.to_le_bytes());
        }
        let crc = Crc32c::new().update(&sector[..508]).finish();
        sector[508..512].copy_from_slice(&crc.to_le_bytes());
        sector
    }

    pub fn decode(sector: &[u8], shard: u32) -> Option<Self> {
        if u32::from_le_bytes(sector[0..4].try_into().unwrap()) != SUPERBLOCK_MAGIC {
            return None;
        }
        let crc = u32::from_le_bytes(sector[508..512].try_into().unwrap());
        if Crc32c::new().update(&sector[..508]).finish() != crc {
            return None;
        }
        let sb_shard = u32::from_le_bytes(sector[4..8].try_into().unwrap());
        if sb_shard != shard {
            return None;
        }
        let n_files = u32::from_le_bytes(sector[44..48].try_into().unwrap()) as usize;
        if n_files > MAX_SB_FILES {
            return None;
        }
        let mut files = Vec::with_capacity(n_files);
        for i in 0..n_files {
            let at = 48 + i * 8;
            files.push((
                u32::from_le_bytes(sector[at..at + 4].try_into().unwrap()),
                u32::from_le_bytes(sector[at + 4..at + 8].try_into().unwrap()),
            ));
        }
        Some(Self {
            shard: sb_shard,
            epoch: u64::from_le_bytes(sector[8..16].try_into().unwrap()),
            last_seq: u64::from_le_bytes(sector[16..24].try_into().unwrap()),
            watermark: [
                u64::from_le_bytes(sector[24..32].try_into().unwrap()),
                u64::from_le_bytes(sector[32..40].try_into().unwrap()),
            ],
            clean: sector[40] != 0,
            // byte 41 was zero padding before the fault layer, so old
            // superblocks decode as not degraded
            degraded: sector[41] != 0,
            files,
        })
    }

    /// Read both slots at `base` and return the valid one with the
    /// highest epoch plus the slot it lives in, or `None` on a device
    /// never formatted (which recovery treats as "dirty with watermark
    /// 0": a full scan that finds nothing on a fresh device). The slot
    /// tells the next writer where *not* to write.
    pub fn read(dev: &dyn Backend, base: u64, shard: u32) -> io::Result<Option<(Self, usize)>> {
        let mut buf = vec![0u8; sector_usize() * SUPERBLOCK_SECTORS as usize];
        read_retried(dev, base, &mut buf)?;
        let a = Self::decode(&buf[..sector_usize()], shard).map(|sb| (sb, 0));
        let b = Self::decode(&buf[sector_usize()..], shard).map(|sb| (sb, 1));
        Ok(match (a, b) {
            (Some(a), Some(b)) => Some(if a.0.epoch >= b.0.epoch { a } else { b }),
            (a, b) => a.or(b),
        })
    }

    /// Write this superblock into `slot`. The caller owns the slot
    /// alternation and ordering (sync before and/or after as the
    /// protocol requires) and must serialize concurrent writers.
    pub fn write_to(&self, dev: &dyn Backend, base: u64, slot: usize) -> io::Result<()> {
        dev.write_at(base + Self::slot_byte(slot), &self.encode())
    }
}

/// One surviving record found by [`scan_region`]: enough to replay the
/// ownership claim (`payload_slot` is region-relative, like the map's
/// `ssd_offset`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LiveRecord {
    pub seq: u64,
    pub lba: i64,
    pub size: i64,
    pub region: usize,
    pub payload_slot: i64,
}

/// Outcome of scanning one region's log.
#[derive(Clone, Debug, Default)]
pub struct ScanReport {
    /// valid records above the flush watermark, in log (= sequence) order
    pub live: Vec<LiveRecord>,
    /// valid records at or below the watermark (already settled on HDD)
    pub skipped: u64,
    /// torn/invalid stretches hunted past (one count per stretch)
    pub torn: u64,
    /// restore point for the region's append cursor: the end of the last
    /// live record (0 if none survived)
    pub cursor: i64,
    /// highest live sequence seen (0 if none)
    pub max_live_seq: u64,
    /// sectors walked (diagnostics/bench: replay rate denominator)
    pub scanned_sectors: i64,
}

/// Buffered sequential sector reader over one region's byte range.
struct SectorReader<'a> {
    dev: &'a dyn Backend,
    base: u64,
    capacity: i64,
    buf: Vec<u8>,
    buf_start: i64,
    buf_sectors: i64,
}

impl<'a> SectorReader<'a> {
    fn new(dev: &'a dyn Backend, base: u64, capacity: i64) -> Self {
        Self { dev, base, capacity, buf: vec![0u8; SCAN_CHUNK], buf_start: 0, buf_sectors: 0 }
    }

    fn sector(&mut self, idx: i64) -> io::Result<&[u8]> {
        debug_assert!(idx < self.capacity);
        if idx < self.buf_start || idx >= self.buf_start + self.buf_sectors {
            let sectors = ((SCAN_CHUNK / sector_usize()) as i64).min(self.capacity - idx);
            let bytes = sectors as usize * sector_usize();
            read_retried(self.dev, self.base + idx as u64 * SECTOR_BYTES, &mut self.buf[..bytes])?;
            self.buf_start = idx;
            self.buf_sectors = sectors;
        }
        let at = (idx - self.buf_start) as usize * sector_usize();
        Ok(&self.buf[at..at + sector_usize()])
    }
}

/// Walk one region's log from sector 0, validating record frames:
///
/// * a frame whose CRC covers its payload is **valid**; it is replayable
///   (`live`) if its sequence is above `watermark`, else already settled;
/// * anything else is a torn or stale stretch: the scanner re-syncs by
///   hunting one sector at a time for the next valid frame, so a torn
///   record from one client never hides a completed (acknowledged)
///   record a concurrent client placed after it.
///
/// Stale frames from a previous region generation parse as valid but sit
/// at or below the watermark, so they advance the walk without being
/// replayed.
pub fn scan_region(
    dev: &dyn Backend,
    base: u64,
    capacity_sectors: i64,
    shard: u32,
    region: u32,
    watermark: u64,
) -> io::Result<ScanReport> {
    let sector = sector_usize();
    let mut report = ScanReport::default();
    let mut reader = SectorReader::new(dev, base, capacity_sectors);
    let mut payload = vec![0u8; SCAN_CHUNK];
    let mut pos = 0i64;
    let mut hunting = false;
    while pos < capacity_sectors {
        let max_payload = capacity_sectors - pos - HEADER_SECTORS;
        let parsed = RecordHeader::decode(reader.sector(pos)?, shard, region, max_payload, pos);
        let valid = match parsed {
            None => None,
            Some((h, stored_crc)) => {
                let mut crc = Crc32c::new();
                crc.update(&reader.sector(pos)?[..RECORD_CRC_COVER]);
                let mut read = 0usize;
                let total = h.size as usize * sector;
                let payload_base = base + (pos + HEADER_SECTORS) as u64 * SECTOR_BYTES;
                while read < total {
                    let take = (total - read).min(payload.len());
                    read_retried(dev, payload_base + read as u64, &mut payload[..take])?;
                    crc.update(&payload[..take]);
                    read += take;
                }
                (crc.finish() == stored_crc).then_some(h)
            }
        };
        match valid {
            Some(h) if h.seq > watermark => {
                hunting = false;
                report.live.push(LiveRecord {
                    seq: h.seq,
                    lba: h.lba,
                    size: h.size,
                    region: region as usize,
                    payload_slot: pos + HEADER_SECTORS,
                });
                report.cursor = pos + HEADER_SECTORS + h.size;
                report.max_live_seq = report.max_live_seq.max(h.seq);
                // live records are the current generation: the allocator
                // laid them out contiguously, so nothing can hide inside
                // their payload — skip it whole
                pos += HEADER_SECTORS + h.size;
            }
            Some(_) => {
                // valid but settled (stale generation). Advance only one
                // sector: a stale frame can sit *inside* a torn stretch
                // (the torn record's slots expose old-generation bytes),
                // and jumping its full stale extent could overshoot an
                // acknowledged live record placed after the tear.
                hunting = false;
                report.skipped += 1;
                pos += 1;
            }
            None => {
                if !hunting {
                    report.torn += 1;
                    hunting = true;
                }
                pos += 1;
            }
        }
    }
    report.scanned_sectors = capacity_sectors;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::backend::{MemBackend, SyntheticLatency};

    fn mem() -> MemBackend {
        MemBackend::new(SyntheticLatency::ZERO)
    }

    fn payload_of(size: i64, fill: u8) -> Vec<u8> {
        vec![fill; size as usize * sector_usize()]
    }

    /// Append a record frame at `slot`, returning the next free slot.
    fn put_record(dev: &dyn Backend, slot: i64, mut h: RecordHeader, payload: &[u8]) -> i64 {
        h.pos = slot;
        dev.write_at(slot as u64 * SECTOR_BYTES, &h.encode(payload)).unwrap();
        dev.write_at((slot + HEADER_SECTORS) as u64 * SECTOR_BYTES, payload).unwrap();
        slot + HEADER_SECTORS + h.size
    }

    fn hdr(seq: u64, lba: i64, size: i64) -> RecordHeader {
        RecordHeader { shard: 3, region: 1, size, lba, seq, pos: 0 }
    }

    #[test]
    fn record_header_round_trips_and_rejects_foreign_frames() {
        let payload = payload_of(4, 0xAB);
        let h = RecordHeader { shard: 3, region: 1, size: 4, lba: 9000, seq: 42, pos: 17 };
        let sector = h.encode(&payload);
        let (back, crc) = RecordHeader::decode(&sector, 3, 1, 100, 17).expect("valid frame");
        assert_eq!(back, h);
        let expect =
            Crc32c::new().update(&sector[..RECORD_CRC_COVER]).update(&payload).finish();
        assert_eq!(crc, expect);
        // wrong shard / region / position / oversize payload are not our
        // frames — the position check is what keeps a frame copied into
        // some payload from being resurrected where it never lived
        assert!(RecordHeader::decode(&sector, 2, 1, 100, 17).is_none());
        assert!(RecordHeader::decode(&sector, 3, 0, 100, 17).is_none());
        assert!(RecordHeader::decode(&sector, 3, 1, 100, 16).is_none(), "frame out of position");
        assert!(RecordHeader::decode(&sector, 3, 1, 3, 17).is_none(), "payload larger than tail");
        let mut bad = sector.clone();
        bad[0] ^= 0xFF;
        assert!(RecordHeader::decode(&bad, 3, 1, 100, 17).is_none(), "bad magic");
    }

    #[test]
    fn superblock_round_trips_and_survives_a_torn_slot() {
        let dev = mem();
        let mut sb = Superblock::fresh(7);
        sb.epoch = 1;
        sb.last_seq = 99;
        sb.watermark = [40, 99];
        sb.files = vec![(1, 0), (9, 1)];
        sb.write_to(&dev, 0, 1).unwrap();
        let (got, slot) = Superblock::read(&dev, 0, 7).unwrap().expect("one valid slot");
        assert_eq!((got, slot), (sb.clone(), 1));
        // the next physical write goes to the OTHER slot (the writer
        // alternates per write); the old superblock survives as the
        // fallback and the reader picks the max epoch
        let mut sb2 = sb.clone();
        sb2.epoch = 3; // epochs may skip — slot choice must not depend on parity
        sb2.clean = true;
        sb2.files.push((4, 2));
        sb2.write_to(&dev, 0, 0).unwrap();
        assert_eq!(Superblock::read(&dev, 0, 7).unwrap().unwrap(), (sb2.clone(), 0));
        // tear the newer slot: the reader falls back to epoch 1 in slot 1
        dev.write_at(Superblock::slot_byte(0) + 100, &[0xFF; 64]).unwrap();
        assert_eq!(Superblock::read(&dev, 0, 7).unwrap().unwrap(), (sb, 1));
        // wrong shard id: the superblock is not ours at all
        assert!(Superblock::read(&dev, 0, 8).unwrap().is_none());
    }

    #[test]
    fn superblock_degraded_flag_round_trips_and_defaults_clear() {
        let dev = mem();
        let mut sb = Superblock::fresh(2);
        sb.epoch = 5;
        sb.degraded = true;
        sb.write_to(&dev, 0, 0).unwrap();
        let (got, _) = Superblock::read(&dev, 0, 2).unwrap().expect("valid slot");
        assert!(got.degraded, "degraded flag survives a restart");
        assert_eq!(got, sb);
        // byte 41 was padding before the fault layer: a pre-fault-layer
        // superblock (zeros there) must decode as not degraded
        assert!(!Superblock::fresh(2).degraded);
        let mut old = Superblock::fresh(2);
        old.epoch = 9;
        let mut sector = old.encode();
        sector[41] = 0;
        let decoded = Superblock::decode(&sector, 2).expect("still valid");
        assert!(!decoded.degraded);
    }

    #[test]
    fn fresh_device_has_no_superblock_and_scans_empty() {
        let dev = mem();
        assert!(Superblock::read(&dev, 0, 0).unwrap().is_none());
        let r = scan_region(&dev, 0, 2048, 0, 0, 0).unwrap();
        assert!(r.live.is_empty());
        assert_eq!(r.cursor, 0);
        assert_eq!((r.skipped, r.torn), (0, 1), "one zero-fill stretch hunted");
        assert_eq!(r.scanned_sectors, 2048);
    }

    #[test]
    fn scan_replays_valid_records_and_skips_flushed_ones() {
        let dev = mem();
        let mut slot = 0;
        for (seq, lba, size) in [(5u64, 100i64, 4i64), (6, 300, 2), (9, 100, 1)] {
            slot = put_record(&dev, slot, hdr(seq, lba, size), &payload_of(size, seq as u8));
        }
        // watermark 5: the first record is already settled on the HDD
        let r = scan_region(&dev, 0, 1024, 3, 1, 5).unwrap();
        assert_eq!(r.skipped, 1);
        assert_eq!(
            r.live,
            vec![
                LiveRecord { seq: 6, lba: 300, size: 2, region: 1, payload_slot: 6 },
                LiveRecord { seq: 9, lba: 100, size: 1, region: 1, payload_slot: 9 },
            ]
        );
        assert_eq!(r.cursor, 10, "cursor restores to the end of the last live record");
        assert_eq!(r.max_live_seq, 9);
    }

    #[test]
    fn scan_discards_torn_records_but_resyncs_onto_later_valid_ones() {
        // the hole-in-log case: client A's record is torn mid-write while
        // client B's later record completed and was acknowledged — the
        // scanner must discard A's frame and still find B's
        let dev = mem();
        let torn_payload = payload_of(6, 0x11);
        let torn = hdr(7, 500, 6);
        let mut slot = put_record(&dev, 0, torn, &torn_payload);
        // tear A: corrupt part of its payload after the fact (as if the
        // crash cut the transfer)
        dev.write_at(3 * SECTOR_BYTES + 17, &[0xEE; 200]).unwrap();
        let b = hdr(8, 900, 2);
        slot = put_record(&dev, slot, b, &payload_of(2, 0x22));
        let r = scan_region(&dev, 0, 1024, 3, 1, 0).unwrap();
        assert_eq!(r.live.len(), 1, "only B survives");
        assert_eq!(r.live[0].seq, 8);
        assert_eq!(r.live[0].payload_slot, 7 + HEADER_SECTORS);
        assert!(r.torn >= 1, "the torn stretch is counted");
        assert_eq!(r.cursor, slot, "cursor lands after B");
    }

    #[test]
    fn scan_handles_recycled_region_with_stale_tail() {
        // generation N-1 filled slots [0..) and was flushed (watermark
        // covers it); generation N wrote two records over the front. The
        // stale frames behind the new tail parse as valid but sit below
        // the watermark.
        let dev = mem();
        let mut slot = 0;
        for seq in 1..=4u64 {
            slot = put_record(&dev, slot, hdr(seq, seq as i64 * 10, 3), &payload_of(3, seq as u8));
        }
        assert_eq!(slot, 16);
        // recycle: generation N starts at 0 with seqs above the watermark
        let mut new_slot = put_record(&dev, 0, hdr(10, 700, 2), &payload_of(2, 0xAA));
        new_slot = put_record(&dev, new_slot, hdr(11, 800, 1), &payload_of(1, 0xBB));
        let r = scan_region(&dev, 0, 1024, 3, 1, 4).unwrap();
        assert_eq!(r.live.len(), 2);
        assert_eq!(r.live[0].seq, 10);
        assert_eq!(r.live[1].seq, 11);
        assert_eq!(r.cursor, new_slot, "cursor ends at the new generation's tail");
        // whatever stale frames remain readable behind the tail were
        // skipped, not replayed
        assert!(r.live.iter().all(|l| l.seq > 4));
    }
}
