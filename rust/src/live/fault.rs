//! Typed I/O fault taxonomy, bounded retry/backoff, and a scriptable
//! fault-injecting [`Backend`] wrapper — the live engine's fault layer.
//!
//! Three pieces, used across the whole I/O pipeline:
//!
//! * [`IoFault`] — the error taxonomy every I/O error is classified
//!   into. Errors the engine makes itself (the injector, the queue's
//!   shutdown path) carry the classification **in the error payload**
//!   ([`FaultError`]), so it round-trips exactly; foreign errors fall
//!   back to `io::ErrorKind` + `ENOSPC` heuristics. The classification
//!   decides the response: transient faults are retried below the
//!   completion token, device-full / permanent SSD faults flip the shard
//!   into degraded (direct-to-HDD) mode, shutdown is surfaced as a typed
//!   rejection, and anything else fails the shard loudly — never a
//!   panic.
//! * [`RetryPolicy`] — bounded exponential backoff: at most
//!   `max_retries` re-attempts, each sleep doubling from `base` and
//!   capped at `cap`, with the **total** sleep bounded by `budget`. The
//!   property tests hold both bounds for arbitrary policies.
//!   [`retry_transient`] is the shared run-one-op helper.
//! * [`FaultBackend`] + [`FaultSpec`] — seeded, deterministic fault
//!   injection over any [`Backend`], driven by a small spec string
//!   (`ssdup live --fault-spec`):
//!
//!   ```text
//!   spec    := clause (',' clause)*
//!   clause  := ('ssd'|'hdd') ':' kind['@op=N'] (':' key '=' value)*
//!   kind    := 'eio'     transient I/O errors on write/read/sync
//!            | 'enospc'  device-full on writes
//!            | 'slow'    injected latency spikes
//!            | 'dead'    permanent device death
//!   keys    := p=FLOAT       trigger probability per op   (default 1.0)
//!              op=N          inert before the device's Nth op
//!              transient=K   eio: K consecutive failures per burst,
//!                            then one guaranteed success (default 1)
//!              delay_us=N    slow: injected stall         (default 500)
//!              min_off=N / max_off=N
//!                            byte-offset window (offset-scoped clauses
//!                            skip sync, which has no offset)
//!   ```
//!
//!   Examples: `ssd:eio:p=0.01:transient=3` (1% transient-EIO storm,
//!   each burst clears after 3 attempts), `hdd:dead@op=5000` (HDD dies
//!   permanently at its 5000th op), `ssd:slow:p=0.1:delay_us=2000`.
//!
//! Determinism: every injection decision comes from one seeded [`Prng`]
//! behind the wrapper's mutex, keyed only by the device's op order — the
//! same single-threaded op sequence always faults at the same points,
//! which is what the `transient=2`-succeeds-on-the-3rd-attempt unit
//! tests rely on.

use std::error::Error as StdError;
use std::fmt;
use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::live::backend::Backend;
use crate::util::prng::Prng;

/// `ENOSPC` on every Unix the engine targets (classification fallback
/// for real device-full errors surfaced by the OS).
const ENOSPC_ERRNO: i32 = 28;

/// What kind of failure an `io::Error` represents — and therefore what
/// the engine does about it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoFault {
    /// Worth retrying with backoff (EINTR/EIO blips, timeouts).
    Transient,
    /// The device is out of space: writes to this tier are pointless,
    /// route around it (SSD tier → degraded mode).
    DeviceFull,
    /// The device is gone or the error is not recoverable by retry.
    Permanent,
    /// Not a device fault at all: the queue/shard is shutting down and
    /// the request was rejected, bytes undelivered.
    Shutdown,
}

impl IoFault {
    /// Classify an error. Engine-made errors carry their [`IoFault`] in
    /// the payload and round-trip exactly; foreign errors fall back to
    /// `ErrorKind` (+ raw `ENOSPC`), defaulting to [`IoFault::Permanent`]
    /// — an unknown error must never be retried into a forged ack.
    pub fn classify(e: &io::Error) -> IoFault {
        if let Some(f) = e.get_ref().and_then(|inner| inner.downcast_ref::<FaultError>()) {
            return f.fault;
        }
        if e.raw_os_error() == Some(ENOSPC_ERRNO) {
            return IoFault::DeviceFull;
        }
        match e.kind() {
            io::ErrorKind::Interrupted | io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => {
                IoFault::Transient
            }
            _ => IoFault::Permanent,
        }
    }

    pub fn is_transient(self) -> bool {
        self == IoFault::Transient
    }

    pub fn is_shutdown(self) -> bool {
        self == IoFault::Shutdown
    }

    /// Build an `io::Error` that classifies back to `self` exactly (the
    /// taxonomy rides in the payload, not just the `ErrorKind`).
    pub fn error(self, msg: impl Into<String>) -> io::Error {
        let payload = FaultError { fault: self, msg: msg.into() };
        match self {
            IoFault::Transient => io::Error::new(io::ErrorKind::Interrupted, payload),
            _ => io::Error::other(payload),
        }
    }
}

impl fmt::Display for IoFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let name = match self {
            IoFault::Transient => "transient",
            IoFault::DeviceFull => "device-full",
            IoFault::Permanent => "permanent",
            IoFault::Shutdown => "shutdown",
        };
        f.write_str(name)
    }
}

/// Error payload carrying an exact [`IoFault`] classification.
#[derive(Debug)]
pub struct FaultError {
    fault: IoFault,
    msg: String,
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} [{}]", self.msg, self.fault)
    }
}

impl StdError for FaultError {}

/// Bounded exponential backoff for transient faults. Two independent
/// hard bounds: at most `max_retries` re-attempts, and the sleeps sum to
/// at most `budget` (each individual sleep doubles from `base`, capped
/// at `cap`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    pub max_retries: u32,
    pub base: Duration,
    pub cap: Duration,
    pub budget: Duration,
}

impl RetryPolicy {
    /// No retries at all: every fault surfaces on the first attempt.
    pub const fn none() -> Self {
        Self { max_retries: 0, base: Duration::ZERO, cap: Duration::ZERO, budget: Duration::ZERO }
    }

    /// Default for device I/O: rides out injected EIO storms (bursts of
    /// a few consecutive failures) without stretching a run — worst case
    /// ~20 ms of sleep per request.
    pub fn io_default() -> Self {
        Self {
            max_retries: 8,
            base: Duration::from_micros(50),
            cap: Duration::from_millis(5),
            budget: Duration::from_millis(20),
        }
    }

    /// Sleep before retry number `attempt` (0-based), given the total
    /// already slept — or `None` once either bound is exhausted.
    pub fn delay(&self, attempt: u32, slept: Duration) -> Option<Duration> {
        if attempt >= self.max_retries || slept >= self.budget {
            return None;
        }
        let factor = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
        let exp = self.base.saturating_mul(factor);
        Some(exp.min(self.cap).min(self.budget - slept))
    }
}

/// Run `op`, retrying transient faults per `policy` with backoff.
/// Returns the final result plus the number of retries taken (0 when the
/// first attempt decided it) — callers book the count into their stats.
pub fn retry_transient<T>(
    policy: &RetryPolicy,
    mut op: impl FnMut() -> io::Result<T>,
) -> (io::Result<T>, u32) {
    let mut retries = 0u32;
    let mut slept = Duration::ZERO;
    loop {
        match op() {
            Ok(v) => return (Ok(v), retries),
            Err(e) => {
                if !IoFault::classify(&e).is_transient() {
                    return (Err(e), retries);
                }
                match policy.delay(retries, slept) {
                    Some(d) => {
                        if !d.is_zero() {
                            std::thread::sleep(d);
                        }
                        slept += d;
                        retries += 1;
                    }
                    None => return (Err(e), retries),
                }
            }
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FaultKind {
    Eio,
    Enospc,
    Slow,
    Dead,
}

/// Which device operation a clause is being consulted for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DevOp {
    Write,
    Read,
    Sync,
}

/// One parsed fault clause (see the module docs for the grammar).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultClause {
    kind: FaultKind,
    p: f64,
    at_op: u64,
    transient: u32,
    delay: Duration,
    min_off: u64,
    max_off: u64,
}

impl FaultClause {
    fn applies(&self, op: DevOp, offset: Option<u64>) -> bool {
        let kind_ok = match self.kind {
            FaultKind::Enospc => op == DevOp::Write,
            FaultKind::Eio | FaultKind::Slow | FaultKind::Dead => true,
        };
        if !kind_ok {
            return false;
        }
        if self.min_off == 0 && self.max_off == u64::MAX {
            return true; // unscoped: every op, sync included
        }
        match offset {
            Some(off) => off >= self.min_off && off < self.max_off,
            None => false, // offset-scoped clauses never hit sync
        }
    }
}

/// A parsed `--fault-spec`: per-tier clause lists. Empty spec = no
/// injection (wrapping is the identity).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    ssd: Vec<FaultClause>,
    hdd: Vec<FaultClause>,
}

impl FaultSpec {
    /// Parse a spec string (grammar in the module docs). Errors name the
    /// offending clause.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for clause in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let mut parts = clause.split(':');
            let device = parts.next().unwrap_or("");
            let Some(kind_tok) = parts.next() else {
                return Err(format!("fault spec '{clause}': missing fault kind"));
            };
            // `dead@op=5000` glues the activation op onto the kind token
            let (kind_name, at_op) = match kind_tok.split_once('@') {
                Some((k, at)) => {
                    let n = at
                        .strip_prefix("op=")
                        .and_then(|v| v.parse::<u64>().ok())
                        .ok_or_else(|| format!("fault spec '{clause}': bad '@{at}' (want @op=N)"))?;
                    (k, n)
                }
                None => (kind_tok, 0),
            };
            let kind = match kind_name {
                "eio" => FaultKind::Eio,
                "enospc" => FaultKind::Enospc,
                "slow" => FaultKind::Slow,
                "dead" => FaultKind::Dead,
                other => {
                    return Err(format!(
                        "fault spec '{clause}': unknown kind '{other}' (eio|enospc|slow|dead)"
                    ))
                }
            };
            let mut c = FaultClause {
                kind,
                p: 1.0,
                at_op,
                transient: 1,
                delay: Duration::from_micros(500),
                min_off: 0,
                max_off: u64::MAX,
            };
            for param in parts {
                let Some((key, val)) = param.split_once('=') else {
                    return Err(format!(
                        "fault spec '{clause}': bad param '{param}' (want key=value)"
                    ));
                };
                let bad = || format!("fault spec '{clause}': bad value in '{param}'");
                match key {
                    "p" => {
                        c.p = val.parse().map_err(|_| bad())?;
                        if !(0.0..=1.0).contains(&c.p) {
                            return Err(format!("fault spec '{clause}': p must be in [0,1]"));
                        }
                    }
                    "op" => c.at_op = val.parse().map_err(|_| bad())?,
                    "transient" => {
                        c.transient = val.parse::<u32>().map_err(|_| bad())?.max(1);
                    }
                    "delay_us" => {
                        c.delay = Duration::from_micros(val.parse().map_err(|_| bad())?);
                    }
                    "min_off" => c.min_off = val.parse().map_err(|_| bad())?,
                    "max_off" => c.max_off = val.parse().map_err(|_| bad())?,
                    other => {
                        return Err(format!("fault spec '{clause}': unknown param '{other}'"));
                    }
                }
            }
            match device {
                "ssd" => spec.ssd.push(c),
                "hdd" => spec.hdd.push(c),
                other => {
                    return Err(format!(
                        "fault spec '{clause}': unknown device '{other}' (ssd|hdd)"
                    ))
                }
            }
        }
        Ok(spec)
    }

    pub fn is_empty(&self) -> bool {
        self.ssd.is_empty() && self.hdd.is_empty()
    }

    /// Wrap a shard's SSD backend. Identity when no `ssd:` clauses
    /// parsed; `seed` should be derived per shard so streams stay
    /// independent but deterministic.
    pub fn wrap_ssd(&self, inner: Box<dyn Backend>, seed: u64) -> Box<dyn Backend> {
        Self::wrap(inner, &self.ssd, seed)
    }

    /// Wrap a shard's HDD backend (see [`FaultSpec::wrap_ssd`]).
    pub fn wrap_hdd(&self, inner: Box<dyn Backend>, seed: u64) -> Box<dyn Backend> {
        Self::wrap(inner, &self.hdd, seed ^ 0x9E37_79B9_7F4A_7C15)
    }

    fn wrap(inner: Box<dyn Backend>, clauses: &[FaultClause], seed: u64) -> Box<dyn Backend> {
        if clauses.is_empty() {
            inner
        } else {
            Box::new(FaultBackend::new(inner, clauses.to_vec(), seed))
        }
    }
}

struct InjectState {
    rng: Prng,
    /// per-clause remaining failures in the current eio burst
    pending: Vec<u32>,
    /// per-clause one-op grace after a burst drains: the attempt after
    /// `transient` consecutive failures succeeds whatever `p` says
    grace: Vec<bool>,
}

/// Seeded, deterministic fault injector over any [`Backend`]. Every
/// operation consults the clause list in order; the first clause that
/// triggers decides the op's fate (error / stall), otherwise the op
/// forwards to the wrapped backend untouched.
pub struct FaultBackend {
    inner: Box<dyn Backend>,
    clauses: Vec<FaultClause>,
    ops: AtomicU64,
    injected: AtomicU64,
    state: Mutex<InjectState>,
}

impl FaultBackend {
    pub fn new(inner: Box<dyn Backend>, clauses: Vec<FaultClause>, seed: u64) -> Self {
        let n = clauses.len();
        Self {
            inner,
            clauses,
            ops: AtomicU64::new(0),
            injected: AtomicU64::new(0),
            state: Mutex::new(InjectState {
                rng: Prng::new(seed),
                pending: vec![0; n],
                grace: vec![false; n],
            }),
        }
    }

    /// Faults injected so far (test/debug visibility).
    pub fn injected_faults(&self) -> u64 {
        // Relaxed: debug counter read, no synchronization implied
        self.injected.load(Ordering::Relaxed)
    }

    /// Device operations seen so far (test/debug visibility).
    pub fn ops_seen(&self) -> u64 {
        // Relaxed: debug counter read, no synchronization implied
        self.ops.load(Ordering::Relaxed)
    }

    fn inject(&self) -> u64 {
        // Relaxed: injection tally — clause state is under the mutex
        self.injected.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Consult every clause for one device op; `Ok(())` means forward.
    fn gate(&self, op: DevOp, offset: Option<u64>) -> io::Result<()> {
        // Relaxed: op numbering only orders faults against a single
        // clause's `at_op` threshold; exactness across threads is not
        // required (scripts target op counts, not interleavings)
        let op_index = self.ops.fetch_add(1, Ordering::Relaxed);
        for (i, c) in self.clauses.iter().enumerate() {
            if op_index < c.at_op || !c.applies(op, offset) {
                continue;
            }
            match c.kind {
                FaultKind::Dead => {
                    self.inject();
                    return Err(IoFault::Permanent
                        .error(format!("injected: device dead since op {}", c.at_op)));
                }
                FaultKind::Eio => {
                    let mut st = self.state.lock().unwrap();
                    if st.pending[i] == 0 {
                        if st.grace[i] {
                            st.grace[i] = false;
                            continue;
                        }
                        if !st.rng.chance(c.p) {
                            continue;
                        }
                        // a fresh burst: `transient` consecutive failures
                        st.pending[i] = c.transient;
                    }
                    st.pending[i] -= 1;
                    if st.pending[i] == 0 {
                        st.grace[i] = true;
                    }
                    drop(st);
                    self.inject();
                    return Err(IoFault::Transient.error("injected: transient EIO"));
                }
                FaultKind::Enospc => {
                    if self.state.lock().unwrap().rng.chance(c.p) {
                        self.inject();
                        return Err(IoFault::DeviceFull.error("injected: device full"));
                    }
                }
                FaultKind::Slow => {
                    let hit = self.state.lock().unwrap().rng.chance(c.p);
                    if hit {
                        self.inject();
                        if !c.delay.is_zero() {
                            // stall outside the state lock
                            std::thread::sleep(c.delay);
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

impl Backend for FaultBackend {
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        self.gate(DevOp::Write, Some(offset))?;
        self.inner.write_at(offset, data)
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        self.gate(DevOp::Read, Some(offset))?;
        self.inner.read_at(offset, buf)
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn sync(&self) -> io::Result<()> {
        self.gate(DevOp::Sync, None)?;
        self.inner.sync()
    }

    fn kind(&self) -> &'static str {
        self.inner.kind()
    }

    fn write_vectored_at(&self, offset: u64, bufs: &[&[u8]]) -> io::Result<()> {
        self.gate(DevOp::Write, Some(offset))?;
        self.inner.write_vectored_at(offset, bufs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::backend::{MemBackend, SyntheticLatency};

    fn mem() -> Box<dyn Backend> {
        Box::new(MemBackend::new(SyntheticLatency::ZERO))
    }

    #[test]
    fn classification_round_trips_through_error_payload() {
        for fault in
            [IoFault::Transient, IoFault::DeviceFull, IoFault::Permanent, IoFault::Shutdown]
        {
            let e = fault.error("probe");
            assert_eq!(IoFault::classify(&e), fault, "{fault}");
            assert!(e.to_string().contains("probe"));
        }
    }

    #[test]
    fn classification_is_stable_across_error_kinds() {
        use io::ErrorKind as K;
        let transient = [K::Interrupted, K::TimedOut, K::WouldBlock];
        for k in transient {
            assert_eq!(IoFault::classify(&io::Error::from(k)), IoFault::Transient, "{k:?}");
        }
        let permanent = [
            K::NotFound,
            K::PermissionDenied,
            K::BrokenPipe,
            K::InvalidData,
            K::UnexpectedEof,
            K::Unsupported,
            K::Other,
        ];
        for k in permanent {
            assert_eq!(IoFault::classify(&io::Error::from(k)), IoFault::Permanent, "{k:?}");
        }
        // real ENOSPC from the OS classifies as device-full
        let enospc = io::Error::from_raw_os_error(ENOSPC_ERRNO);
        assert_eq!(IoFault::classify(&enospc), IoFault::DeviceFull);
        // a stringly error someone made without the payload: permanent
        assert_eq!(IoFault::classify(&io::Error::other("boom")), IoFault::Permanent);
    }

    #[test]
    fn backoff_is_bounded_for_arbitrary_policies() {
        let mut rng = Prng::new(99);
        for case in 0..200 {
            let policy = RetryPolicy {
                max_retries: rng.gen_range(20) as u32,
                base: Duration::from_micros(rng.gen_range(5_000)),
                cap: Duration::from_micros(1 + rng.gen_range(20_000)),
                budget: Duration::from_micros(rng.gen_range(50_000)),
            };
            let mut attempt = 0u32;
            let mut slept = Duration::ZERO;
            while let Some(d) = policy.delay(attempt, slept) {
                assert!(d <= policy.cap, "case {case}: sleep above per-sleep cap");
                slept += d;
                attempt += 1;
                assert!(slept <= policy.budget, "case {case}: total sleep above budget");
                assert!(attempt <= policy.max_retries, "case {case}: attempts above cap");
            }
            // and the loop terminated — both bounds are hard stops
            assert!(attempt <= policy.max_retries && slept <= policy.budget);
        }
    }

    #[test]
    fn backoff_delays_grow_until_capped() {
        let p = RetryPolicy {
            max_retries: 10,
            base: Duration::from_micros(100),
            cap: Duration::from_micros(450),
            budget: Duration::from_secs(1),
        };
        assert_eq!(p.delay(0, Duration::ZERO), Some(Duration::from_micros(100)));
        assert_eq!(p.delay(1, Duration::ZERO), Some(Duration::from_micros(200)));
        assert_eq!(p.delay(2, Duration::ZERO), Some(Duration::from_micros(400)));
        assert_eq!(p.delay(3, Duration::ZERO), Some(Duration::from_micros(450)), "capped");
        assert_eq!(p.delay(10, Duration::ZERO), None, "attempt cap");
        assert_eq!(p.delay(0, Duration::from_secs(1)), None, "budget spent");
    }

    #[test]
    fn spec_grammar_parses_the_documented_examples() {
        let spec = FaultSpec::parse("ssd:eio:p=0.01:transient=3,hdd:dead@op=5000").unwrap();
        assert_eq!(spec.ssd.len(), 1);
        assert_eq!(spec.hdd.len(), 1);
        let eio = &spec.ssd[0];
        assert_eq!(eio.kind, FaultKind::Eio);
        assert!((eio.p - 0.01).abs() < 1e-12);
        assert_eq!(eio.transient, 3);
        let dead = &spec.hdd[0];
        assert_eq!(dead.kind, FaultKind::Dead);
        assert_eq!(dead.at_op, 5000);

        let spec =
            FaultSpec::parse("ssd:enospc:op=100:min_off=4096, hdd:slow:p=0.5:delay_us=250")
                .unwrap();
        assert_eq!(spec.ssd[0].kind, FaultKind::Enospc);
        assert_eq!(spec.ssd[0].at_op, 100);
        assert_eq!(spec.ssd[0].min_off, 4096);
        assert_eq!(spec.hdd[0].delay, Duration::from_micros(250));

        assert!(FaultSpec::parse("").unwrap().is_empty());
        for bad in [
            "nvme:eio",
            "ssd:badkind",
            "ssd:eio:p=1.5",
            "ssd:eio:frob=1",
            "ssd:dead@banana",
            "ssd",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn transient_two_fails_twice_then_succeeds() {
        // p=1: the very first write starts a burst of exactly 2 failures;
        // the 3rd attempt must succeed (the grace op), deterministically.
        let spec = FaultSpec::parse("ssd:eio:transient=2").unwrap();
        let dev = FaultBackend::new(mem(), spec.ssd.clone(), 7);
        assert!(dev.write_at(0, b"x").is_err(), "attempt 1 fails");
        assert!(dev.write_at(0, b"x").is_err(), "attempt 2 fails");
        assert!(dev.write_at(0, b"x").is_ok(), "attempt 3 succeeds");
        assert_eq!(dev.injected_faults(), 2, "exactly two faults injected");
        // the retry helper sees the same schedule end to end
        let dev = FaultBackend::new(mem(), spec.ssd.clone(), 7);
        let policy = RetryPolicy { base: Duration::ZERO, ..RetryPolicy::io_default() };
        let (result, retries) = retry_transient(&policy, || dev.write_at(0, b"x"));
        assert!(result.is_ok());
        assert_eq!(retries, 2, "succeeds on the 3rd attempt with 2 retries booked");
    }

    #[test]
    fn dead_at_op_kills_every_later_operation() {
        let spec = FaultSpec::parse("ssd:dead@op=3").unwrap();
        let dev = FaultBackend::new(mem(), spec.ssd.clone(), 1);
        for _ in 0..3 {
            dev.write_at(0, b"ok").unwrap();
        }
        for _ in 0..5 {
            let e = dev.write_at(0, b"no").unwrap_err();
            assert_eq!(IoFault::classify(&e), IoFault::Permanent);
        }
        let mut buf = [0u8; 2];
        assert!(dev.read_at(0, &mut buf).is_err(), "reads die too");
        assert!(dev.sync().is_err(), "sync dies too");
    }

    #[test]
    fn enospc_hits_writes_only_and_respects_offset_window() {
        let spec = FaultSpec::parse("ssd:enospc:min_off=1024").unwrap();
        let dev = FaultBackend::new(mem(), spec.ssd.clone(), 3);
        dev.write_at(0, b"superblock area ok").unwrap();
        dev.write_at(1023, b"x").unwrap(); // offset below the window
        let e = dev.write_at(4096, b"log area").unwrap_err();
        assert_eq!(IoFault::classify(&e), IoFault::DeviceFull);
        let mut buf = [0u8; 4];
        dev.read_at(4096, &mut buf).unwrap(); // reads unaffected
        dev.sync().unwrap(); // offset-scoped clause skips sync
    }

    #[test]
    fn seeded_injection_is_deterministic() {
        let spec = FaultSpec::parse("ssd:eio:p=0.3").unwrap();
        let run = |seed: u64| -> Vec<bool> {
            let dev = FaultBackend::new(mem(), spec.ssd.clone(), seed);
            (0..200).map(|i| dev.write_at(i * 8, b"deadbeef").is_err()).collect()
        };
        assert_eq!(run(42), run(42), "same seed, same fault schedule");
        assert_ne!(run(42), run(43), "different seed, different schedule");
        let faults = run(42).iter().filter(|&&f| f).count();
        assert!(faults > 20 && faults < 120, "p=0.3 fault rate plausible ({faults}/200)");
    }

    #[test]
    fn retry_transient_gives_up_on_permanent_faults() {
        let calls = AtomicU64::new(0);
        let (result, retries) = retry_transient(&RetryPolicy::io_default(), || {
            calls.fetch_add(1, Ordering::Relaxed);
            Err::<(), _>(IoFault::Permanent.error("dead"))
        });
        assert!(result.is_err());
        assert_eq!(retries, 0);
        assert_eq!(calls.load(Ordering::Relaxed), 1, "no retry on permanent");
    }
}
