//! Pluggable storage backends for the live engine.
//!
//! A [`Backend`] is a flat byte-addressable store — the live analogue of
//! the simulator's device models. The whole API is **`&self`**
//! (positional-I/O style, like `pwrite`/`pread`): any number of threads
//! may issue reads and writes concurrently, so the shard's ingest
//! clients, its background flusher, and mid-burst readers all drive the
//! device at the same time with no device-wide lock anywhere. Callers are
//! responsible for not issuing overlapping concurrent writes to the same
//! bytes (the shard's ownership map serializes those); overlapping a read
//! with a write to the same bytes yields some interleaving of old and new
//! content, never a crash.
//!
//! Two implementations ship:
//!
//! * [`MemBackend`] — a chunked sparse in-memory store with configurable
//!   synthetic latency, so unit tests run instantly and benches can model
//!   SSD/HDD speed ratios without real disks. The page store
//!   ([`MemStore`]) can be shared between backends and **snapshotted**:
//!   in snapshot (volatile-overlay) mode, writes land in an overlay that
//!   only [`Backend::sync`] merges into the durable map, and
//!   [`MemStore::freeze`] clones the durable map mid-flight — a
//!   power-loss image with torn in-flight writes, which is what lets the
//!   crash-recovery tests run with zero external dependencies;
//! * [`FileBackend`] — a real `std::fs` file (sparse where the OS
//!   allows), used by `ssdup live --backend file`. On Unix it uses true
//!   positional I/O (`pwrite`/`pread` via `FileExt`), so concurrent
//!   transfers never fight over a shared cursor; `sync` is a real
//!   `sync_data`, and [`FileBackend::open_existing`] reopens a previous
//!   run's image for crash recovery (`ssdup live --recover`).
//!
//! Writes at arbitrary offsets are allowed (HDD images are sparse); holes
//! read as zero on both implementations.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A flat byte store with positional (`&self`) I/O. `Send + Sync` so a
/// shard's clients, flusher, and readers can all hold it at once.
pub trait Backend: Send + Sync {
    /// Write `data` at absolute byte `offset` (sparse writes allowed).
    /// Callers must not overlap concurrent writes to the same bytes.
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()>;

    /// Fill `buf` from `offset`; unwritten holes read as zero.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()>;

    /// Total bytes written over the backend's lifetime.
    fn bytes_written(&self) -> u64;

    /// Flush to durable storage. The live shard calls this before
    /// acknowledging a write (publish) and before recycling a flushed
    /// region — acknowledged means durable.
    fn sync(&self) -> io::Result<()>;

    fn kind(&self) -> &'static str;
}

/// Any shared handle to a backend is itself a backend: the whole API is
/// `&self`, so an `Arc<T>` forwards every call. This is what lets a
/// caller keep an inspection handle to a backend that a wrapper (like
/// the group-commit sequencer) owns.
impl<T: Backend + ?Sized> Backend for Arc<T> {
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        (**self).write_at(offset, data)
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        (**self).read_at(offset, buf)
    }

    fn bytes_written(&self) -> u64 {
        (**self).bytes_written()
    }

    fn sync(&self) -> io::Result<()> {
        (**self).sync()
    }

    fn kind(&self) -> &'static str {
        (**self).kind()
    }
}

/// Synthetic service time applied per [`MemBackend`] operation: a fixed
/// per-op cost plus a bandwidth term. Mirrors the cost structure of the
/// simulator's device models closely enough for shard-scaling benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyntheticLatency {
    pub per_op_us: u64,
    pub us_per_mib: u64,
}

impl SyntheticLatency {
    /// No artificial delay (unit tests).
    pub const ZERO: SyntheticLatency = SyntheticLatency { per_op_us: 0, us_per_mib: 0 };

    /// SATA-SSD-like: ~380 MB/s sequential, small per-op cost.
    pub fn ssd() -> Self {
        Self { per_op_us: 60, us_per_mib: 2_600 }
    }

    /// HDD-like: ~110 MB/s sequential plus a per-op positioning cost.
    pub fn hdd() -> Self {
        Self { per_op_us: 400, us_per_mib: 9_000 }
    }

    fn apply(&self, bytes: usize) {
        let us = self.per_op_us + ((bytes as u64 * self.us_per_mib) >> 20);
        if us > 0 {
            std::thread::sleep(Duration::from_micros(us));
        }
    }
}

/// Page granularity of the sparse in-memory store.
const PAGE_BYTES: usize = 64 * 1024;

/// Number of page-lock shards. Power of two; plenty for the handful of
/// threads a shard can keep in flight at once.
const LOCK_SHARDS: usize = 64;

type PageMap = Vec<Mutex<HashMap<u64, Box<[u8]>>>>;

fn empty_pages() -> PageMap {
    (0..LOCK_SHARDS).map(|_| Mutex::new(HashMap::new())).collect()
}

/// The sharable page store behind [`MemBackend`]: durable pages plus —
/// in snapshot mode — a volatile overlay modeling a device write cache.
///
/// * **direct mode** (`MemStore::new(false)`, and every backend made via
///   [`MemBackend::new`]): writes land in the durable map immediately and
///   `sync` is a no-op — the original, fastest behavior;
/// * **snapshot mode** (`MemStore::new(true)`): writes land in a volatile
///   overlay; `sync` merges the *whole* overlay into the durable map
///   (like `fsync` flushing a shared page cache); [`MemStore::freeze`]
///   clones the durable map into a fresh store — the exact power-loss
///   image: unsynced writes are gone, and an in-flight write caught
///   between pages is genuinely torn.
pub struct MemStore {
    durable: PageMap,
    overlay: PageMap,
    volatile: bool,
}

impl MemStore {
    pub fn new(volatile: bool) -> Arc<Self> {
        Arc::new(Self { durable: empty_pages(), overlay: empty_pages(), volatile })
    }

    /// Clone the durable state into a fresh store (same mode): the image
    /// a machine would reboot with if power failed at this instant. Safe
    /// to call while other threads keep writing — each page is cloned
    /// under its lock, so a concurrent multi-page write is captured
    /// partially (a torn write), exactly like real power loss.
    pub fn freeze(&self) -> Arc<Self> {
        let durable: PageMap = self
            .durable
            .iter()
            .map(|s| Mutex::new(s.lock().unwrap().clone()))
            .collect();
        Arc::new(Self { durable, overlay: empty_pages(), volatile: self.volatile })
    }

    /// Resident (allocated) bytes across durable + overlay pages.
    pub fn resident_bytes(&self) -> u64 {
        let count = |m: &PageMap| -> u64 {
            m.iter().map(|s| s.lock().unwrap().len() as u64 * PAGE_BYTES as u64).sum()
        };
        count(&self.durable) + count(&self.overlay)
    }

    fn shard_of(page: u64) -> usize {
        (page % LOCK_SHARDS as u64) as usize
    }

    /// Copy `data` into pages starting at byte `offset`. In snapshot mode
    /// the target is the overlay, copy-on-write from the durable page.
    fn write(&self, offset: u64, data: &[u8]) {
        let mut off = offset;
        let mut rest = data;
        while !rest.is_empty() {
            let page = off / PAGE_BYTES as u64;
            let within = (off % PAGE_BYTES as u64) as usize;
            let take = rest.len().min(PAGE_BYTES - within);
            if self.volatile {
                let mut shard = self.overlay[Self::shard_of(page)].lock().unwrap();
                let p = shard.entry(page).or_insert_with(|| {
                    // copy-on-write: seed the overlay page from the
                    // durable copy so partial-page writes keep old bytes
                    self.durable[Self::shard_of(page)]
                        .lock()
                        .unwrap()
                        .get(&page)
                        .cloned()
                        .unwrap_or_else(|| vec![0u8; PAGE_BYTES].into_boxed_slice())
                });
                p[within..within + take].copy_from_slice(&rest[..take]);
            } else {
                let mut shard = self.durable[Self::shard_of(page)].lock().unwrap();
                let p =
                    shard.entry(page).or_insert_with(|| vec![0u8; PAGE_BYTES].into_boxed_slice());
                p[within..within + take].copy_from_slice(&rest[..take]);
            }
            off += take as u64;
            rest = &rest[take..];
        }
    }

    fn read(&self, offset: u64, buf: &mut [u8]) {
        let mut off = offset;
        let mut rest: &mut [u8] = buf;
        while !rest.is_empty() {
            let page = off / PAGE_BYTES as u64;
            let within = (off % PAGE_BYTES as u64) as usize;
            let take = rest.len().min(PAGE_BYTES - within);
            let mut served = false;
            if self.volatile {
                let shard = self.overlay[Self::shard_of(page)].lock().unwrap();
                if let Some(p) = shard.get(&page) {
                    rest[..take].copy_from_slice(&p[within..within + take]);
                    served = true;
                }
            }
            if !served {
                let shard = self.durable[Self::shard_of(page)].lock().unwrap();
                match shard.get(&page) {
                    Some(p) => rest[..take].copy_from_slice(&p[within..within + take]),
                    None => rest[..take].fill(0),
                }
            }
            off += take as u64;
            rest = &mut rest[take..];
        }
    }

    /// Merge every overlay page into the durable map (snapshot mode; a
    /// no-op otherwise). Like a real `fsync`, this flushes the shared
    /// cache — including other writers' not-yet-synced pages.
    fn sync(&self) {
        if !self.volatile {
            return;
        }
        for (i, shard) in self.overlay.iter().enumerate() {
            let mut overlay = shard.lock().unwrap();
            if overlay.is_empty() {
                continue;
            }
            let mut durable = self.durable[i].lock().unwrap();
            for (page, data) in overlay.drain() {
                durable.insert(page, data);
            }
        }
    }
}

/// Chunked sparse in-memory backend over a (possibly shared)
/// [`MemStore`]. Only touched 64 KiB pages are allocated, so a TiB-scale
/// sparse HDD image costs memory proportional to the data actually
/// written. Concurrency comes from sharding the page table by page index:
/// transfers touching different pages never contend, and the
/// synthetic-latency sleep (the modeled device service time) is taken
/// before any lock, so concurrent in-flight operations overlap their
/// service times exactly like commands queued on a real device.
pub struct MemBackend {
    store: Arc<MemStore>,
    latency: SyntheticLatency,
    bytes_written: AtomicU64,
}

impl MemBackend {
    /// Private direct-mode store (the original zero-ceremony constructor).
    pub fn new(latency: SyntheticLatency) -> Self {
        Self::over(MemStore::new(false), latency)
    }

    /// A backend over a caller-owned store — the handle that survives an
    /// engine "crash" so a second engine can recover from the same pages.
    pub fn over(store: Arc<MemStore>, latency: SyntheticLatency) -> Self {
        Self { store, latency, bytes_written: AtomicU64::new(0) }
    }

    /// The shared page store (freeze/inspect from tests).
    pub fn store(&self) -> Arc<MemStore> {
        Arc::clone(&self.store)
    }

    /// Resident (allocated) bytes — test visibility into sparseness.
    pub fn resident_bytes(&self) -> u64 {
        self.store.resident_bytes()
    }
}

impl Backend for MemBackend {
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        // modeled service time first, outside every lock: concurrent
        // writers overlap their sleeps (a deep device queue), then only
        // touch per-page locks for the memcpy
        self.latency.apply(data.len());
        self.store.write(offset, data);
        self.bytes_written.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        self.latency.apply(buf.len());
        self.store.read(offset, buf);
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        self.bytes_written.load(Ordering::Relaxed)
    }

    fn sync(&self) -> io::Result<()> {
        self.store.sync();
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "mem"
    }
}

/// Real-file backend. Offsets past EOF read as zero, matching sparse-file
/// semantics. I/O is positional (`pwrite`/`pread` on Unix), so concurrent
/// callers never share a file cursor; `sync` is `sync_data`, so the
/// shard's publish barrier makes acknowledged writes power-loss durable.
pub struct FileBackend {
    file: File,
    path: PathBuf,
    bytes_written: AtomicU64,
    /// non-Unix fallback only: serializes the seek+transfer pairs that
    /// emulate positional I/O
    #[cfg(not(unix))]
    cursor: Mutex<()>,
}

impl FileBackend {
    /// Create (truncating any previous image) — a fresh device.
    pub fn create(path: &Path) -> io::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(path)?;
        Ok(Self::from_file(file, path))
    }

    /// Reopen an existing image *without* truncating — the recovery path
    /// (`LiveEngine::open_file`). Fails if the image does not exist: a
    /// silently-created empty file would turn "recover my data" into
    /// "start over".
    pub fn open_existing(path: &Path) -> io::Result<Self> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        Ok(Self::from_file(file, path))
    }

    fn from_file(file: File, path: &Path) -> Self {
        Self {
            file,
            path: path.to_path_buf(),
            bytes_written: AtomicU64::new(0),
            #[cfg(not(unix))]
            cursor: Mutex::new(()),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Backend for FileBackend {
    #[cfg(unix)]
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.write_all_at(data, offset)?;
        self.bytes_written.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    #[cfg(unix)]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        use std::os::unix::fs::FileExt;
        // read to EOF, then zero-fill the hole past it
        let mut filled = 0;
        while filled < buf.len() {
            match self.file.read_at(&mut buf[filled..], offset + filled as u64)? {
                0 => break,
                n => filled += n,
            }
        }
        buf[filled..].fill(0);
        Ok(())
    }

    #[cfg(not(unix))]
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        let _guard = self.cursor.lock().unwrap();
        let mut f = &self.file;
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(data)?;
        self.bytes_written.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    #[cfg(not(unix))]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        use std::io::{Read, Seek, SeekFrom};
        let _guard = self.cursor.lock().unwrap();
        let mut f = &self.file;
        f.seek(SeekFrom::Start(offset))?;
        let mut filled = 0;
        while filled < buf.len() {
            match f.read(&mut buf[filled..])? {
                0 => break,
                n => filled += n,
            }
        }
        buf[filled..].fill(0);
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        self.bytes_written.load(Ordering::Relaxed)
    }

    fn sync(&self) -> io::Result<()> {
        self.file.sync_data()
    }

    fn kind(&self) -> &'static str {
        "file"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(b: &dyn Backend) {
        b.write_at(10, b"hello").unwrap();
        b.write_at(1_000_000, b"world").unwrap();
        let mut buf = [0u8; 5];
        b.read_at(10, &mut buf).unwrap();
        assert_eq!(&buf, b"hello");
        b.read_at(1_000_000, &mut buf).unwrap();
        assert_eq!(&buf, b"world");
        // holes (and reads past every write) are zero
        b.read_at(500, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 5]);
        b.read_at(2_000_000, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 5]);
        assert_eq!(b.bytes_written(), 10);
        b.sync().unwrap();
    }

    #[test]
    fn mem_backend_round_trips() {
        round_trip(&MemBackend::new(SyntheticLatency::ZERO));
    }

    #[test]
    fn mem_backend_snapshot_mode_round_trips() {
        round_trip(&MemBackend::over(MemStore::new(true), SyntheticLatency::ZERO));
    }

    #[test]
    fn file_backend_round_trips() {
        let dir = std::env::temp_dir().join(format!("ssdup-be-{}", std::process::id()));
        let b = FileBackend::create(&dir.join("t.img")).unwrap();
        round_trip(&b);
        drop(b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_backend_open_existing_sees_previous_data_and_rejects_missing() {
        let dir = std::env::temp_dir().join(format!("ssdup-beo-{}", std::process::id()));
        let path = dir.join("img");
        {
            let b = FileBackend::create(&path).unwrap();
            b.write_at(100, b"persist").unwrap();
            b.sync().unwrap();
        }
        let b = FileBackend::open_existing(&path).unwrap();
        let mut buf = [0u8; 7];
        b.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf, b"persist", "reopen must not truncate");
        assert!(FileBackend::open_existing(&dir.join("absent")).is_err());
        drop(b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mem_backend_is_sparse() {
        let b = MemBackend::new(SyntheticLatency::ZERO);
        b.write_at(0, &[1u8; 512]).unwrap();
        b.write_at(1 << 40, &[2u8; 512]).unwrap(); // 1 TiB away
        assert!(b.resident_bytes() <= 4 * PAGE_BYTES as u64, "sparse writes stay cheap");
    }

    #[test]
    fn mem_write_spanning_pages() {
        let b = MemBackend::new(SyntheticLatency::ZERO);
        let data: Vec<u8> = (0..(PAGE_BYTES + 100)).map(|i| (i % 251) as u8).collect();
        let start = PAGE_BYTES as u64 - 50;
        b.write_at(start, &data).unwrap();
        let mut back = vec![0u8; data.len()];
        b.read_at(start, &mut back).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn snapshot_store_loses_unsynced_writes_and_keeps_synced_ones() {
        let store = MemStore::new(true);
        let b = MemBackend::over(Arc::clone(&store), SyntheticLatency::ZERO);
        b.write_at(0, b"durable-after-sync").unwrap();
        b.sync().unwrap();
        b.write_at(100, b"volatile").unwrap(); // never synced
        // the live view reads both
        let mut buf = [0u8; 8];
        b.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf, b"volatile");
        // the frozen (power-loss) image only has the synced write
        let frozen = MemBackend::over(store.freeze(), SyntheticLatency::ZERO);
        let mut got = [0u8; 18];
        frozen.read_at(0, &mut got).unwrap();
        assert_eq!(&got, b"durable-after-sync");
        frozen.read_at(100, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 8], "unsynced write must not survive the freeze");
        // partial-page overwrite before sync keeps the old synced bytes
        // around it (copy-on-write overlay)
        b.write_at(2, b"XX").unwrap();
        let mut mixed = [0u8; 7];
        b.read_at(0, &mut mixed).unwrap();
        assert_eq!(&mixed, b"duXXble");
    }

    #[test]
    fn direct_mode_freeze_is_a_plain_copy() {
        // non-volatile store: every write is durable immediately (process
        // kill semantics — the page cache survives), so freeze sees all
        let store = MemStore::new(false);
        let b = MemBackend::over(Arc::clone(&store), SyntheticLatency::ZERO);
        b.write_at(0, b"kept").unwrap();
        let frozen = MemBackend::over(store.freeze(), SyntheticLatency::ZERO);
        let mut buf = [0u8; 4];
        frozen.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"kept");
        // and the copy is independent of later writes
        b.write_at(0, b"gone").unwrap();
        frozen.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"kept");
    }

    /// The point of the `&self` API: disjoint transfers from many threads
    /// through one shared backend, no `&mut` anywhere.
    fn concurrent_disjoint_writes(b: &(dyn Backend + '_)) {
        const THREADS: usize = 8;
        const SPAN: usize = 3 * PAGE_BYTES + 1234; // straddle page boundaries
        std::thread::scope(|s| {
            for t in 0..THREADS {
                s.spawn(move || {
                    let data: Vec<u8> = (0..SPAN).map(|i| ((i + t * 31) % 251) as u8).collect();
                    b.write_at((t * SPAN) as u64, &data).unwrap();
                });
            }
        });
        let mut back = vec![0u8; SPAN];
        for t in 0..THREADS {
            b.read_at((t * SPAN) as u64, &mut back).unwrap();
            assert!(
                back.iter().enumerate().all(|(i, &v)| v == ((i + t * 31) % 251) as u8),
                "thread {t}'s extent round-trips"
            );
        }
        assert_eq!(b.bytes_written(), (THREADS * SPAN) as u64);
    }

    #[test]
    fn mem_backend_concurrent_disjoint_writes() {
        concurrent_disjoint_writes(&MemBackend::new(SyntheticLatency::ZERO));
    }

    #[test]
    fn snapshot_mode_concurrent_disjoint_writes() {
        concurrent_disjoint_writes(&MemBackend::over(MemStore::new(true), SyntheticLatency::ZERO));
    }

    #[test]
    fn file_backend_concurrent_disjoint_writes() {
        let dir = std::env::temp_dir().join(format!("ssdup-bec-{}", std::process::id()));
        let b = FileBackend::create(&dir.join("c.img")).unwrap();
        concurrent_disjoint_writes(&b);
        drop(b);
        std::fs::remove_dir_all(&dir).ok();
    }
}
