//! Pluggable storage backends for the live engine.
//!
//! A [`Backend`] is a flat byte-addressable store — the live analogue of
//! the simulator's device models. The whole API is **`&self`**
//! (positional-I/O style, like `pwrite`/`pread`): any number of threads
//! may issue reads and writes concurrently, so the shard's ingest
//! clients, its background flusher, and mid-burst readers all drive the
//! device at the same time with no device-wide lock anywhere. Callers are
//! responsible for not issuing overlapping concurrent writes to the same
//! bytes (the shard's ownership map serializes those); overlapping a read
//! with a write to the same bytes yields some interleaving of old and new
//! content, never a crash.
//!
//! Two implementations ship:
//!
//! * [`MemBackend`] — a chunked sparse in-memory store with configurable
//!   synthetic latency, so unit tests run instantly and benches can model
//!   SSD/HDD speed ratios without real disks. The page store
//!   ([`MemStore`]) can be shared between backends and **snapshotted**:
//!   in snapshot (volatile-overlay) mode, writes land in an overlay that
//!   only [`Backend::sync`] merges into the durable map, and
//!   [`MemStore::freeze`] clones the durable map mid-flight — a
//!   power-loss image with torn in-flight writes, which is what lets the
//!   crash-recovery tests run with zero external dependencies;
//! * [`FileBackend`] — a real `std::fs` file (sparse where the OS
//!   allows), used by `ssdup live --backend file`. On Unix it uses true
//!   positional I/O (`pwrite`/`pread` via `FileExt`), so concurrent
//!   transfers never fight over a shared cursor; `sync` is a real
//!   `sync_data`, and [`FileBackend::open_existing`] reopens a previous
//!   run's image for crash recovery (`ssdup live --recover`).
//!
//! Writes at arbitrary offsets are allowed (HDD images are sparse); holes
//! read as zero on both implementations.
//!
//! On top of the raw backends sits [`IoQueue`] — an io_uring-style
//! submission/completion layer (queue-per-device, like a block layer's
//! per-device request queue): producers enqueue batched [`IoReq`]s and
//! park on a [`CompletionToken`] while a small worker pool (N workers,
//! N ≪ clients) drives the device, coalescing adjacent requests into
//! vectored writes and advancing the group-commit ticket watermark on
//! completion. Queue depth is therefore decoupled from thread count.

use std::collections::{HashMap, VecDeque};
use std::fs::{File, OpenOptions};
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::live::commit::GroupSync;
use crate::live::fault::{retry_transient, IoFault, RetryPolicy};

/// A flat byte store with positional (`&self`) I/O. `Send + Sync` so a
/// shard's clients, flusher, and readers can all hold it at once.
pub trait Backend: Send + Sync {
    /// Write `data` at absolute byte `offset` (sparse writes allowed).
    /// Callers must not overlap concurrent writes to the same bytes.
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()>;

    /// Fill `buf` from `offset`; unwritten holes read as zero.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()>;

    /// Total bytes written over the backend's lifetime.
    fn bytes_written(&self) -> u64;

    /// Flush to durable storage. The live shard calls this before
    /// acknowledging a write (publish) and before recycling a flushed
    /// region — acknowledged means durable.
    fn sync(&self) -> io::Result<()>;

    fn kind(&self) -> &'static str;

    /// Write `bufs` back to back starting at `offset` (`pwritev`-style
    /// gather). The default is a sequential [`Backend::write_at`] loop;
    /// implementations override it to coalesce the transfer into one
    /// device operation ([`FileBackend`]: a single syscall over a
    /// staging buffer; [`MemBackend`]: one modeled service time for the
    /// whole gather — buffered emulation). Same aliasing rules as
    /// `write_at`.
    fn write_vectored_at(&self, offset: u64, bufs: &[&[u8]]) -> io::Result<()> {
        let mut off = offset;
        for buf in bufs {
            self.write_at(off, buf)?;
            off += buf.len() as u64;
        }
        Ok(())
    }
}

/// Any shared handle to a backend is itself a backend: the whole API is
/// `&self`, so an `Arc<T>` forwards every call. This is what lets a
/// caller keep an inspection handle to a backend that a wrapper (like
/// the group-commit sequencer) owns.
impl<T: Backend + ?Sized> Backend for Arc<T> {
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        (**self).write_at(offset, data)
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        (**self).read_at(offset, buf)
    }

    fn bytes_written(&self) -> u64 {
        (**self).bytes_written()
    }

    fn sync(&self) -> io::Result<()> {
        (**self).sync()
    }

    fn kind(&self) -> &'static str {
        (**self).kind()
    }

    fn write_vectored_at(&self, offset: u64, bufs: &[&[u8]]) -> io::Result<()> {
        (**self).write_vectored_at(offset, bufs)
    }
}

/// Synthetic service time applied per [`MemBackend`] operation: a fixed
/// per-op cost plus a bandwidth term, with **bounded device concurrency**
/// — up to `max_inflight` operations overlap their service times fully
/// (independent command lanes, like NCQ slots); past that, service time
/// grows with the excess so aggregate throughput plateaus instead of
/// scaling linearly forever. IO-depth sweeps therefore show a realistic
/// knee at `max_inflight`. Mirrors the cost structure of the simulator's
/// device models closely enough for shard-scaling benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyntheticLatency {
    pub per_op_us: u64,
    pub us_per_mib: u64,
    /// Concurrent operations the device absorbs at full speed; `0` means
    /// unlimited (the pre-knee behavior, used by most unit tests).
    pub max_inflight: u64,
}

impl SyntheticLatency {
    /// No artificial delay (unit tests).
    pub const ZERO: SyntheticLatency =
        SyntheticLatency { per_op_us: 0, us_per_mib: 0, max_inflight: 0 };

    /// SATA-SSD-like: ~380 MB/s sequential, small per-op cost, NCQ-depth
    /// 32 command concurrency.
    pub fn ssd() -> Self {
        Self { per_op_us: 60, us_per_mib: 2_600, max_inflight: 32 }
    }

    /// HDD-like: ~110 MB/s sequential plus a per-op positioning cost and
    /// a shallow command queue.
    pub fn hdd() -> Self {
        Self { per_op_us: 400, us_per_mib: 9_000, max_inflight: 4 }
    }

    /// Modeled service time for one `bytes`-sized operation issued while
    /// `depth` operations (including this one) are in flight on the
    /// device. Pure, so the knee math is unit-testable without sleeping:
    /// below the knee the time is depth-independent (lanes overlap
    /// fully); above it, it scales by `depth / max_inflight`, which pins
    /// aggregate throughput at the knee value.
    pub fn service_us(&self, bytes: usize, depth: u64) -> u64 {
        let base = self.per_op_us + ((bytes as u64 * self.us_per_mib) >> 20);
        if self.max_inflight > 0 && depth > self.max_inflight {
            base * depth / self.max_inflight
        } else {
            base
        }
    }

    fn apply(&self, bytes: usize, depth: u64) {
        let us = self.service_us(bytes, depth);
        if us > 0 {
            std::thread::sleep(Duration::from_micros(us));
        }
    }
}

/// Page granularity of the sparse in-memory store.
const PAGE_BYTES: usize = 64 * 1024;

/// Number of page-lock shards. Power of two; plenty for the handful of
/// threads a shard can keep in flight at once.
const LOCK_SHARDS: usize = 64;

type PageMap = Vec<Mutex<HashMap<u64, Box<[u8]>>>>;

fn empty_pages() -> PageMap {
    (0..LOCK_SHARDS).map(|_| Mutex::new(HashMap::new())).collect()
}

/// The sharable page store behind [`MemBackend`]: durable pages plus —
/// in snapshot mode — a volatile overlay modeling a device write cache.
///
/// * **direct mode** (`MemStore::new(false)`, and every backend made via
///   [`MemBackend::new`]): writes land in the durable map immediately and
///   `sync` is a no-op — the original, fastest behavior;
/// * **snapshot mode** (`MemStore::new(true)`): writes land in a volatile
///   overlay; `sync` merges the *whole* overlay into the durable map
///   (like `fsync` flushing a shared page cache); [`MemStore::freeze`]
///   clones the durable map into a fresh store — the exact power-loss
///   image: unsynced writes are gone, and an in-flight write caught
///   between pages is genuinely torn.
pub struct MemStore {
    durable: PageMap,
    overlay: PageMap,
    volatile: bool,
}

impl MemStore {
    pub fn new(volatile: bool) -> Arc<Self> {
        Arc::new(Self { durable: empty_pages(), overlay: empty_pages(), volatile })
    }

    /// Clone the durable state into a fresh store (same mode): the image
    /// a machine would reboot with if power failed at this instant. Safe
    /// to call while other threads keep writing — each page is cloned
    /// under its lock, so a concurrent multi-page write is captured
    /// partially (a torn write), exactly like real power loss.
    pub fn freeze(&self) -> Arc<Self> {
        let durable: PageMap = self
            .durable
            .iter()
            .map(|s| Mutex::new(s.lock().unwrap().clone()))
            .collect();
        Arc::new(Self { durable, overlay: empty_pages(), volatile: self.volatile })
    }

    /// Resident (allocated) bytes across durable + overlay pages.
    pub fn resident_bytes(&self) -> u64 {
        let count = |m: &PageMap| -> u64 {
            m.iter().map(|s| s.lock().unwrap().len() as u64 * PAGE_BYTES as u64).sum()
        };
        count(&self.durable) + count(&self.overlay)
    }

    fn shard_of(page: u64) -> usize {
        (page % LOCK_SHARDS as u64) as usize
    }

    /// Copy `data` into pages starting at byte `offset`. In snapshot mode
    /// the target is the overlay, copy-on-write from the durable page.
    fn write(&self, offset: u64, data: &[u8]) {
        let mut off = offset;
        let mut rest = data;
        while !rest.is_empty() {
            let page = off / PAGE_BYTES as u64;
            let within = (off % PAGE_BYTES as u64) as usize;
            let take = rest.len().min(PAGE_BYTES - within);
            if self.volatile {
                let mut shard = self.overlay[Self::shard_of(page)].lock().unwrap();
                let p = shard.entry(page).or_insert_with(|| {
                    // copy-on-write: seed the overlay page from the
                    // durable copy so partial-page writes keep old bytes
                    self.durable[Self::shard_of(page)]
                        .lock()
                        .unwrap()
                        .get(&page)
                        .cloned()
                        .unwrap_or_else(|| vec![0u8; PAGE_BYTES].into_boxed_slice())
                });
                p[within..within + take].copy_from_slice(&rest[..take]);
            } else {
                let mut shard = self.durable[Self::shard_of(page)].lock().unwrap();
                let p =
                    shard.entry(page).or_insert_with(|| vec![0u8; PAGE_BYTES].into_boxed_slice());
                p[within..within + take].copy_from_slice(&rest[..take]);
            }
            off += take as u64;
            rest = &rest[take..];
        }
    }

    fn read(&self, offset: u64, buf: &mut [u8]) {
        let mut off = offset;
        let mut rest: &mut [u8] = buf;
        while !rest.is_empty() {
            let page = off / PAGE_BYTES as u64;
            let within = (off % PAGE_BYTES as u64) as usize;
            let take = rest.len().min(PAGE_BYTES - within);
            let mut served = false;
            if self.volatile {
                let shard = self.overlay[Self::shard_of(page)].lock().unwrap();
                if let Some(p) = shard.get(&page) {
                    rest[..take].copy_from_slice(&p[within..within + take]);
                    served = true;
                }
            }
            if !served {
                let shard = self.durable[Self::shard_of(page)].lock().unwrap();
                match shard.get(&page) {
                    Some(p) => rest[..take].copy_from_slice(&p[within..within + take]),
                    None => rest[..take].fill(0),
                }
            }
            off += take as u64;
            rest = &mut rest[take..];
        }
    }

    /// Merge every overlay page into the durable map (snapshot mode; a
    /// no-op otherwise). Like a real `fsync`, this flushes the shared
    /// cache — including other writers' not-yet-synced pages.
    fn sync(&self) {
        if !self.volatile {
            return;
        }
        for (i, shard) in self.overlay.iter().enumerate() {
            let mut overlay = shard.lock().unwrap();
            if overlay.is_empty() {
                continue;
            }
            let mut durable = self.durable[i].lock().unwrap();
            for (page, data) in overlay.drain() {
                durable.insert(page, data);
            }
        }
    }
}

/// Chunked sparse in-memory backend over a (possibly shared)
/// [`MemStore`]. Only touched 64 KiB pages are allocated, so a TiB-scale
/// sparse HDD image costs memory proportional to the data actually
/// written. Concurrency comes from sharding the page table by page index:
/// transfers touching different pages never contend, and the
/// synthetic-latency sleep (the modeled device service time) is taken
/// before any lock, so concurrent in-flight operations overlap their
/// service times exactly like commands queued on a real device.
pub struct MemBackend {
    store: Arc<MemStore>,
    latency: SyntheticLatency,
    bytes_written: AtomicU64,
    /// operations currently inside the modeled service time — the depth
    /// fed to [`SyntheticLatency::service_us`] for the concurrency knee
    inflight: AtomicU64,
}

impl MemBackend {
    /// Private direct-mode store (the original zero-ceremony constructor).
    pub fn new(latency: SyntheticLatency) -> Self {
        Self::over(MemStore::new(false), latency)
    }

    /// A backend over a caller-owned store — the handle that survives an
    /// engine "crash" so a second engine can recover from the same pages.
    pub fn over(store: Arc<MemStore>, latency: SyntheticLatency) -> Self {
        Self { store, latency, bytes_written: AtomicU64::new(0), inflight: AtomicU64::new(0) }
    }

    /// Run `op` with the in-flight depth counted around the modeled
    /// service sleep.
    fn timed<R>(&self, bytes: usize, op: impl FnOnce() -> R) -> R {
        // Relaxed: advisory depth gauge feeding the latency model — an
        // off-by-one race only nudges a modeled sleep, orders nothing
        let depth = self.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.latency.apply(bytes, depth);
        let r = op();
        // Relaxed: same gauge, decrement side
        self.inflight.fetch_sub(1, Ordering::Relaxed);
        r
    }

    /// The shared page store (freeze/inspect from tests).
    pub fn store(&self) -> Arc<MemStore> {
        Arc::clone(&self.store)
    }

    /// Resident (allocated) bytes — test visibility into sparseness.
    pub fn resident_bytes(&self) -> u64 {
        self.store.resident_bytes()
    }
}

impl Backend for MemBackend {
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        // modeled service time first, outside every lock: concurrent
        // writers overlap their sleeps (a deep device queue), then only
        // touch per-page locks for the memcpy
        self.timed(data.len(), || self.store.write(offset, data));
        // Relaxed: throughput stats counter, folded after the run
        self.bytes_written.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        self.timed(buf.len(), || self.store.read(offset, buf));
        Ok(())
    }

    /// Buffered gather emulation: one modeled service time for the whole
    /// vector (a single device command), then the per-buffer memcpys.
    fn write_vectored_at(&self, offset: u64, bufs: &[&[u8]]) -> io::Result<()> {
        let total: usize = bufs.iter().map(|b| b.len()).sum();
        self.timed(total, || {
            let mut off = offset;
            for buf in bufs {
                self.store.write(off, buf);
                off += buf.len() as u64;
            }
        });
        // Relaxed: throughput stats counter, folded after the run
        self.bytes_written.fetch_add(total as u64, Ordering::Relaxed);
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        // Relaxed: stats read — totals only need to be eventually exact
        self.bytes_written.load(Ordering::Relaxed)
    }

    fn sync(&self) -> io::Result<()> {
        self.store.sync();
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "mem"
    }
}

/// Real-file backend. Offsets past EOF read as zero, matching sparse-file
/// semantics. I/O is positional (`pwrite`/`pread` on Unix), so concurrent
/// callers never share a file cursor; `sync` is `sync_data`, so the
/// shard's publish barrier makes acknowledged writes power-loss durable.
pub struct FileBackend {
    file: File,
    path: PathBuf,
    bytes_written: AtomicU64,
    /// non-Unix fallback only: serializes the seek+transfer pairs that
    /// emulate positional I/O
    #[cfg(not(unix))]
    cursor: Mutex<()>,
}

impl FileBackend {
    /// Create (truncating any previous image) — a fresh device.
    pub fn create(path: &Path) -> io::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(path)?;
        Ok(Self::from_file(file, path))
    }

    /// Reopen an existing image *without* truncating — the recovery path
    /// (`LiveEngine::open_file`). Fails if the image does not exist: a
    /// silently-created empty file would turn "recover my data" into
    /// "start over".
    pub fn open_existing(path: &Path) -> io::Result<Self> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        Ok(Self::from_file(file, path))
    }

    fn from_file(file: File, path: &Path) -> Self {
        Self {
            file,
            path: path.to_path_buf(),
            bytes_written: AtomicU64::new(0),
            #[cfg(not(unix))]
            cursor: Mutex::new(()),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Backend for FileBackend {
    #[cfg(unix)]
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.write_all_at(data, offset)?;
        // Relaxed: throughput stats counter, folded after the run
        self.bytes_written.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    #[cfg(unix)]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        use std::os::unix::fs::FileExt;
        // read to EOF, then zero-fill the hole past it
        let mut filled = 0;
        while filled < buf.len() {
            match self.file.read_at(&mut buf[filled..], offset + filled as u64)? {
                0 => break,
                n => filled += n,
            }
        }
        buf[filled..].fill(0);
        Ok(())
    }

    #[cfg(not(unix))]
    fn write_at(&self, offset: u64, data: &[u8]) -> io::Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        let _guard = self.cursor.lock().unwrap();
        let mut f = &self.file;
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(data)?;
        // Relaxed: throughput stats counter, folded after the run
        self.bytes_written.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    #[cfg(not(unix))]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        use std::io::{Read, Seek, SeekFrom};
        let _guard = self.cursor.lock().unwrap();
        let mut f = &self.file;
        f.seek(SeekFrom::Start(offset))?;
        let mut filled = 0;
        while filled < buf.len() {
            match f.read(&mut buf[filled..])? {
                0 => break,
                n => filled += n,
            }
        }
        buf[filled..].fill(0);
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        // Relaxed: stats read — totals only need to be eventually exact
        self.bytes_written.load(Ordering::Relaxed)
    }

    fn sync(&self) -> io::Result<()> {
        self.file.sync_data()
    }

    fn kind(&self) -> &'static str {
        "file"
    }

    /// Gather into a staging buffer and issue **one** positional write —
    /// the zero-dependency stand-in for `pwritev` (libc is off-limits),
    /// trading one memcpy for N-1 syscalls.
    fn write_vectored_at(&self, offset: u64, bufs: &[&[u8]]) -> io::Result<()> {
        match bufs {
            [] => Ok(()),
            [one] => self.write_at(offset, one),
            many => {
                let total: usize = many.iter().map(|b| b.len()).sum();
                let mut staged = Vec::with_capacity(total);
                for buf in many {
                    staged.extend_from_slice(buf);
                }
                self.write_at(offset, &staged)
            }
        }
    }
}

// ---------------------------------------------------------------------
// IoQueue: submission/completion pipeline over a GroupSync'd device
// ---------------------------------------------------------------------

/// One queued positional write. The data is carried as an erased pointer
/// — io_uring's "registered buffer" idiom — so a request can either own
/// its bytes ([`IoReq::owned`]) or borrow the submitter's buffer without
/// a lifetime parameter ([`IoReq::borrowed`], unsafe: the submitter must
/// outwait the completion).
pub struct IoReq {
    offset: u64,
    ptr: *const u8,
    len: usize,
    _own: Option<Box<[u8]>>,
}

// SAFETY: the pointed-to bytes are either owned by `_own` (moved with
// the request) or covered by the `IoReq::borrowed` contract — the
// submitter keeps them alive and unmodified until the batch's
// completion is delivered (and `CompletionToken` blocks in `Drop` until
// then, so even an unwinding submitter cannot free them early).
unsafe impl Send for IoReq {}

impl IoReq {
    /// A request that owns its payload.
    pub fn owned(offset: u64, data: Box<[u8]>) -> Self {
        let (ptr, len) = (data.as_ptr(), data.len());
        Self { offset, ptr, len, _own: Some(data) }
    }

    /// A request borrowing the submitter's buffer, with the lifetime
    /// erased (no copy on the ingest hot path).
    ///
    /// # Safety
    ///
    /// The caller must keep `data` alive and unmodified until the
    /// [`CompletionToken`] returned by the `submit` call carrying this
    /// request has been waited on (or dropped — its `Drop` waits). The
    /// live shard satisfies this by parking on the token before the
    /// buffers leave scope.
    pub unsafe fn borrowed(offset: u64, data: &[u8]) -> Self {
        Self { offset, ptr: data.as_ptr(), len: data.len(), _own: None }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn as_slice(&self) -> &[u8] {
        // SAFETY: valid per the Send invariant above
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

/// What a completed batch hands back to its submitter.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    /// Group-commit ticket covering every write in the batch — pass to
    /// [`GroupSync::barrier_for`] to wait for durability. 0 in ungrouped
    /// mode (where `barrier_for` runs its own sync regardless).
    pub ticket: u64,
    /// When an I/O worker started the batch's first device write: the
    /// `queue_wait` → device-write boundary for stage attribution.
    pub started: Instant,
    /// Transient-fault retries the worker absorbed before this batch
    /// landed (0 on the common path).
    pub retries: u32,
    /// Wall time (µs) the worker spent on the batch when it retried —
    /// all attempts plus backoff sleeps; 0 when `retries == 0`. Feeds
    /// the `fault_retry` stage so retry time is attributable.
    pub retry_us: u64,
}

struct TokenState {
    result: Option<io::Result<Completion>>,
    done: bool,
}

type TokenCell = Arc<(Mutex<TokenState>, Condvar)>;

fn finish_token(cell: &TokenCell, result: io::Result<Completion>) {
    let (lock, cv) = &**cell;
    let mut st = lock.lock().unwrap();
    st.result = Some(result);
    st.done = true;
    cv.notify_all();
}

/// Handle to one in-flight batch. [`CompletionToken::wait`] parks until
/// an I/O worker delivers the batch's completion (or failure). Dropping
/// an unwaited token **blocks** until the batch completes — that is what
/// makes [`IoReq::borrowed`]'s contract hold even if the submitter
/// panics between enqueue and wait.
pub struct CompletionToken {
    cell: TokenCell,
}

impl CompletionToken {
    /// Park until the batch completed; returns its covering ticket and
    /// start timestamp, or the device error that failed it.
    pub fn wait(self) -> io::Result<Completion> {
        let (lock, cv) = &*self.cell;
        let mut st = lock.lock().unwrap();
        loop {
            if st.done {
                return st.result.take().expect("completion delivered exactly once");
            }
            st = cv.wait(st).unwrap();
        }
    }
}

impl Drop for CompletionToken {
    fn drop(&mut self) {
        let (lock, cv) = &*self.cell;
        let mut st = lock.lock().unwrap();
        while !st.done {
            st = cv.wait(st).unwrap();
        }
    }
}

struct Batch {
    reqs: Vec<IoReq>,
    token: TokenCell,
}

struct QueueState {
    queue: VecDeque<Batch>,
    /// requests admitted (queued or being driven), for depth backpressure
    outstanding: usize,
    shutdown: bool,
}

struct QueueShared {
    dev: Arc<GroupSync>,
    state: Mutex<QueueState>,
    /// work available (workers wait here)
    work: Condvar,
    /// depth slot freed (submitters wait here)
    space: Condvar,
    depth: usize,
    /// transient faults are retried with this backoff before a batch is
    /// allowed to fail — below the completion token, so a retried batch
    /// completes and tickets exactly like a clean one
    retry: RetryPolicy,
    // ---- achieved-depth statistics (relaxed counters) ----
    reqs: AtomicU64,
    batches: AtomicU64,
    /// device writes actually issued (post-coalescing)
    device_writes: AtomicU64,
    /// max outstanding requests ever observed at an enqueue
    depth_high_water: AtomicU64,
    /// sum of outstanding depth sampled at each enqueue (mean = /batches)
    depth_sum: AtomicU64,
    /// batch re-attempts taken after transient faults
    retries: AtomicU64,
    /// transient device faults observed (retried or not)
    transient_faults: AtomicU64,
}

/// Achieved-depth counters of one [`IoQueue`].
#[derive(Clone, Copy, Debug, Default)]
pub struct IoQueueStats {
    /// requests enqueued
    pub reqs: u64,
    /// batches enqueued (one completion token each)
    pub batches: u64,
    /// device writes issued — `reqs - device_writes` is the number of
    /// writes saved by adjacent-request coalescing
    pub device_writes: u64,
    /// highest in-flight request count observed at an enqueue
    pub depth_high_water: u64,
    /// sum of the in-flight depth sampled at each enqueue
    pub depth_sum: u64,
    /// batch re-attempts taken after transient faults
    pub retries: u64,
    /// transient device faults observed (each retried attempt that
    /// failed transiently counts once)
    pub transient_faults: u64,
}

impl IoQueueStats {
    /// Mean in-flight request depth observed at enqueue time.
    pub fn mean_depth(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.depth_sum as f64 / self.batches as f64
        }
    }

    pub fn merge(&mut self, other: &IoQueueStats) {
        self.reqs += other.reqs;
        self.batches += other.batches;
        self.device_writes += other.device_writes;
        self.depth_high_water = self.depth_high_water.max(other.depth_high_water);
        self.depth_sum += other.depth_sum;
        self.retries += other.retries;
        self.transient_faults += other.transient_faults;
    }
}

/// Per-device submission/completion queue: producers enqueue batches of
/// [`IoReq`]s and park on tokens; `workers` pool threads pop batches,
/// coalesce byte-adjacent requests into single vectored device writes
/// (`pwritev`-style), and advance the device's [`GroupSync`] watermark
/// completion-side ([`GroupSync::note_write`]) so the returned ticket
/// covers the batch exactly. `depth` bounds admitted-but-incomplete
/// requests (backpressure); a batch larger than the whole budget is
/// still admitted alone, or it could never run.
///
/// Dropping the queue shuts it down: never-started batches fail with an
/// error (parked submitters unblock — loudly, not silently), in-flight
/// ones finish, and the workers are joined.
pub struct IoQueue {
    shared: Arc<QueueShared>,
    workers: Vec<JoinHandle<()>>,
}

impl IoQueue {
    pub fn new(dev: Arc<GroupSync>, workers: usize, depth: usize, label: &str) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(QueueShared {
            dev,
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                outstanding: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            depth: depth.max(1),
            retry: RetryPolicy::io_default(),
            reqs: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            device_writes: AtomicU64::new(0),
            depth_high_water: AtomicU64::new(0),
            depth_sum: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            transient_faults: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ssdup-io-{label}-{i}"))
                    .spawn(move || Self::worker_loop(&shared))
                    .expect("spawn io worker thread")
            })
            .collect();
        Self { shared, workers: handles }
    }

    /// Enqueue one batch; every request in it completes (and tickets)
    /// together. Blocks while the queue is at depth. The returned token
    /// must be waited on (its `Drop` waits) — see [`IoReq::borrowed`].
    pub fn submit(&self, reqs: Vec<IoReq>) -> CompletionToken {
        assert!(!reqs.is_empty(), "empty batch");
        let sh = &*self.shared;
        let n = reqs.len();
        let cell: TokenCell =
            Arc::new((Mutex::new(TokenState { result: None, done: false }), Condvar::new()));
        let token = CompletionToken { cell: Arc::clone(&cell) };
        let mut st = sh.state.lock().unwrap();
        while !st.shutdown && st.outstanding > 0 && st.outstanding + n > sh.depth {
            st = sh.space.wait(st).unwrap();
        }
        if st.shutdown {
            drop(st);
            finish_token(&cell, Err(IoFault::Shutdown.error("io queue shut down")));
            return token;
        }
        st.outstanding += n;
        let depth_now = st.outstanding as u64;
        st.queue.push_back(Batch { reqs, token: cell });
        drop(st);
        // Relaxed: queue stats counters (reqs/batches/depth gauges) —
        // sampled by `stats()` after the fact, synchronize nothing
        sh.reqs.fetch_add(n as u64, Ordering::Relaxed);
        sh.batches.fetch_add(1, Ordering::Relaxed);
        sh.depth_high_water.fetch_max(depth_now, Ordering::Relaxed);
        sh.depth_sum.fetch_add(depth_now, Ordering::Relaxed);
        sh.work.notify_one();
        token
    }

    pub fn stats(&self) -> IoQueueStats {
        let sh = &*self.shared;
        // Relaxed throughout: point-in-time stats snapshot; the counters
        // are independent and slight skew between them is acceptable
        IoQueueStats {
            reqs: sh.reqs.load(Ordering::Relaxed),
            batches: sh.batches.load(Ordering::Relaxed),
            device_writes: sh.device_writes.load(Ordering::Relaxed),
            depth_high_water: sh.depth_high_water.load(Ordering::Relaxed),
            depth_sum: sh.depth_sum.load(Ordering::Relaxed),
            retries: sh.retries.load(Ordering::Relaxed),
            transient_faults: sh.transient_faults.load(Ordering::Relaxed),
        }
    }

    fn worker_loop(sh: &QueueShared) {
        loop {
            let batch = {
                let mut st = sh.state.lock().unwrap();
                loop {
                    if let Some(b) = st.queue.pop_front() {
                        break b;
                    }
                    if st.shutdown {
                        return;
                    }
                    st = sh.work.wait(st).unwrap();
                }
            };
            let n = batch.reqs.len() as u64;
            // book the batch before its device writes so a group-commit
            // leader's batching window sees queued traffic, then advance
            // the watermark completion-side: the returned ticket covers
            // exactly this batch. Transient faults are retried *inside*
            // the begin/note pair: a retried batch still completes and
            // tickets exactly once, so barrier coverage stays exact
            // (positional writes are idempotent — re-running a batch is
            // safe).
            sh.dev.begin_write(n);
            let started = Instant::now();
            let (result, retries) = retry_transient(&sh.retry, || Self::run_batch(sh, &batch.reqs));
            let retry_us = if retries > 0 { started.elapsed().as_micros() as u64 } else { 0 };
            let mut faults = retries as u64;
            if let Err(e) = &result {
                if IoFault::classify(e).is_transient() {
                    faults += 1;
                }
            }
            if retries > 0 {
                // Relaxed: fault-accounting counters, read by stats()
                sh.retries.fetch_add(retries as u64, Ordering::Relaxed);
            }
            if faults > 0 {
                // Relaxed: fault-accounting counter (as above)
                sh.transient_faults.fetch_add(faults, Ordering::Relaxed);
            }
            let ticket = sh.dev.note_write(n);
            finish_token(
                &batch.token,
                result.map(|()| Completion { ticket, started, retries, retry_us }),
            );
            let mut st = sh.state.lock().unwrap();
            st.outstanding -= batch.reqs.len();
            drop(st);
            sh.space.notify_all();
        }
    }

    /// Issue a batch's device writes, coalescing byte-adjacent requests
    /// into single vectored transfers.
    fn run_batch(sh: &QueueShared, reqs: &[IoReq]) -> io::Result<()> {
        let mut i = 0;
        while i < reqs.len() {
            let mut end = reqs[i].offset + reqs[i].len as u64;
            let mut j = i + 1;
            while j < reqs.len() && reqs[j].offset == end {
                end += reqs[j].len as u64;
                j += 1;
            }
            let bufs: Vec<&[u8]> = reqs[i..j].iter().map(|r| r.as_slice()).collect();
            // Relaxed: coalescing-effectiveness counter, read by stats()
            sh.device_writes.fetch_add(1, Ordering::Relaxed);
            sh.dev.write_vectored_raw(reqs[i].offset, &bufs)?;
            i = j;
        }
        Ok(())
    }

    fn shutdown_now(&self) {
        let sh = &*self.shared;
        let pending: Vec<Batch> = {
            let mut st = sh.state.lock().unwrap();
            st.shutdown = true;
            let pending: Vec<Batch> = st.queue.drain(..).collect();
            for b in &pending {
                st.outstanding -= b.reqs.len();
            }
            pending
        };
        sh.work.notify_all();
        sh.space.notify_all();
        for b in pending {
            finish_token(&b.token, Err(IoFault::Shutdown.error("io queue shut down")));
        }
    }
}

impl Drop for IoQueue {
    fn drop(&mut self) {
        self.shutdown_now();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(b: &dyn Backend) {
        b.write_at(10, b"hello").unwrap();
        b.write_at(1_000_000, b"world").unwrap();
        let mut buf = [0u8; 5];
        b.read_at(10, &mut buf).unwrap();
        assert_eq!(&buf, b"hello");
        b.read_at(1_000_000, &mut buf).unwrap();
        assert_eq!(&buf, b"world");
        // holes (and reads past every write) are zero
        b.read_at(500, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 5]);
        b.read_at(2_000_000, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 5]);
        assert_eq!(b.bytes_written(), 10);
        b.sync().unwrap();
    }

    #[test]
    fn mem_backend_round_trips() {
        round_trip(&MemBackend::new(SyntheticLatency::ZERO));
    }

    #[test]
    fn mem_backend_snapshot_mode_round_trips() {
        round_trip(&MemBackend::over(MemStore::new(true), SyntheticLatency::ZERO));
    }

    #[test]
    fn file_backend_round_trips() {
        let dir = std::env::temp_dir().join(format!("ssdup-be-{}", std::process::id()));
        let b = FileBackend::create(&dir.join("t.img")).unwrap();
        round_trip(&b);
        drop(b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_backend_open_existing_sees_previous_data_and_rejects_missing() {
        let dir = std::env::temp_dir().join(format!("ssdup-beo-{}", std::process::id()));
        let path = dir.join("img");
        {
            let b = FileBackend::create(&path).unwrap();
            b.write_at(100, b"persist").unwrap();
            b.sync().unwrap();
        }
        let b = FileBackend::open_existing(&path).unwrap();
        let mut buf = [0u8; 7];
        b.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf, b"persist", "reopen must not truncate");
        assert!(FileBackend::open_existing(&dir.join("absent")).is_err());
        drop(b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mem_backend_is_sparse() {
        let b = MemBackend::new(SyntheticLatency::ZERO);
        b.write_at(0, &[1u8; 512]).unwrap();
        b.write_at(1 << 40, &[2u8; 512]).unwrap(); // 1 TiB away
        assert!(b.resident_bytes() <= 4 * PAGE_BYTES as u64, "sparse writes stay cheap");
    }

    #[test]
    fn mem_write_spanning_pages() {
        let b = MemBackend::new(SyntheticLatency::ZERO);
        let data: Vec<u8> = (0..(PAGE_BYTES + 100)).map(|i| (i % 251) as u8).collect();
        let start = PAGE_BYTES as u64 - 50;
        b.write_at(start, &data).unwrap();
        let mut back = vec![0u8; data.len()];
        b.read_at(start, &mut back).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn snapshot_store_loses_unsynced_writes_and_keeps_synced_ones() {
        let store = MemStore::new(true);
        let b = MemBackend::over(Arc::clone(&store), SyntheticLatency::ZERO);
        b.write_at(0, b"durable-after-sync").unwrap();
        b.sync().unwrap();
        b.write_at(100, b"volatile").unwrap(); // never synced
        // the live view reads both
        let mut buf = [0u8; 8];
        b.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf, b"volatile");
        // the frozen (power-loss) image only has the synced write
        let frozen = MemBackend::over(store.freeze(), SyntheticLatency::ZERO);
        let mut got = [0u8; 18];
        frozen.read_at(0, &mut got).unwrap();
        assert_eq!(&got, b"durable-after-sync");
        frozen.read_at(100, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 8], "unsynced write must not survive the freeze");
        // partial-page overwrite before sync keeps the old synced bytes
        // around it (copy-on-write overlay)
        b.write_at(2, b"XX").unwrap();
        let mut mixed = [0u8; 7];
        b.read_at(0, &mut mixed).unwrap();
        assert_eq!(&mixed, b"duXXble");
    }

    #[test]
    fn direct_mode_freeze_is_a_plain_copy() {
        // non-volatile store: every write is durable immediately (process
        // kill semantics — the page cache survives), so freeze sees all
        let store = MemStore::new(false);
        let b = MemBackend::over(Arc::clone(&store), SyntheticLatency::ZERO);
        b.write_at(0, b"kept").unwrap();
        let frozen = MemBackend::over(store.freeze(), SyntheticLatency::ZERO);
        let mut buf = [0u8; 4];
        frozen.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"kept");
        // and the copy is independent of later writes
        b.write_at(0, b"gone").unwrap();
        frozen.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"kept");
    }

    /// The point of the `&self` API: disjoint transfers from many threads
    /// through one shared backend, no `&mut` anywhere.
    fn concurrent_disjoint_writes(b: &(dyn Backend + '_)) {
        const THREADS: usize = 8;
        const SPAN: usize = 3 * PAGE_BYTES + 1234; // straddle page boundaries
        std::thread::scope(|s| {
            for t in 0..THREADS {
                s.spawn(move || {
                    let data: Vec<u8> = (0..SPAN).map(|i| ((i + t * 31) % 251) as u8).collect();
                    b.write_at((t * SPAN) as u64, &data).unwrap();
                });
            }
        });
        let mut back = vec![0u8; SPAN];
        for t in 0..THREADS {
            b.read_at((t * SPAN) as u64, &mut back).unwrap();
            assert!(
                back.iter().enumerate().all(|(i, &v)| v == ((i + t * 31) % 251) as u8),
                "thread {t}'s extent round-trips"
            );
        }
        assert_eq!(b.bytes_written(), (THREADS * SPAN) as u64);
    }

    #[test]
    fn mem_backend_concurrent_disjoint_writes() {
        concurrent_disjoint_writes(&MemBackend::new(SyntheticLatency::ZERO));
    }

    #[test]
    fn snapshot_mode_concurrent_disjoint_writes() {
        concurrent_disjoint_writes(&MemBackend::over(MemStore::new(true), SyntheticLatency::ZERO));
    }

    #[test]
    fn file_backend_concurrent_disjoint_writes() {
        let dir = std::env::temp_dir().join(format!("ssdup-bec-{}", std::process::id()));
        let b = FileBackend::create(&dir.join("c.img")).unwrap();
        concurrent_disjoint_writes(&b);
        drop(b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthetic_latency_knee_math() {
        let lat = SyntheticLatency { per_op_us: 100, us_per_mib: 0, max_inflight: 4 };
        // below the knee: depth-independent (lanes overlap fully)
        assert_eq!(lat.service_us(0, 1), 100);
        assert_eq!(lat.service_us(0, 4), 100);
        // above it: grows linearly with the excess, so aggregate
        // throughput (depth / service) pins at the knee value
        assert_eq!(lat.service_us(0, 8), 200);
        assert_eq!(lat.service_us(0, 16), 400);
        // unlimited lanes = the pre-knee behavior
        let flat = SyntheticLatency { per_op_us: 100, us_per_mib: 0, max_inflight: 0 };
        assert_eq!(flat.service_us(0, 1000), 100);
        // the bandwidth term scales the same way
        let bw = SyntheticLatency { per_op_us: 0, us_per_mib: 1024, max_inflight: 2 };
        assert_eq!(bw.service_us(1 << 20, 1), 1024);
        assert_eq!(bw.service_us(1 << 20, 4), 2048);
    }

    #[test]
    fn vectored_write_round_trips_on_every_backend() {
        let check = |b: &dyn Backend| {
            b.write_vectored_at(100, &[b"abc", b"defg", b"h"]).unwrap();
            let mut buf = [0u8; 8];
            b.read_at(100, &mut buf).unwrap();
            assert_eq!(&buf, b"abcdefgh");
            assert_eq!(b.bytes_written(), 8);
            b.write_vectored_at(0, &[]).unwrap(); // empty gather is a no-op
            assert_eq!(b.bytes_written(), 8);
        };
        check(&MemBackend::new(SyntheticLatency::ZERO));
        let dir = std::env::temp_dir().join(format!("ssdup-bev-{}", std::process::id()));
        let fb = FileBackend::create(&dir.join("v.img")).unwrap();
        check(&fb);
        drop(fb);
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- IoQueue ----

    fn queue_over_mem(
        latency: SyntheticLatency,
        workers: usize,
        depth: usize,
    ) -> (Arc<MemStore>, Arc<GroupSync>, IoQueue) {
        let store = MemStore::new(false);
        let dev = Arc::new(GroupSync::new(
            Box::new(MemBackend::over(Arc::clone(&store), latency)),
            true,
            Duration::ZERO,
        ));
        let q = IoQueue::new(Arc::clone(&dev), workers, depth, "test");
        (store, dev, q)
    }

    #[test]
    fn io_queue_completes_batches_and_tickets_cover_them() {
        let (store, dev, q) = queue_over_mem(SyntheticLatency::ZERO, 2, 8);
        let tokens: Vec<CompletionToken> = (0..16u64)
            .map(|i| {
                q.submit(vec![IoReq::owned(i * 8, vec![i as u8; 8].into_boxed_slice())])
            })
            .collect();
        for (i, t) in tokens.into_iter().enumerate() {
            let c = t.wait().unwrap();
            dev.barrier_for(c.ticket).unwrap();
            let mut buf = [0u8; 8];
            store.read(i as u64 * 8, &mut buf);
            assert_eq!(buf, [i as u8; 8], "request {i} landed before its barrier");
        }
        let st = q.stats();
        assert_eq!(st.reqs, 16);
        assert_eq!(st.batches, 16);
        assert!(st.depth_high_water >= 1 && st.depth_high_water <= 8);
    }

    #[test]
    fn io_queue_coalesces_adjacent_requests_into_one_device_write() {
        let (store, _dev, q) = queue_over_mem(SyntheticLatency::ZERO, 1, 8);
        // header+payload style batch: byte-adjacent, must become ONE
        // device write; the third request is disjoint, its own write
        let batch = vec![
            IoReq::owned(0, vec![1u8; 512].into_boxed_slice()),
            IoReq::owned(512, vec![2u8; 1024].into_boxed_slice()),
            IoReq::owned(10_000, vec![3u8; 256].into_boxed_slice()),
        ];
        q.submit(batch).wait().unwrap();
        assert_eq!(q.stats().reqs, 3);
        assert_eq!(q.stats().device_writes, 2, "adjacent pair coalesced, disjoint not");
        let mut buf = vec![0u8; 1536];
        store.read(0, &mut buf);
        assert!(buf[..512].iter().all(|&b| b == 1) && buf[512..].iter().all(|&b| b == 2));
    }

    #[test]
    fn io_queue_borrowed_requests_round_trip() {
        let (store, _dev, q) = queue_over_mem(SyntheticLatency::ZERO, 1, 4);
        let payload = vec![7u8; 4096];
        // SAFETY: `payload` outlives the wait below
        let token = q.submit(vec![unsafe { IoReq::borrowed(64, &payload) }]);
        token.wait().unwrap();
        let mut buf = vec![0u8; 4096];
        store.read(64, &mut buf);
        assert_eq!(buf, payload);
    }

    #[test]
    fn io_queue_depth_backpressure_caps_outstanding_requests() {
        // one slow worker, depth 2: submitters must block instead of
        // queueing unboundedly
        let (_store, _dev, q) = queue_over_mem(
            SyntheticLatency { per_op_us: 2_000, us_per_mib: 0, max_inflight: 0 },
            1,
            2,
        );
        let tokens: Vec<CompletionToken> = (0..6u64)
            .map(|i| q.submit(vec![IoReq::owned(i * 64, vec![0u8; 64].into_boxed_slice())]))
            .collect();
        for t in tokens {
            t.wait().unwrap();
        }
        let st = q.stats();
        assert_eq!(st.reqs, 6);
        assert!(
            st.depth_high_water <= 2,
            "depth cap violated: high water {}",
            st.depth_high_water
        );
    }

    #[test]
    fn io_queue_shutdown_fails_never_started_batches() {
        let (_store, _dev, q) = queue_over_mem(
            SyntheticLatency { per_op_us: 50_000, us_per_mib: 0, max_inflight: 0 },
            1,
            64,
        );
        // batch 1 occupies the lone worker for ~50ms; batches 2..4 wait
        // in the submission queue and must fail loudly on shutdown, not
        // hang their submitters
        let first = q.submit(vec![IoReq::owned(0, vec![0u8; 8].into_boxed_slice())]);
        std::thread::sleep(Duration::from_millis(5)); // worker picked batch 1
        let queued: Vec<CompletionToken> = (1..4u64)
            .map(|i| q.submit(vec![IoReq::owned(i * 8, vec![0u8; 8].into_boxed_slice())]))
            .collect();
        drop(q); // shutdown: fail pending, finish in-flight, join
        assert!(first.wait().is_ok(), "the in-flight batch finishes normally");
        for t in queued {
            let e = t.wait().expect_err("a never-started batch must fail, not vanish");
            assert_eq!(
                IoFault::classify(&e),
                IoFault::Shutdown,
                "shutdown rejection is typed, not a stringly device error"
            );
        }
    }

    #[test]
    fn io_queue_retries_transient_faults_below_the_completion_token() {
        use crate::live::fault::FaultSpec;
        // eio burst of 2 on writes only (offset-scoped so the barrier's
        // sync stays clean): the worker must absorb both faults and
        // deliver a normal completion with 2 retries booked
        let store = MemStore::new(false);
        let spec = FaultSpec::parse("ssd:eio:transient=2:max_off=1000000000").unwrap();
        let inner = Box::new(MemBackend::over(Arc::clone(&store), SyntheticLatency::ZERO));
        let dev = Arc::new(GroupSync::new(spec.wrap_ssd(inner, 11), true, Duration::ZERO));
        let q = IoQueue::new(Arc::clone(&dev), 1, 8, "faulty");
        let token = q.submit(vec![IoReq::owned(0, vec![9u8; 64].into_boxed_slice())]);
        let comp = token.wait().unwrap();
        dev.barrier_for(comp.ticket).unwrap();
        assert_eq!(comp.retries, 2, "exactly the burst length absorbed");
        let st = q.stats();
        assert_eq!(st.retries, 2);
        assert_eq!(st.transient_faults, 2);
        let mut buf = [0u8; 64];
        store.read(0, &mut buf);
        assert_eq!(buf, [9u8; 64], "the write landed despite the storm");
    }

    #[test]
    fn io_queue_surfaces_permanent_faults_without_retrying() {
        use crate::live::fault::FaultSpec;
        let store = MemStore::new(false);
        let spec = FaultSpec::parse("ssd:dead@op=0").unwrap();
        let inner = Box::new(MemBackend::over(Arc::clone(&store), SyntheticLatency::ZERO));
        let dev = Arc::new(GroupSync::new(spec.wrap_ssd(inner, 5), true, Duration::ZERO));
        let q = IoQueue::new(dev, 1, 8, "dead");
        let e = q
            .submit(vec![IoReq::owned(0, vec![1u8; 8].into_boxed_slice())])
            .wait()
            .expect_err("a dead device must fail the batch");
        assert_eq!(IoFault::classify(&e), IoFault::Permanent);
        assert_eq!(q.stats().retries, 0, "permanent faults are not retried");
    }

    #[test]
    fn io_queue_many_clients_few_workers_all_writes_land() {
        // clients ≫ workers: 12 submitters over 2 workers, disjoint
        // extents, everything must land and ticket
        let (store, dev, q) = queue_over_mem(SyntheticLatency::ZERO, 2, 16);
        const CLIENTS: usize = 12;
        const EACH: usize = 20;
        std::thread::scope(|s| {
            for c in 0..CLIENTS {
                let (q, dev) = (&q, &dev);
                s.spawn(move || {
                    for i in 0..EACH {
                        let off = (c * EACH + i) as u64 * 32;
                        let data = vec![(c * EACH + i) as u8; 32].into_boxed_slice();
                        let comp = q.submit(vec![IoReq::owned(off, data)]).wait().unwrap();
                        dev.barrier_for(comp.ticket).unwrap();
                    }
                });
            }
        });
        let mut buf = [0u8; 32];
        for k in 0..CLIENTS * EACH {
            store.read(k as u64 * 32, &mut buf);
            assert_eq!(buf, [k as u8; 32], "write {k} lost");
        }
        assert_eq!(q.stats().reqs, (CLIENTS * EACH) as u64);
    }
}
