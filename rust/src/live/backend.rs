//! Pluggable storage backends for the live engine.
//!
//! A [`Backend`] is a flat byte-addressable store — the live analogue of
//! the simulator's device models. Two implementations ship:
//!
//! * [`MemBackend`] — a chunked sparse in-memory store with configurable
//!   synthetic latency, so unit tests run instantly and benches can model
//!   SSD/HDD speed ratios without real disks;
//! * [`FileBackend`] — a real `std::fs` file (sparse where the OS allows),
//!   used by `ssdup live --backend file`. The SSD log path only ever
//!   appends within a region, so the file backend sees the same
//!   sequential-write pattern a real burst buffer produces.
//!
//! Writes at arbitrary offsets are allowed (HDD images are sparse); holes
//! read as zero on both implementations.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// A flat byte store. `Send` so shards can own one on a worker thread.
pub trait Backend: Send {
    /// Write `data` at absolute byte `offset` (sparse writes allowed).
    fn write_at(&mut self, offset: u64, data: &[u8]) -> io::Result<()>;

    /// Fill `buf` from `offset`; unwritten holes read as zero.
    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> io::Result<()>;

    /// Total bytes written over the backend's lifetime.
    fn bytes_written(&self) -> u64;

    /// Flush to durable storage (no-op for memory).
    fn sync(&mut self) -> io::Result<()>;

    fn kind(&self) -> &'static str;
}

/// Synthetic service time applied per [`MemBackend`] operation: a fixed
/// per-op cost plus a bandwidth term. Mirrors the cost structure of the
/// simulator's device models closely enough for shard-scaling benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyntheticLatency {
    pub per_op_us: u64,
    pub us_per_mib: u64,
}

impl SyntheticLatency {
    /// No artificial delay (unit tests).
    pub const ZERO: SyntheticLatency = SyntheticLatency { per_op_us: 0, us_per_mib: 0 };

    /// SATA-SSD-like: ~380 MB/s sequential, small per-op cost.
    pub fn ssd() -> Self {
        Self { per_op_us: 60, us_per_mib: 2_600 }
    }

    /// HDD-like: ~110 MB/s sequential plus a per-op positioning cost.
    pub fn hdd() -> Self {
        Self { per_op_us: 400, us_per_mib: 9_000 }
    }

    fn apply(&self, bytes: usize) {
        let us = self.per_op_us + ((bytes as u64 * self.us_per_mib) >> 20);
        if us > 0 {
            std::thread::sleep(Duration::from_micros(us));
        }
    }
}

/// Page granularity of the sparse in-memory store.
const PAGE_BYTES: usize = 64 * 1024;

/// Chunked sparse in-memory backend: only touched 64 KiB pages are
/// allocated, so a TiB-scale sparse HDD image costs memory proportional to
/// the data actually written.
pub struct MemBackend {
    pages: HashMap<u64, Box<[u8]>>,
    latency: SyntheticLatency,
    bytes_written: u64,
}

impl MemBackend {
    pub fn new(latency: SyntheticLatency) -> Self {
        Self { pages: HashMap::new(), latency, bytes_written: 0 }
    }

    /// Resident (allocated) bytes — test visibility into sparseness.
    pub fn resident_bytes(&self) -> u64 {
        self.pages.len() as u64 * PAGE_BYTES as u64
    }

    fn page_mut(&mut self, idx: u64) -> &mut [u8] {
        self.pages.entry(idx).or_insert_with(|| vec![0u8; PAGE_BYTES].into_boxed_slice())
    }
}

impl Backend for MemBackend {
    fn write_at(&mut self, offset: u64, data: &[u8]) -> io::Result<()> {
        self.latency.apply(data.len());
        let mut off = offset;
        let mut rest = data;
        while !rest.is_empty() {
            let page = off / PAGE_BYTES as u64;
            let within = (off % PAGE_BYTES as u64) as usize;
            let take = rest.len().min(PAGE_BYTES - within);
            self.page_mut(page)[within..within + take].copy_from_slice(&rest[..take]);
            off += take as u64;
            rest = &rest[take..];
        }
        self.bytes_written += data.len() as u64;
        Ok(())
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        self.latency.apply(buf.len());
        let mut off = offset;
        let mut rest: &mut [u8] = buf;
        while !rest.is_empty() {
            let page = off / PAGE_BYTES as u64;
            let within = (off % PAGE_BYTES as u64) as usize;
            let take = rest.len().min(PAGE_BYTES - within);
            match self.pages.get(&page) {
                Some(p) => rest[..take].copy_from_slice(&p[within..within + take]),
                None => rest[..take].fill(0),
            }
            off += take as u64;
            rest = &mut rest[take..];
        }
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    fn sync(&mut self) -> io::Result<()> {
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "mem"
    }
}

/// Real-file backend. The file is created (truncated) on open; offsets
/// past EOF read as zero, matching sparse-file semantics.
pub struct FileBackend {
    file: File,
    path: PathBuf,
    bytes_written: u64,
}

impl FileBackend {
    pub fn create(path: &Path) -> io::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(path)?;
        Ok(Self { file, path: path.to_path_buf(), bytes_written: 0 })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Backend for FileBackend {
    fn write_at(&mut self, offset: u64, data: &[u8]) -> io::Result<()> {
        self.file.seek(SeekFrom::Start(offset))?;
        self.file.write_all(data)?;
        self.bytes_written += data.len() as u64;
        Ok(())
    }

    fn read_at(&mut self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        self.file.seek(SeekFrom::Start(offset))?;
        // read to EOF, then zero-fill the hole past it
        let mut filled = 0;
        while filled < buf.len() {
            match self.file.read(&mut buf[filled..])? {
                0 => break,
                n => filled += n,
            }
        }
        buf[filled..].fill(0);
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    fn sync(&mut self) -> io::Result<()> {
        self.file.sync_data()
    }

    fn kind(&self) -> &'static str {
        "file"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(b: &mut dyn Backend) {
        b.write_at(10, b"hello").unwrap();
        b.write_at(1_000_000, b"world").unwrap();
        let mut buf = [0u8; 5];
        b.read_at(10, &mut buf).unwrap();
        assert_eq!(&buf, b"hello");
        b.read_at(1_000_000, &mut buf).unwrap();
        assert_eq!(&buf, b"world");
        // holes (and reads past every write) are zero
        b.read_at(500, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 5]);
        b.read_at(2_000_000, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 5]);
        assert_eq!(b.bytes_written(), 10);
        b.sync().unwrap();
    }

    #[test]
    fn mem_backend_round_trips() {
        round_trip(&mut MemBackend::new(SyntheticLatency::ZERO));
    }

    #[test]
    fn file_backend_round_trips() {
        let dir = std::env::temp_dir().join(format!("ssdup-be-{}", std::process::id()));
        let mut b = FileBackend::create(&dir.join("t.img")).unwrap();
        round_trip(&mut b);
        drop(b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mem_backend_is_sparse() {
        let mut b = MemBackend::new(SyntheticLatency::ZERO);
        b.write_at(0, &[1u8; 512]).unwrap();
        b.write_at(1 << 40, &[2u8; 512]).unwrap(); // 1 TiB away
        assert!(b.resident_bytes() <= 4 * PAGE_BYTES as u64, "sparse writes stay cheap");
    }

    #[test]
    fn mem_write_spanning_pages() {
        let mut b = MemBackend::new(SyntheticLatency::ZERO);
        let data: Vec<u8> = (0..(PAGE_BYTES + 100)).map(|i| (i % 251) as u8).collect();
        let start = PAGE_BYTES as u64 - 50;
        b.write_at(start, &data).unwrap();
        let mut back = vec![0u8; data.len()];
        b.read_at(start, &mut back).unwrap();
        assert_eq!(back, data);
    }
}
