//! One live burst-buffer shard: the real-time analogue of the simulator's
//! per-I/O-node server.
//!
//! A shard owns a detector + routing policy + two-region pipeline plus an
//! SSD/HDD backend pair, and splits work across two lock domains:
//!
//! * the **core** mutex guards all coordination state (pipeline metadata,
//!   stream grouper, policy, file table, stats). Ingest holds it while
//!   routing, appending to the SSD log, and feeding the detector — a
//!   shard's ingest is serial by design (the scaling unit is the shard);
//! * the **device** mutexes (`ssd`, `hdd`) guard the backends alone, so
//!   the background flusher moves region bytes SSD→HDD *without* the core
//!   lock — buffering and flushing overlap, which is the whole point of
//!   the paper's two-region pipeline (§2.4).
//!
//! Lock order is always core → device; the flusher takes devices only.
//! Backpressure is physical: a write that finds both regions unavailable
//! blocks its client on a condvar until the flusher frees a region —
//! the paper's "the system waits until a region becomes empty".
//!
//! **Overwrite safety.** Every ingest claims its sector range in the
//! shard's [`OwnershipMap`] (under the core lock, after the SSD bytes
//! landed), so the newest copy of every sector is always locatable. A
//! direct-to-HDD write that would overlap a live buffered extent is
//! absorbed into the SSD log instead — a direct write racing the flusher
//! for the same sectors is the one ordering the locks cannot arbitrate.
//! The flusher copies exactly the map's surviving extents for its
//! region — superseded ranges are absent from the map — so a stale
//! buffered copy can never clobber newer data on the HDD, and skipped
//! sectors cost no HDD bandwidth. Reads resolve through the same map and
//! are served from the newest copy — SSD log or HDD — even mid-burst.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::buffer::{BufferOutcome, FlushStrategy, Pipeline};
use crate::detector::native::NativeDetector;
use crate::detector::stream::StreamGrouper;
use crate::device::SeekModel;
use crate::fs::{FileTable, SubRequest};
use crate::live::backend::Backend;
use crate::live::ownership::{OwnershipMap, Tier};
use crate::redirector::{AdaptivePolicy, AlwaysHdd, AlwaysSsd, RoutePolicy, WatermarkPolicy};
use crate::server::config::SystemKind;
use crate::types::{sectors_to_bytes, Route, SECTOR_BYTES};

/// Per-shard configuration (the engine derives one from its `LiveConfig`).
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    pub system: SystemKind,
    /// whole-SSD budget in sectors; each pipeline region gets half
    pub ssd_capacity_sectors: i64,
    pub stream_len: usize,
    pub pause_below: f32,
    pub history: usize,
    /// re-check interval for paused flushes and condvar waits
    pub flush_check: Duration,
    pub seek: SeekModel,
}

/// Counters a shard accumulates; snapshot via [`Shard::stats`].
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub bytes_in: u64,
    pub ssd_bytes_buffered: u64,
    pub hdd_direct_bytes: u64,
    pub flushed_bytes: u64,
    /// bytes whose buffered copy was superseded by a newer write before
    /// the flusher reached it (skipped at flush time). Conservation:
    /// after a full drain, `ssd_bytes_buffered == flushed_bytes +
    /// superseded_bytes`.
    pub superseded_bytes: u64,
    /// direct-route writes absorbed into the SSD log because they
    /// overlapped live buffered data (cross-route rewrite safety)
    pub rerouted_writes: u64,
    pub streams: u64,
    pub flushes: u64,
    pub flush_pauses: u64,
    pub flush_pause_us: u64,
    pub blocked_waits: u64,
    pub pct_sum: f64,
}

impl ShardStats {
    /// Mean random percentage over this shard's completed streams.
    pub fn mean_percentage(&self) -> f64 {
        if self.streams == 0 {
            0.0
        } else {
            self.pct_sum / self.streams as f64
        }
    }
}

/// Fraction of ingested bytes that went through the SSD buffer, over a
/// set of shard stats (shared by the engine and the load-gen report).
pub fn ssd_ratio(stats: &[ShardStats]) -> f64 {
    let total: u64 = stats.iter().map(|s| s.bytes_in).sum();
    let ssd: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    if total == 0 {
        0.0
    } else {
        ssd as f64 / total as f64
    }
}

/// Everything guarded by the core mutex.
struct ShardCore {
    files: FileTable,
    grouper: StreamGrouper,
    detector: NativeDetector,
    policy: Box<dyn RoutePolicy + Send>,
    route: Route,
    pipeline: Pipeline,
    /// sector-ownership extent map: where the newest copy of every
    /// buffered sector lives (see the module docs on overwrite safety)
    own: OwnershipMap,
    drained: bool,
    shutdown: bool,
    /// set by the flusher on a backend I/O error, with the cause; waiters
    /// surface it instead of polling a pipeline that can never drain
    failed: Option<String>,
    stats: ShardStats,
}

pub struct Shard {
    core: Mutex<ShardCore>,
    ssd: Mutex<Box<dyn Backend>>,
    hdd: Mutex<Box<dyn Backend>>,
    /// signalled when the flusher frees a region (blocked ingest, drain)
    space: Condvar,
    /// signalled when flush work appears or the pause gate may open
    work: Condvar,
    /// direct-to-HDD writes currently in flight (traffic-aware gate input)
    direct_inflight: AtomicU64,
    strategy: FlushStrategy,
    half_sectors: i64,
    use_ssd: bool,
    flush_check: Duration,
}

fn policy_for(system: SystemKind, history: usize) -> Box<dyn RoutePolicy + Send> {
    match system {
        SystemKind::OrangeFs => Box::new(AlwaysHdd),
        SystemKind::OrangeFsBB => Box::new(AlwaysSsd),
        SystemKind::Ssdup => Box::<WatermarkPolicy>::default(),
        SystemKind::SsdupPlus => Box::new(AdaptivePolicy::new(history)),
    }
}

impl Shard {
    pub fn new(cfg: &ShardConfig, ssd: Box<dyn Backend>, hdd: Box<dyn Backend>) -> Self {
        let policy = policy_for(cfg.system, cfg.history);
        let route = policy.initial_route();
        let strategy = match cfg.system {
            SystemKind::SsdupPlus => FlushStrategy::TrafficAware { pause_below: cfg.pause_below },
            _ => FlushStrategy::Immediate,
        };
        Shard {
            core: Mutex::new(ShardCore {
                files: FileTable::new(),
                grouper: StreamGrouper::new(cfg.stream_len),
                detector: NativeDetector::new(cfg.seek),
                policy,
                route,
                pipeline: Pipeline::new(cfg.ssd_capacity_sectors),
                own: OwnershipMap::new(),
                drained: false,
                shutdown: false,
                failed: None,
                stats: ShardStats::default(),
            }),
            ssd: Mutex::new(ssd),
            hdd: Mutex::new(hdd),
            space: Condvar::new(),
            work: Condvar::new(),
            direct_inflight: AtomicU64::new(0),
            strategy,
            half_sectors: cfg.ssd_capacity_sectors / 2,
            use_ssd: cfg.system.uses_ssd(),
            flush_check: cfg.flush_check,
        }
    }

    /// Ingest one sub-request with its payload. Blocks (physical
    /// backpressure) while both pipeline regions are unavailable.
    ///
    /// Overwrites are fully supported, across routes: the newest copy of
    /// every sector is tracked in the ownership map, stale buffered
    /// copies are superseded, and a direct write over live buffered data
    /// is absorbed into the SSD log (see the module docs).
    pub fn submit(&self, sub: &SubRequest, payload: &[u8]) {
        let size = sub.size as i64;
        debug_assert_eq!(payload.len() as u64, sub.bytes());
        let mut direct_dest: Option<u64> = None;
        {
            let mut core = self.core.lock().unwrap();
            // the engine is one burst per instance: the flusher exits for
            // good once a drain completes, so a later submit could buffer
            // bytes that no one would ever flush — fail loudly instead
            assert!(!core.drained, "submit after drain: the live engine is one burst per engine");
            let lba = core.files.lba(sub.parent.file, sub.local_offset);
            debug_assert!(lba <= i32::MAX as i64, "LBA exceeds detector i32 space");
            core.stats.bytes_in += payload.len() as u64;
            // a sub-request larger than a region could never buffer:
            // route it directly to HDD (safety valve)
            let mut route = if !self.use_ssd || size > self.half_sectors {
                Route::Hdd
            } else {
                core.route
            };
            // overwrite safety: a direct write overlapping a live
            // buffered extent would race the flusher for the same HDD
            // sectors. Absorb it into the SSD log instead — the claim
            // below supersedes the stale copy and the flush order across
            // regions keeps last-write-wins on the HDD.
            if route == Route::Hdd && self.use_ssd && core.own.overlaps_ssd(lba, size) {
                if size <= self.half_sectors {
                    route = Route::Ssd;
                    core.stats.rerouted_writes += 1;
                } else {
                    // valve-sized write over buffered data cannot be
                    // absorbed: force the overlap out through the flusher
                    // and only then go direct
                    while core.own.overlaps_ssd(lba, size) {
                        core.stats.blocked_waits += 1;
                        // only the active region needs forcing — overlaps
                        // held by a pending/flushing region drain anyway
                        let active = core.pipeline.active_region();
                        if core.own.overlaps_ssd_region(lba, size, active) {
                            core.pipeline.enqueue_residual_flush();
                        }
                        self.work.notify_all();
                        core = self.space.wait_timeout(core, self.flush_check).unwrap().0;
                        if let Some(msg) = core.failed.clone() {
                            drop(core); // release before panicking: no poisoning
                            panic!("shard failed while blocked on a region: {msg}");
                        }
                        if core.shutdown {
                            drop(core);
                            panic!(
                                "shard shut down with a blocked write still pending \
                                 ({} bytes undelivered)",
                                payload.len()
                            );
                        }
                    }
                }
            }
            match route {
                Route::Hdd => {
                    debug_assert!(!core.own.overlaps_ssd(lba, size), "direct write over live buffer");
                    core.stats.hdd_direct_bytes += payload.len() as u64;
                    // counted under the core lock so the flusher's gate
                    // sees the direct traffic the moment it is decided
                    self.direct_inflight.fetch_add(1, Ordering::SeqCst);
                    direct_dest = Some(lba as u64 * SECTOR_BYTES);
                }
                Route::Ssd => loop {
                    let (region, ssd_offset, filled) =
                        match core.pipeline.buffer(sub.parent.file, sub.local_offset as i64, size) {
                            BufferOutcome::Buffered { region, ssd_offset } => {
                                (region, ssd_offset, false)
                            }
                            BufferOutcome::BufferedAndFull { region, ssd_offset, .. } => {
                                (region, ssd_offset, true)
                            }
                            BufferOutcome::Blocked => {
                                // "the system waits until a region becomes
                                // empty" — closed-loop backpressure
                                core.stats.blocked_waits += 1;
                                self.work.notify_all();
                                core = self.space.wait_timeout(core, self.flush_check).unwrap().0;
                                if let Some(msg) = core.failed.clone() {
                                    drop(core); // release before panicking: no poisoning
                                    panic!("shard failed while blocked on a region: {msg}");
                                }
                                if core.shutdown {
                                    // the caller was never acknowledged:
                                    // vanishing silently here would turn a
                                    // shutdown into data loss the client
                                    // believes was written
                                    drop(core);
                                    panic!(
                                        "shard shut down with a blocked write still pending \
                                         ({} bytes undelivered)",
                                        payload.len()
                                    );
                                }
                                continue;
                            }
                        };
                    if let Err(e) = self.write_ssd(region, ssd_offset, payload) {
                        self.fail_and_panic(core, format!("ssd backend write: {e}"));
                    }
                    // claim under the same core-lock hold as the append:
                    // the flusher and readers resolve against a map that
                    // never lags the log
                    let stale = core.own.claim(lba, size, Tier::Ssd { region, ssd_offset });
                    core.stats.superseded_bytes += sectors_to_bytes(stale);
                    core.stats.ssd_bytes_buffered += payload.len() as u64;
                    if filled {
                        self.work.notify_all(); // a region is ready to flush
                    }
                    break;
                },
            }
            // server-side detection feeds on the post-striping disk address
            if let Some(stream) = core.grouper.push_parts(sub.parent.app, lba as i32, sub.size) {
                let det = core.detector.detect(&stream.reqs);
                core.stats.streams += 1;
                core.stats.pct_sum += det.percentage as f64;
                core.route = core.policy.on_stream(&det);
                // a route change can unpause the traffic-aware flusher
                self.work.notify_all();
            }
        }
        if let Some(dest) = direct_dest {
            let wrote = self.hdd.lock().unwrap().write_at(dest, payload);
            if self.direct_inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
                // direct traffic ebbed: the traffic-aware gate may open
                self.work.notify_all();
            }
            if let Err(e) = wrote {
                // no lock is held here, so the panic poisons nothing
                self.fail(format!("hdd backend write: {e}"));
                panic!("shard hdd write failed: {e}");
            }
        }
    }

    /// Append `payload` into the SSD log at the pipeline-assigned slot.
    /// Called with the core lock held (core → device order), which is what
    /// guarantees the flusher's `drain_flushing` only ever sees regions
    /// whose bytes are fully on the backend.
    fn write_ssd(&self, region: usize, ssd_offset: i64, payload: &[u8]) -> std::io::Result<()> {
        let base = region as u64 * self.half_sectors as u64 * SECTOR_BYTES;
        let mut ssd = self.ssd.lock().unwrap();
        ssd.write_at(base + ssd_offset as u64 * SECTOR_BYTES, payload)
    }

    /// Record a failure, release the core lock, wake all waiters, and
    /// panic in the calling thread — without poisoning any mutex.
    fn fail_and_panic(&self, mut core: std::sync::MutexGuard<'_, ShardCore>, msg: String) -> ! {
        core.failed.get_or_insert(msg.clone());
        drop(core);
        self.space.notify_all();
        self.work.notify_all();
        panic!("shard failed: {msg}");
    }

    /// Read back `buf.len()` bytes the shard's HDD holds for
    /// `(file, local_offset)` — verification path. Unlike [`Shard::read`]
    /// this deliberately ignores buffered copies; only meaningful after a
    /// drain.
    pub fn read_hdd(&self, file: u32, local_offset: i32, buf: &mut [u8]) {
        let lba = self.core.lock().unwrap().files.lba(file, local_offset);
        let read = self.hdd.lock().unwrap().read_at(lba as u64 * SECTOR_BYTES, buf);
        // result is inspected after the guard dropped: no poisoning
        read.expect("hdd backend read");
    }

    /// Read `buf.len()` bytes for `(file, local_offset)` from wherever
    /// the newest copy lives — SSD log or HDD — resolved per segment
    /// through the ownership map. Works mid-burst, before any drain.
    ///
    /// The core lock is held across the device reads: a region flush
    /// completing concurrently would otherwise recycle the very SSD slots
    /// being read (the flusher needs the core lock to complete, so it
    /// cannot). Reads therefore serialize against ingest; the live read
    /// path favors correctness over read concurrency for now.
    pub fn read(&self, file: u32, local_offset: i32, buf: &mut [u8]) {
        let sector = SECTOR_BYTES as usize;
        debug_assert_eq!(buf.len() % sector, 0, "reads are sector-aligned");
        let sectors = (buf.len() / sector) as i64;
        if sectors == 0 {
            return;
        }
        let mut core = self.core.lock().unwrap();
        let lba = core.files.lba(file, local_offset);
        for (seg_lba, seg_size, tier) in core.own.resolve(lba, sectors) {
            let dst = (seg_lba - lba) as usize * sector;
            let len = seg_size as usize * sector;
            let slice = &mut buf[dst..dst + len];
            let read = match tier {
                Tier::Hdd => self.hdd.lock().unwrap().read_at(seg_lba as u64 * SECTOR_BYTES, slice),
                Tier::Ssd { region, ssd_offset } => {
                    let base = region as u64 * self.half_sectors as u64 * SECTOR_BYTES;
                    self.ssd.lock().unwrap().read_at(base + ssd_offset as u64 * SECTOR_BYTES, slice)
                }
            };
            if let Err(e) = read {
                drop(core); // release before panicking: no poisoning
                panic!("shard read failed: {e}");
            }
        }
    }

    pub fn stats(&self) -> ShardStats {
        self.core.lock().unwrap().stats.clone()
    }

    /// Background flusher: runs on its own thread until shutdown, or until
    /// the shard is drained clean.
    pub(crate) fn flusher_loop(&self) {
        // reused bounded copy buffer: one allocation for the thread's life
        let mut chunk = vec![0u8; 1 << 20];
        loop {
            // ---- acquire the next region to flush (or exit) ----
            let (region, resolved): (usize, Vec<(u64, u64, usize)>) = {
                let mut core = self.core.lock().unwrap();
                let region = loop {
                    if core.shutdown || core.failed.is_some() {
                        return;
                    }
                    if core.drained
                        && core.pipeline.flushing_region().is_none()
                        && core.pipeline.flush_pending.is_empty()
                    {
                        core.pipeline.enqueue_residual_flush();
                    }
                    if let Some(r) = core.pipeline.next_flush() {
                        break r;
                    }
                    if core.drained && !core.pipeline.dirty() {
                        self.space.notify_all();
                        return;
                    }
                    core = self.work.wait_timeout(core, self.flush_check).unwrap().0;
                };
                let region_base = region as u64 * self.half_sectors as u64 * SECTOR_BYTES;
                // reset the region's append metadata; what actually gets
                // copied comes from the ownership map: its extents for
                // this region are exactly the *newest* copies living in
                // the log, ascending by LBA (sequential HDD order) and
                // already clipped of every superseded range — stale-flush
                // suppression by construction
                core.pipeline.reset_flushing();
                core.stats.flushes += 1;
                let resolved: Vec<(u64, u64, usize)> = core
                    .own
                    .region_extents(region)
                    .into_iter()
                    .map(|(lba, size, slot)| {
                        (
                            region_base + slot as u64 * SECTOR_BYTES,
                            lba as u64 * SECTOR_BYTES,
                            (size as u64 * SECTOR_BYTES) as usize,
                        )
                    })
                    .collect();
                (region, resolved)
            };

            // ---- gate + copy, without the core lock ----
            for (ssd_byte, hdd_byte, len) in resolved {
                if !self.gate_extent() {
                    return; // shutdown while paused
                }
                let mut done = 0usize;
                while done < len {
                    let take = chunk.len().min(len - done);
                    let read =
                        self.ssd.lock().unwrap().read_at(ssd_byte + done as u64, &mut chunk[..take]);
                    if let Err(e) = read {
                        self.fail(format!("flusher: ssd backend read: {e}"));
                        return;
                    }
                    let write =
                        self.hdd.lock().unwrap().write_at(hdd_byte + done as u64, &chunk[..take]);
                    if let Err(e) = write {
                        self.fail(format!("flusher: hdd backend write: {e}"));
                        return;
                    }
                    done += take;
                }
            }

            // ---- complete: free the region, settle its surviving
            // extents (their newest copy is the HDD one now), wake
            // blocked ingest ----
            {
                let mut core = self.core.lock().unwrap();
                core.pipeline.flush_done();
                // account flushed bytes from the map at completion, not
                // from what the copy loop moved: an extent superseded
                // *mid-copy* was already booked into superseded_bytes by
                // its claim, so counting the (now stale) copy too would
                // double-book it — `buffered == flushed + superseded`
                // must stay exact
                let settled = core.own.release_region(region);
                core.stats.flushed_bytes += sectors_to_bytes(settled);
            }
            self.space.notify_all();
        }
    }

    /// Traffic-aware pause gate, re-evaluated per flush extent like the
    /// DES flusher. Returns false only on shutdown or shard failure.
    fn gate_extent(&self) -> bool {
        let mut core = self.core.lock().unwrap();
        let mut paused_at: Option<Instant> = None;
        loop {
            if core.shutdown || core.failed.is_some() {
                return false;
            }
            let pct = core.policy.current_percentage().unwrap_or(1.0);
            let direct = self.direct_inflight.load(Ordering::SeqCst) > 0;
            if self.strategy.allow_flush(pct, direct, core.drained) {
                break;
            }
            if paused_at.is_none() {
                paused_at = Some(Instant::now());
                core.stats.flush_pauses += 1;
            }
            core = self.work.wait_timeout(core, self.flush_check).unwrap().0;
        }
        if let Some(t0) = paused_at {
            core.stats.flush_pause_us += t0.elapsed().as_micros() as u64;
        }
        true
    }

    /// All producers have finished: flush any partial detection stream and
    /// queue the residual region.
    pub(crate) fn begin_drain(&self) {
        {
            let mut core = self.core.lock().unwrap();
            core.drained = true;
            if let Some(stream) = core.grouper.flush_partial() {
                let det = core.detector.detect(&stream.reqs);
                core.stats.streams += 1;
                core.stats.pct_sum += det.percentage as f64;
                core.route = core.policy.on_stream(&det);
            }
            core.pipeline.enqueue_residual_flush();
        }
        self.work.notify_all();
    }

    /// Record a fatal flusher error and wake every waiter so it surfaces
    /// in a caller thread instead of hanging the engine.
    fn fail(&self, msg: String) {
        self.core.lock().unwrap().failed.get_or_insert(msg);
        self.space.notify_all();
        self.work.notify_all();
    }

    /// Block until every buffered byte has reached the HDD backend.
    /// Panics (in the caller's thread) if the flusher hit a backend I/O
    /// error — buffered data can then never drain.
    pub(crate) fn wait_drained(&self) {
        let mut core = self.core.lock().unwrap();
        while core.pipeline.dirty() {
            if let Some(msg) = core.failed.clone() {
                drop(core); // release before panicking: no poisoning
                panic!("shard failed before drain completed: {msg}");
            }
            core = self.space.wait_timeout(core, self.flush_check).unwrap().0;
        }
    }

    /// Flush both backends to durable storage.
    pub(crate) fn sync(&self) {
        let ssd = self.ssd.lock().unwrap().sync();
        ssd.expect("ssd sync");
        let hdd = self.hdd.lock().unwrap().sync();
        hdd.expect("hdd sync");
    }

    pub(crate) fn request_shutdown(&self) {
        self.core.lock().unwrap().shutdown = true;
        self.work.notify_all();
        self.space.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::live::backend::{MemBackend, SyntheticLatency};
    use crate::live::payload;
    use crate::types::Request;

    fn cfg(system: SystemKind, capacity_sectors: i64) -> ShardConfig {
        ShardConfig {
            system,
            ssd_capacity_sectors: capacity_sectors,
            stream_len: 1024, // no detection flips mid-test
            pause_below: 0.45,
            history: 64,
            flush_check: Duration::from_millis(1),
            seek: SeekModel::default(),
        }
    }

    fn mem_shard(system: SystemKind, capacity_sectors: i64) -> Shard {
        Shard::new(
            &cfg(system, capacity_sectors),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        )
    }

    fn sub(file: u32, offset: i32, size: i32) -> SubRequest {
        SubRequest {
            node: 0,
            local_offset: offset,
            size,
            parent: Request { app: 0, proc_id: 0, file, offset, size },
        }
    }

    fn gen_payload(file: u32, offset: i32, size: i32, gen: u64) -> Vec<u8> {
        let mut buf = vec![0u8; (size as u64 * SECTOR_BYTES) as usize];
        payload::fill_gen(file, offset as i64, gen, &mut buf);
        buf
    }

    #[test]
    fn shutdown_while_blocked_panics_instead_of_dropping_bytes() {
        // no flusher thread: both regions fill and stay unavailable
        let shard = Arc::new(mem_shard(SystemKind::OrangeFsBB, 256));
        shard.submit(&sub(1, 0, 128), &gen_payload(1, 0, 128, 1)); // fills region 0
        shard.submit(&sub(1, 128, 128), &gen_payload(1, 128, 128, 1)); // fills region 1
        let worker = Arc::clone(&shard);
        let handle = std::thread::spawn(move || {
            // both regions full, nobody flushing: blocks, then shutdown
            // arrives — silently returning here would be data loss the
            // caller was never told about
            worker.submit(&sub(1, 256, 128), &gen_payload(1, 256, 128, 1));
        });
        std::thread::sleep(Duration::from_millis(20));
        shard.request_shutdown();
        assert!(
            handle.join().is_err(),
            "a write dropped by shutdown must panic, not vanish"
        );
    }

    #[test]
    fn rewrite_of_buffered_sector_serves_and_flushes_the_newest_copy() {
        let shard = mem_shard(SystemKind::OrangeFsBB, 4096);
        let s = SECTOR_BYTES as usize;
        // first version buffers in the SSD log
        shard.submit(&sub(1, 0, 64), &gen_payload(1, 0, 64, 1));
        // mid-burst read returns it (SSD hit)
        let mut got = vec![0u8; 64 * s];
        shard.read(1, 0, &mut got);
        assert_eq!(got, gen_payload(1, 0, 64, 1));
        // overwrite part of it: the newest copy wins immediately
        shard.submit(&sub(1, 16, 32), &gen_payload(1, 16, 32, 2));
        shard.read(1, 0, &mut got);
        assert_eq!(got[..16 * s], gen_payload(1, 0, 64, 1)[..16 * s]);
        assert_eq!(got[16 * s..48 * s], gen_payload(1, 16, 32, 2)[..]);
        assert_eq!(got[48 * s..], gen_payload(1, 0, 64, 1)[48 * s..]);
        // drain synchronously (no flusher thread: run one loop pass by
        // hand via begin_drain + flusher_loop, which exits once clean)
        shard.begin_drain();
        shard.flusher_loop();
        let stats = shard.stats();
        assert_eq!(stats.superseded_bytes, 32 * SECTOR_BYTES, "stale copy skipped");
        assert_eq!(
            stats.flushed_bytes + stats.superseded_bytes,
            stats.ssd_bytes_buffered,
            "conservation: buffered == flushed + superseded"
        );
        // post-drain the HDD holds the merged newest content
        let mut hdd = vec![0u8; 64 * s];
        shard.read_hdd(1, 0, &mut hdd);
        assert_eq!(hdd, got, "HDD must match the newest-copy view");
        // and the ownership map is empty: reads now come from HDD
        let mut again = vec![0u8; 64 * s];
        shard.read(1, 0, &mut again);
        assert_eq!(again, got);
    }

    #[test]
    fn direct_write_over_buffered_extent_is_absorbed_into_the_log() {
        // the dangerous cross-route direction: data buffered in the SSD
        // log, route flips to HDD, and the same sectors are rewritten.
        // The rewrite must be absorbed into the log, not written direct —
        // otherwise the later flush would resurrect the stale copy.
        let mut c = cfg(SystemKind::SsdupPlus, 4096);
        c.stream_len = 4; // one detection window per 4 sub-requests
        let shard = Shard::new(
            &c,
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        );
        // window 1: sparse offsets -> random (pct 1.0) -> route SSD next
        for off in [0, 10_000, 50_000, 90_000] {
            shard.submit(&sub(1, off, 16), &gen_payload(1, off, 16, 1));
        }
        // window 2: buffered in the log (route is SSD); contiguous run ->
        // pct 0.0 -> route flips back to HDD afterwards
        for k in 0..4 {
            let off = 200_000 + k * 16;
            shard.submit(&sub(1, off, 16), &gen_payload(1, off, 16, 1));
        }
        let mid = shard.stats();
        assert_eq!(mid.ssd_bytes_buffered, 4 * 16 * SECTOR_BYTES, "window 2 buffered");
        assert_eq!(mid.rerouted_writes, 0);
        // route is HDD now; rewrite a buffered extent -> must be absorbed
        shard.submit(&sub(1, 200_016, 16), &gen_payload(1, 200_016, 16, 2));
        let after = shard.stats();
        assert_eq!(after.rerouted_writes, 1, "cross-route rewrite absorbed into the log");
        assert_eq!(after.superseded_bytes, 16 * SECTOR_BYTES, "stale buffered copy superseded");
        assert_eq!(after.hdd_direct_bytes, mid.hdd_direct_bytes, "no direct write raced the flusher");
        // the newest copy is served mid-burst…
        let s = SECTOR_BYTES as usize;
        let mut got = vec![0u8; 16 * s];
        shard.read(1, 200_016, &mut got);
        assert_eq!(got, gen_payload(1, 200_016, 16, 2));
        // …and survives the drain byte-exactly
        shard.begin_drain();
        shard.flusher_loop();
        let mut hdd = vec![0u8; 16 * s];
        shard.read_hdd(1, 200_016, &mut hdd);
        assert_eq!(hdd, gen_payload(1, 200_016, 16, 2), "flusher must not resurrect the stale copy");
        let end = shard.stats();
        assert_eq!(
            end.flushed_bytes + end.superseded_bytes,
            end.ssd_bytes_buffered,
            "conservation: buffered == flushed + superseded"
        );
    }
}
