//! One live burst-buffer shard: the real-time analogue of the simulator's
//! per-I/O-node server.
//!
//! A shard owns a detector + routing policy + two-region pipeline plus an
//! SSD/HDD backend pair. Since the backends expose concurrent positional
//! I/O (`&self` — see [`crate::live::backend`]), there is exactly **one**
//! lock: the **core** mutex, and it guards *coordination only* — pipeline
//! metadata, stream grouper, policy, file table, ownership map, stats.
//! **No thread ever holds it across device I/O.** Every hot path splits
//! into short critical sections around an unlocked device transfer:
//!
//! * **Ingest (reserve → enqueue → completion-publish).** Under the core
//!   lock a write routes, reserves its pipeline slot, and claims its
//!   sector range in the ownership map as *pending*; the lock drops; the
//!   record's bytes are **enqueued** on the shard's per-device
//!   [`IoQueue`](crate::live::backend::IoQueue) and the client parks on
//!   a completion token while a small worker pool drives the device; a
//!   brief re-acquire publishes the claim. Queue depth is therefore
//!   decoupled from client-thread count — per-shard ingest bandwidth
//!   scales with in-flight *requests*, not blocked OS threads (the
//!   paper's buffering/flushing overlap, §2.4, extended to the ingest
//!   path itself).
//! * **Reads (resolve → pin → read).** [`Shard::read`] resolves the range
//!   under the lock, takes a per-region *pin*, releases the lock, reads
//!   the devices, and unpins. A flush completion waits for a region's
//!   pins to drain before recycling its log slots, so a reader never sees
//!   a slot reused under it — and readers never serialize against ingest.
//! * **Flush.** The flusher snapshots its region's surviving extents
//!   under the lock (after waiting for the region's pending claims to
//!   publish — a queued region accepts no new appends, so that state is
//!   final), then copies SSD→HDD with no lock held, in coalesced runs
//!   (see `copy_runs`).
//!
//! Backpressure is physical: a write that finds both regions unavailable
//! blocks its client on a condvar until the flusher frees a region —
//! the paper's "the system waits until a region becomes empty".
//!
//! **Overwrite safety.** Every ingest claims its sector range in the
//! shard's [`OwnershipMap`] in the same critical section that reserves
//! its slot, so the newest copy of every sector is always locatable and
//! claims are totally ordered by the core lock. A direct-to-HDD write
//! that would overlap a live buffered extent is absorbed into the SSD log
//! instead, and any claim overlapping an *in-flight* direct write waits
//! for it to land first — the two cases where an unordered device write
//! could otherwise resurrect stale bytes on the HDD. The flusher copies
//! exactly the map's surviving extents for its region (superseded ranges
//! are absent — stale-flush suppression by construction), and reads
//! resolve through the same map, waiting out claims whose device bytes
//! are still in flight (a pending claim has no readable copy anywhere).
//!
//! **Crash consistency.** Every buffered extent is persisted as a framed
//! record (`live::record`): one self-describing header sector — magic,
//! shard, region, LBA, length, a monotone sequence assigned in the claim
//! critical section, and a CRC-32C over header + payload — followed by
//! the payload. The publish step waits on a **group-commit barrier**
//! ([`crate::live::commit::GroupSync`]) before the claim is
//! acknowledged: a device sync that started after the record's bytes
//! landed has completed — usually one sync shared by every publisher in
//! flight, instead of one fsync per record — so **acknowledged means
//! durable**; recovery can only lose writes that never returned to
//! their client. A per-shard
//! superblock (two alternating slots past the region logs) persists the
//! flush watermarks — rewritten, synced, *before* a flushed region's map
//! entries are released and its slots recycled — plus the file table
//! (rewritten on first touch of a new file, the one place the shard
//! holds its core lock across device I/O, because the extent mapping
//! must be durable before any byte of the file can be acknowledged) and
//! the clean-shutdown flag. [`Shard::recover`] reverses all of this:
//! clean superblocks short-circuit, dirty ones trigger a checksum-
//! validated scan of both region logs, and surviving records replay in
//! sequence order to rebuild the ownership map and pipeline state.
//!
//! **Fault handling.** Transient device errors are absorbed *below* the
//! acknowledgement: the queue workers, the group-commit syncs, and every
//! read path retry with bounded exponential backoff
//! ([`crate::live::fault::RetryPolicy`]) before an error surfaces. A
//! write the SSD still refuses flips the shard into sticky **degraded
//! mode** (recorded in the superblock): the failed claim is aborted and
//! re-routed, and every new write goes direct to the HDD while the data
//! already buffered keeps draining through the flusher. What remains —
//! HDD backstop failures, shutdown racing a blocked write — surfaces as
//! typed [`SubmitError`]/[`ReadError`] values, never panics. One
//! visibility caveat: a reader racing the *unacknowledged* HDD retry of
//! a degrading write can transiently observe the range's older HDD
//! copy; once the retry lands (and always after the submit returns),
//! reads are exact.

use std::fmt;
use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::buffer::{BufferOutcome, FlushStrategy, Pipeline};
use crate::detector::native::NativeDetector;
use crate::detector::stream::StreamGrouper;
use crate::device::SeekModel;
use crate::fs::{FileTable, SubRequest};
use crate::live::backend::{Backend, IoQueue, IoReq};
use crate::live::commit::GroupSync;
use crate::live::fault::{retry_transient, RetryPolicy};
use crate::live::flushsched::{FlushCoordinator, FlushToken};
use crate::live::ownership::{OwnershipMap, Tier};
use crate::live::record::{
    scan_region, LiveRecord, RecordHeader, Superblock, HEADER_SECTORS, MAX_SB_FILES,
};
use crate::obs::{Stage, StageSet, TraceCollector, DEFAULT_RING_EVENTS};
use crate::redirector::{AdaptivePolicy, AlwaysHdd, AlwaysSsd, RoutePolicy, WatermarkPolicy};
use crate::server::config::SystemKind;
use crate::types::{sectors_to_bytes, Detection, Route, SECTOR_BYTES};

/// Number of pipeline regions (fixed by the two-region design, §2.4).
const REGIONS: usize = 2;

/// Flusher copy-buffer size: also the upper bound of one coalesced copy
/// run, and thus the granularity of traffic-gate re-checks.
const CHUNK_BYTES: usize = 1 << 20;

/// Ingest-bias margin: a shard counts as *array-hot* when its SSD-log
/// occupancy exceeds the array mean by this much (on top of the
/// absolute floor in [`crate::live::flushsched`]). New detection
/// streams assigned to a hot shard's SSD start direct-to-HDD instead,
/// so the fullest log stops attracting more load while it drains.
const HOT_BIAS_MARGIN: f32 = 0.15;

/// Deferral pressure valve: a flusher holding back a hot region stops
/// deferring the moment the shard's live occupancy reaches this
/// fraction — buffer space is about to run out, and reclaiming the
/// region outranks concentrating supersession.
const DEFER_OCCUPANCY_CEILING: f32 = 0.75;

/// Per-shard configuration (the engine derives one from its `LiveConfig`).
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    pub system: SystemKind,
    /// stable shard identity, stamped into every record frame and the
    /// superblock — recovery refuses logs that belong to another shard
    pub shard_id: u32,
    /// whole-SSD budget in sectors; each pipeline region gets half
    pub ssd_capacity_sectors: i64,
    pub stream_len: usize,
    pub pause_below: f32,
    pub history: usize,
    /// re-check interval for paused flushes and condvar waits
    pub flush_check: Duration,
    pub seek: SeekModel,
    /// group commit: coalesce concurrent publishers' durability barriers
    /// into shared device syncs (`false` = one fsync per record, the
    /// ungrouped baseline)
    pub group_commit: bool,
    /// how long an elected group-commit leader waits for in-flight
    /// writes to land before syncing (zero = natural batching only)
    pub group_commit_window: Duration,
    /// I/O worker threads per device queue (N ≪ clients): the pool that
    /// drives queued device writes, decoupling queue depth from
    /// client-thread count
    pub io_workers: usize,
    /// submission-queue depth per device: max admitted-but-incomplete
    /// requests before `submit` exerts backpressure
    pub io_depth: usize,
    /// hot/cold deferral bound: how long the flusher may hold back a
    /// queued region whose surviving extents are predominantly *hot*
    /// (recently rewritten), so further rewrites supersede in the
    /// buffer instead of costing HDD copies. `Duration::ZERO` disables
    /// deferral entirely.
    pub hot_defer_window: Duration,
}

/// What [`Shard::recover`] found and rebuilt — per shard.
#[derive(Clone, Debug, Default)]
pub struct ShardRecovery {
    /// superblock said the last shutdown drained cleanly: the log scan
    /// was skipped entirely
    pub clean: bool,
    /// surviving records replayed into the ownership map
    pub records_replayed: u64,
    /// valid records skipped because their region's flush watermark says
    /// they are already settled on the HDD
    pub records_skipped: u64,
    /// torn/invalid log stretches discarded (one count per stretch)
    pub torn_discarded: u64,
    /// valid-looking records discarded because their LBA belongs to no
    /// file in the recovered table (only an unacknowledged write can be
    /// orphaned: a file's table entry is durable before its first ack)
    pub orphaned: u64,
    /// payload bytes put back under ownership (they re-enter the stats
    /// as buffered bytes and drain through the normal flush path)
    pub bytes_recovered: u64,
    /// log sectors walked by the scan (0 on a clean reopen)
    pub sectors_scanned: i64,
    /// file-table entries restored from the superblock
    pub files_restored: usize,
}

/// Counters a shard accumulates; snapshot via [`Shard::stats`].
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub bytes_in: u64,
    pub ssd_bytes_buffered: u64,
    pub hdd_direct_bytes: u64,
    pub flushed_bytes: u64,
    /// bytes whose buffered copy was superseded by a newer write before
    /// the flusher reached it (skipped at flush time). Conservation:
    /// after a full drain, `ssd_bytes_buffered == flushed_bytes +
    /// superseded_bytes`.
    pub superseded_bytes: u64,
    /// direct-route writes absorbed into the SSD log because they
    /// overlapped live buffered data (cross-route rewrite safety)
    pub rerouted_writes: u64,
    pub streams: u64,
    pub flushes: u64,
    /// coalesced SSD→HDD copy runs issued by the flusher: adjacent
    /// surviving extents merge into one sequential HDD write (and one
    /// gate check), so `flush_runs` ≪ extent count on fragmented maps
    pub flush_runs: u64,
    pub flush_pauses: u64,
    pub flush_pause_us: u64,
    /// time the flusher spent actually copying SSD→HDD (gathering log
    /// segments + the sequential HDD write, per coalesced run) — the
    /// companion of `flush_pause_us`, so the pause/copy duty cycle is
    /// computable ([`ShardStats::flush_duty_cycle`])
    pub flush_run_us: u64,
    /// waits actually taken by blocked ingest (region backpressure or the
    /// valve forcing an overlap out through the flusher) — one count per
    /// wait, never booked when a re-check finds the path already clear
    pub blocked_waits: u64,
    /// device syncs actually issued (SSD + HDD), group-commit leaders and
    /// drain/shutdown syncs included
    pub syncs: u64,
    /// durability barriers requested by publish/flush paths — each one a
    /// would-be fsync without group commit
    pub sync_barriers: u64,
    /// requests enqueued on the shard's submission queues (SSD + HDD)
    pub io_reqs: u64,
    /// device writes actually issued by the queue workers —
    /// `io_reqs - io_device_writes` writes were saved by byte-adjacent
    /// coalescing into vectored transfers
    pub io_device_writes: u64,
    /// highest in-flight request depth observed at an enqueue — the
    /// achieved queue depth (≫ io_workers when clients pile up)
    pub io_depth_high_water: u64,
    /// mean in-flight request depth sampled at enqueue time
    pub io_mean_depth: f64,
    /// device-level retries absorbed below the ack: queue-worker write
    /// retries, group-commit sync retries, and inline read retries
    pub io_retries: u64,
    /// transient device faults observed — every retried fault plus any
    /// transient error that survived its retry budget
    pub transient_faults: u64,
    /// sticky degraded mode: the SSD refused a write (or filled up) and
    /// every new write now routes direct to the HDD
    pub degraded: bool,
    /// bytes the flusher took up for flushing, snapshotted when it
    /// claimed their region — the denominator of
    /// [`ShardStats::superseded_at_flush`]
    pub queued_for_flush_bytes: u64,
    /// bytes superseded *while queued for flush*: between the flusher
    /// taking up a region and its copy-run snapshot (the hot-defer
    /// window sits in between), newer writes landed over queued
    /// extents. This is supersession that deferral concentrated in the
    /// buffer — HDD copies that never had to happen.
    pub superseded_at_flush_bytes: u64,
    /// flush cycles the hot/cold deferral actually held back (at least
    /// one deferral wait taken before the copy runs started)
    pub hot_defers: u64,
    /// flush-coordinator token acquisitions (one per flush cycle when
    /// the shard runs coordinated; 0 when uncoordinated)
    pub flush_token_waits: u64,
    /// wall time spent waiting for HDD-bandwidth tokens from the flush
    /// coordinator (0 when uncontended: grants are immediate)
    pub flush_token_wait_us: u64,
    /// detection streams steered direct-to-HDD by the array-aware
    /// ingest bias because this shard's log stood out as hot
    pub biased_streams: u64,
    pub pct_sum: f64,
}

impl ShardStats {
    /// Mean random percentage over this shard's completed streams.
    pub fn mean_percentage(&self) -> f64 {
        if self.streams == 0 {
            0.0
        } else {
            self.pct_sum / self.streams as f64
        }
    }

    /// Barriers satisfied per device sync — the group-commit batching
    /// factor (≈1 when ungrouped or single-client; >1 when concurrent
    /// publishers share barriers).
    pub fn writes_per_sync(&self) -> f64 {
        if self.syncs == 0 {
            0.0
        } else {
            self.sync_barriers as f64 / self.syncs as f64
        }
    }

    /// Fraction of flusher wall time spent copying (vs paused by the
    /// traffic-aware gate). 0.0 when the flusher never ran at all.
    pub fn flush_duty_cycle(&self) -> f64 {
        let total = self.flush_run_us + self.flush_pause_us;
        if total == 0 {
            0.0
        } else {
            self.flush_run_us as f64 / total as f64
        }
    }

    /// Fraction of queued-for-flush bytes that were superseded while
    /// they waited for the copy runs to start — the hot/cold deferral
    /// payoff. 0.0 before any region was taken up.
    pub fn superseded_at_flush(&self) -> f64 {
        if self.queued_for_flush_bytes == 0 {
            0.0
        } else {
            self.superseded_at_flush_bytes as f64 / self.queued_for_flush_bytes as f64
        }
    }
}

/// Fraction of ingested bytes that went through the SSD buffer, over a
/// set of shard stats (shared by the engine and the load-gen report).
pub fn ssd_ratio(stats: &[ShardStats]) -> f64 {
    let total: u64 = stats.iter().map(|s| s.bytes_in).sum();
    let ssd: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    if total == 0 {
        0.0
    } else {
        ssd as f64 / total as f64
    }
}

/// Why [`Shard::submit`] refused a write. Typed so callers decide what
/// a rejection means — the shard itself never panics on an I/O fault.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// shutdown arrived while the write was still waiting for space or
    /// an overlap to settle — the bytes were **not** delivered
    Shutdown,
    /// the shard failed permanently (the HDD backstop refused a write or
    /// sync even after retries); the first cause is preserved verbatim
    Failed(String),
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Shutdown => write!(f, "shard shut down with the write undelivered"),
            SubmitError::Failed(msg) => write!(f, "shard failed: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why [`Shard::read`] could not serve a range.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ReadError {
    /// shutdown arrived while the read waited on an in-flight claim
    Shutdown,
    /// the shard failed permanently before the range resolved
    Failed(String),
    /// a device read error that survived the inline transient retries
    Device(String),
}

impl fmt::Display for ReadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReadError::Shutdown => write!(f, "shard shut down while the read waited"),
            ReadError::Failed(msg) => write!(f, "shard failed: {msg}"),
            ReadError::Device(msg) => write!(f, "device read failed: {msg}"),
        }
    }
}

impl std::error::Error for ReadError {}

/// Everything guarded by the core mutex.
struct ShardCore {
    files: FileTable,
    grouper: StreamGrouper,
    detector: NativeDetector,
    policy: Box<dyn RoutePolicy + Send>,
    route: Route,
    pipeline: Pipeline,
    /// sector-ownership extent map: where the newest copy of every
    /// buffered sector lives, including claims still in flight (see the
    /// module docs on overwrite safety)
    own: OwnershipMap,
    /// reserved-but-unpublished SSD slots per region. The flusher waits
    /// for its region's count to hit zero before snapshotting: those
    /// slots' device bytes are still being written by client threads.
    pending_slots: [u64; REGIONS],
    /// next record sequence (monotone per shard; 0 is never assigned, so
    /// a zero watermark means "nothing flushed")
    next_seq: u64,
    /// highest sequence reserved into each region's current log
    /// generation — the flush watermark persisted before the region is
    /// recycled (reset to 0 at release)
    region_max_seq: [u64; REGIONS],
    /// in-memory image of the on-SSD superblock (epoch, watermarks,
    /// clean flag, file table); every device rewrite snapshots it here
    /// under the core lock, so a later epoch always carries a superset
    /// of earlier state
    sb: Superblock,
    drained: bool,
    shutdown: bool,
    /// set on a backend I/O error, with the cause; waiters surface it
    /// instead of polling work that can never finish
    failed: Option<String>,
    /// sticky degraded mode: the SSD refused a write, so every new
    /// write routes direct to the HDD (see [`Shard::submit`]); the
    /// flusher keeps draining what was buffered before the failure
    degraded: bool,
    stats: ShardStats,
}

impl ShardCore {
    /// Book one completed detection stream: the counters and the policy
    /// re-route live in one place so the ingest and drain close paths
    /// can never drift apart in their accounting.
    fn account_stream(&mut self, det: &Detection) {
        self.stats.streams += 1;
        self.stats.pct_sum += det.percentage as f64;
        self.route = self.policy.on_stream(det);
    }
}

pub struct Shard {
    core: Mutex<ShardCore>,
    /// concurrent (`&self`) backends: readers and superblock writers
    /// issue positional I/O directly — there is deliberately no device
    /// mutex anywhere in the shard. Each backend sits behind a
    /// [`GroupSync`] sequencer: publish paths call `barrier_for()`
    /// instead of `sync()`, so concurrent publishers share device syncs
    /// (acknowledged = covered by a completed barrier). `Arc` because
    /// the submission queues' workers advance the same sequencers
    /// completion-side.
    ssd: Arc<GroupSync>,
    hdd: Arc<GroupSync>,
    /// per-device submission/completion queues: ingest and the flusher
    /// enqueue their device writes here and park on completion tokens
    /// while `io_workers` pool threads drive the device — queue depth is
    /// decoupled from client-thread count (see the module docs)
    ssd_q: IoQueue,
    hdd_q: IoQueue,
    /// copy runs the flusher groups into one queue batch (byte-adjacent
    /// runs coalesce into single vectored HDD writes)
    flush_window: usize,
    /// signalled when the flusher frees a region (blocked ingest, drain)
    space: Condvar,
    /// signalled when flush work appears, the pause gate may open, or a
    /// reader pin drains
    work: Condvar,
    /// signalled when an in-flight claim publishes (SSD slot published or
    /// direct write landed): wakes readers waiting on a pending range,
    /// writers waiting out an in-flight direct overlap, and a flusher
    /// waiting for its region's reserved slots
    published: Condvar,
    /// readers currently holding resolved slots into each region's log.
    /// Incremented under the core lock at resolve time; decremented
    /// lock-free (`Release`) when the device reads finish, paired with
    /// the flusher's `Acquire` load before it recycles the region.
    read_pins: [AtomicU64; REGIONS],
    /// inline transient-read retries absorbed by [`Shard::read`],
    /// [`Shard::read_hdd`], and the flusher's log reads — folded into
    /// the stats snapshot alongside the queue and sync retry counters
    read_retries: AtomicU64,
    /// direct-to-HDD writes in flight (traffic-aware gate input).
    /// Ordering: increments happen inside the core critical section that
    /// decided the route, decrements after the unlocked device write;
    /// both use `Release`, the gate reads `Acquire`. The gate needs a
    /// conservative snapshot only (it re-polls every `flush_check`), so
    /// the cross-variable total order `SeqCst` would add is not required.
    direct_inflight: AtomicU64,
    strategy: FlushStrategy,
    half_sectors: i64,
    /// largest payload a region can frame: half minus the header sector
    max_buffer_sectors: i64,
    use_ssd: bool,
    flush_check: Duration,
    /// engine-shared flush coordinator: the flusher holds one of its
    /// HDD-bandwidth tokens across a flush cycle's copy runs, and the
    /// ingest path consults its occupancy map to steer new streams off
    /// an array-hot log. `None` = uncoordinated (standalone shards,
    /// `--flush-concurrency 0`).
    coordinator: Option<Arc<FlushCoordinator>>,
    /// hot/cold deferral bound (see [`ShardConfig::hot_defer_window`])
    hot_defer_window: Duration,
    shard_id: u32,
    /// byte offset of the superblock slots (just past both region logs)
    sb_base: u64,
    /// Serializes superblock device writes; holds the highest epoch
    /// already written + synced and the slot to write next. Epoch order
    /// is fixed under the core lock, but writers reach the device in any
    /// order: a writer whose snapshot epoch is not newer than the
    /// recorded one **skips** its write — the durable superblock already
    /// carries a superset of its state (later epochs snapshot `core.sb`
    /// after earlier mutations). The slot alternates per *physical*
    /// write (never by epoch parity — epochs can skip), so consecutive
    /// durable superblocks always sit in different slots and a torn
    /// write can never destroy the newest surviving one. Leaf lock:
    /// never acquired before taking `core` — the first-touch path takes
    /// it *while* holding core, the flusher takes it with no other lock
    /// held.
    sb_lock: Mutex<SbWriter>,
    /// trace collector (shared with the engine's other shards): span
    /// emission is gated on its enabled flag, one atomic load per span
    obs: Arc<TraceCollector>,
    /// per-stage ack-latency attribution histograms. Leaf lock: taken
    /// for one batched fold at a time, never while acquiring any other
    /// shard lock (`core` or `sb_lock` may be held *around* it).
    stage_lat: Mutex<StageSet>,
}

/// Device-write-order state for the superblock (guarded by `sb_lock`).
struct SbWriter {
    /// highest epoch durably written
    last_epoch: u64,
    /// slot the next physical write targets
    next_slot: usize,
}

/// Outcome of the routing/claim critical section of [`Shard::submit`]:
/// which device write this client owes, and the ticket to publish after.
/// `ssd_offset` is the record frame's *header* slot; the payload follows
/// at `ssd_offset + HEADER_SECTORS` (what the ownership map tracks).
enum Claimed<'a> {
    Direct { dest: u64, ticket: u64, gate: DirectGate<'a> },
    Slot { region: usize, ssd_offset: i64, ticket: u64, seq: u64, absorbed: bool },
}

/// RAII restore of `direct_inflight`: taken in the claim critical
/// section right after the increment, dropped once the direct write's
/// outcome is published — **including** the failure path, where the
/// typed `SubmitError` return skips the publish section. Without the
/// guard, a failed HDD write left the counter elevated forever, and the
/// traffic-aware gate (`direct > 0`) never reopened for the other
/// threads of a still-draining engine.
struct DirectGate<'a> {
    shard: &'a Shard,
}

impl Drop for DirectGate<'_> {
    fn drop(&mut self) {
        // Release: pairs with the flusher gate's Acquire load, so a
        // gate that sees zero also sees the completed direct write
        if self.shard.direct_inflight.fetch_sub(1, Ordering::Release) == 1 {
            // direct traffic ebbed: the traffic-aware gate may open
            self.shard.work.notify_all();
        }
    }
}

fn policy_for(system: SystemKind, history: usize) -> Box<dyn RoutePolicy + Send> {
    match system {
        SystemKind::OrangeFs => Box::new(AlwaysHdd),
        SystemKind::OrangeFsBB => Box::new(AlwaysSsd),
        SystemKind::Ssdup => Box::<WatermarkPolicy>::default(),
        SystemKind::SsdupPlus => Box::new(AdaptivePolicy::new(history)),
    }
}

/// One sequential HDD write gathered from one or more SSD log segments.
struct CopyRun {
    hdd_byte: u64,
    len: usize,
    /// `(ssd_byte, len)` source segments, gathered in order
    segs: Vec<(u64, usize)>,
}

/// Coalesce a region's surviving extents (ascending LBA, from
/// `region_extents`) into bounded copy runs: extents adjacent on the HDD
/// merge into **one sequential HDD write** even when their log slots are
/// scattered — random reads from the SSD are cheap (§2.5), sequential
/// writes are what the HDD wants. One traffic-gate check and one HDD
/// write per run instead of per extent; runs are capped at `chunk_cap`
/// so the gate still re-checks at a bounded byte granularity.
fn copy_runs(extents: Vec<(i64, i64, i64)>, region_base: u64, chunk_cap: usize) -> Vec<CopyRun> {
    let mut runs: Vec<CopyRun> = Vec::new();
    for (lba, size, slot) in extents {
        let mut hdd_byte = lba as u64 * SECTOR_BYTES;
        let mut ssd_byte = region_base + slot as u64 * SECTOR_BYTES;
        let mut left = (size as u64 * SECTOR_BYTES) as usize;
        while left > 0 {
            let take = match runs.last_mut() {
                Some(run) if run.hdd_byte + run.len as u64 == hdd_byte && run.len < chunk_cap => {
                    let take = left.min(chunk_cap - run.len);
                    run.segs.push((ssd_byte, take));
                    run.len += take;
                    take
                }
                _ => {
                    let take = left.min(chunk_cap);
                    runs.push(CopyRun { hdd_byte, len: take, segs: vec![(ssd_byte, take)] });
                    take
                }
            };
            hdd_byte += take as u64;
            ssd_byte += take as u64;
            left -= take;
        }
    }
    runs
}

impl Shard {
    /// A fresh shard over empty (or to-be-overwritten) backends. No
    /// superblock is written until the first flush, first new file, or
    /// shutdown — recovery treats "no valid superblock" as a dirty
    /// device with zero watermarks, which scans to exactly what was
    /// framed so far.
    pub fn new(cfg: &ShardConfig, ssd: Box<dyn Backend>, hdd: Box<dyn Backend>) -> Self {
        Self::new_with_obs(cfg, ssd, hdd, Arc::new(TraceCollector::new(DEFAULT_RING_EVENTS)))
    }

    /// [`Shard::new`] with a shared trace collector (the engine passes
    /// one collector to all of its shards).
    pub fn new_with_obs(
        cfg: &ShardConfig,
        ssd: Box<dyn Backend>,
        hdd: Box<dyn Backend>,
        obs: Arc<TraceCollector>,
    ) -> Self {
        let writer = SbWriter { last_epoch: 0, next_slot: 0 };
        Self::assemble(cfg, ssd, hdd, Self::fresh_core(cfg), writer, obs)
    }

    fn fresh_core(cfg: &ShardConfig) -> ShardCore {
        let policy = policy_for(cfg.system, cfg.history);
        let route = policy.initial_route();
        ShardCore {
            files: FileTable::new(),
            grouper: StreamGrouper::new(cfg.stream_len),
            detector: NativeDetector::new(cfg.seek),
            policy,
            route,
            pipeline: Pipeline::new(cfg.ssd_capacity_sectors),
            own: OwnershipMap::new(),
            pending_slots: [0; REGIONS],
            next_seq: 1,
            region_max_seq: [0; REGIONS],
            sb: Superblock::fresh(cfg.shard_id),
            drained: false,
            shutdown: false,
            failed: None,
            degraded: false,
            stats: ShardStats::default(),
        }
    }

    fn assemble(
        cfg: &ShardConfig,
        ssd: Box<dyn Backend>,
        hdd: Box<dyn Backend>,
        core: ShardCore,
        sb_writer: SbWriter,
        obs: Arc<TraceCollector>,
    ) -> Self {
        let strategy = match cfg.system {
            SystemKind::SsdupPlus => FlushStrategy::TrafficAware { pause_below: cfg.pause_below },
            _ => FlushStrategy::Immediate,
        };
        let half = cfg.ssd_capacity_sectors / 2;
        let ssd = Arc::new(
            GroupSync::new(ssd, cfg.group_commit, cfg.group_commit_window)
                .with_trace(Arc::clone(&obs), cfg.shard_id),
        );
        let hdd = Arc::new(
            GroupSync::new(hdd, cfg.group_commit, cfg.group_commit_window)
                .with_trace(Arc::clone(&obs), cfg.shard_id),
        );
        let ssd_q =
            IoQueue::new(Arc::clone(&ssd), cfg.io_workers, cfg.io_depth, &format!("s{}", cfg.shard_id));
        let hdd_q =
            IoQueue::new(Arc::clone(&hdd), cfg.io_workers, cfg.io_depth, &format!("h{}", cfg.shard_id));
        Shard {
            core: Mutex::new(core),
            ssd,
            hdd,
            ssd_q,
            hdd_q,
            flush_window: cfg.io_depth.clamp(1, 4),
            space: Condvar::new(),
            work: Condvar::new(),
            published: Condvar::new(),
            read_pins: [AtomicU64::new(0), AtomicU64::new(0)],
            read_retries: AtomicU64::new(0),
            direct_inflight: AtomicU64::new(0),
            strategy,
            half_sectors: half,
            max_buffer_sectors: half - HEADER_SECTORS,
            use_ssd: cfg.system.uses_ssd(),
            flush_check: cfg.flush_check,
            coordinator: None,
            hot_defer_window: cfg.hot_defer_window,
            shard_id: cfg.shard_id,
            sb_base: 2 * half as u64 * SECTOR_BYTES,
            sb_lock: Mutex::new(sb_writer),
            obs,
            stage_lat: Mutex::new(StageSet::new()),
        }
    }

    /// Attach the engine's shared flush coordinator. Builder-style: the
    /// coordinator spans every shard of an array and must be wired
    /// before the flusher thread spawns, while the engine still owns
    /// the shard by value. Standalone shards stay uncoordinated.
    pub fn with_coordinator(mut self, co: Arc<FlushCoordinator>) -> Self {
        self.coordinator = Some(co);
        self
    }

    /// Live SSD-log occupancy in `[0, 1]`: bytes buffered and not yet
    /// flushed or superseded, over the whole log capacity. This is the
    /// priority the flush coordinator ranks waiters by, and the signal
    /// behind its ingest-bias load map.
    fn occupancy(&self, core: &ShardCore) -> f32 {
        let s = &core.stats;
        let live = s.ssd_bytes_buffered.saturating_sub(s.flushed_bytes + s.superseded_bytes);
        let cap = sectors_to_bytes(2 * self.half_sectors);
        if cap == 0 {
            0.0
        } else {
            (live as f64 / cap as f64) as f32
        }
    }

    /// Snapshot of the per-stage ack-latency attribution histograms.
    pub fn stage_latency(&self) -> StageSet {
        self.stage_lat.lock().unwrap().clone()
    }

    /// Fold a batch of completed spans into the attribution histograms
    /// (one leaf-lock acquisition) and emit them as trace events when
    /// the collector is enabled. `skip_trace` names stages another layer
    /// already traces (the group-commit sequencer emits `barrier_wait`).
    fn book_spans(&self, spans: &[(Stage, Instant, Instant)], skip_trace: Option<Stage>) {
        {
            let mut lat = self.stage_lat.lock().unwrap();
            for &(stage, t0, t1) in spans {
                lat.record(stage, t1.duration_since(t0).as_micros() as u64);
            }
        }
        if self.obs.is_enabled() {
            for &(stage, t0, t1) in spans {
                if Some(stage) != skip_trace {
                    self.obs.emit(stage, self.shard_id, t0, t1);
                }
            }
        }
    }

    /// Write `sb` into the alternation slot and wait for a covering sync
    /// barrier, unless a newer epoch is already durable (see the
    /// `sb_lock` field docs). Callers pass the guard so the decision, the
    /// write, and the slot flip are atomic. The barrier coalesces with
    /// concurrent publishers' — a superblock rewrite rides the same
    /// device sync as the records landing around it.
    fn write_superblock(&self, w: &mut SbWriter, sb: &Superblock) -> io::Result<()> {
        if sb.epoch <= w.last_epoch {
            return Ok(());
        }
        let t0 = Instant::now();
        sb.write_to(&self.ssd, self.sb_base, w.next_slot)?;
        self.ssd.barrier()?;
        self.book_spans(&[(Stage::SbWrite, t0, Instant::now())], None);
        w.last_epoch = sb.epoch;
        w.next_slot = 1 - w.next_slot;
        Ok(())
    }

    /// Reopen a shard over backends that already hold a previous run's
    /// state: read the superblock, and — unless it records a clean
    /// shutdown — scan both region logs, validate every record frame,
    /// discard torn stretches, skip records the flush watermarks prove
    /// settled, and replay the survivors in sequence order to rebuild
    /// the ownership map and pipeline. The recovered data drains through
    /// the normal flush path; new submits are accepted as usual.
    ///
    /// A dirty superblock (epoch bumped, clean flag off) is persisted
    /// before the shard is returned, so a crash right after a *clean*
    /// reopen can never be short-circuited into ignoring new records.
    pub fn recover(
        cfg: &ShardConfig,
        ssd: Box<dyn Backend>,
        hdd: Box<dyn Backend>,
    ) -> io::Result<(Self, ShardRecovery)> {
        Self::recover_with_obs(cfg, ssd, hdd, Arc::new(TraceCollector::new(DEFAULT_RING_EVENTS)))
    }

    /// [`Shard::recover`] with a shared trace collector; the replay span
    /// (superblock read + log scan + record replay) lands on the trace
    /// when the collector was created enabled.
    pub fn recover_with_obs(
        cfg: &ShardConfig,
        ssd: Box<dyn Backend>,
        hdd: Box<dyn Backend>,
        obs: Arc<TraceCollector>,
    ) -> io::Result<(Self, ShardRecovery)> {
        let t_replay = Instant::now();
        let half = cfg.ssd_capacity_sectors / 2;
        let sb_base = 2 * half as u64 * SECTOR_BYTES;
        let found = Superblock::read(ssd.as_ref(), sb_base, cfg.shard_id)?;
        let (mut sb, found_slot) = match found {
            Some((sb, slot)) => (sb, Some(slot)),
            None => (Superblock::fresh(cfg.shard_id), None),
        };
        let mut core = Self::fresh_core(cfg);
        let mut rec = ShardRecovery { clean: sb.clean, ..ShardRecovery::default() };
        for &(file, slot) in &sb.files {
            core.files.restore_entry(file, slot);
        }
        rec.files_restored = sb.files.len();
        core.next_seq = sb.last_seq.max(sb.watermark[0]).max(sb.watermark[1]) + 1;
        // a shard that degraded before the crash stays degraded: the SSD
        // it gave up on is the same device it would be trusting again
        core.degraded = sb.degraded;
        core.stats.degraded = sb.degraded;
        if !sb.clean {
            let mut scans = Vec::with_capacity(REGIONS);
            for r in 0..REGIONS {
                let base = r as u64 * half as u64 * SECTOR_BYTES;
                scans.push(scan_region(
                    ssd.as_ref(),
                    base,
                    half,
                    cfg.shard_id,
                    r as u32,
                    sb.watermark[r],
                )?);
            }
            // merge live records across regions in sequence order; drop
            // orphans (LBAs outside every recovered file extent — such a
            // record's first-touch superblock never became durable, so
            // its write was never acknowledged)
            let mut live: Vec<LiveRecord> = Vec::new();
            for s in &scans {
                for l in &s.live {
                    if core.files.owns_lba(l.lba) {
                        live.push(*l);
                    } else {
                        rec.orphaned += 1;
                    }
                }
                rec.records_skipped += s.skipped;
                rec.torn_discarded += s.torn;
                rec.sectors_scanned += s.scanned_sectors;
            }
            live.sort_unstable_by_key(|l| l.seq);
            rec.records_replayed = live.len() as u64;
            let recovered_sectors: i64 = live.iter().map(|l| l.size).sum();
            rec.bytes_recovered = sectors_to_bytes(recovered_sectors);
            let (own, replay_superseded) = OwnershipMap::rebuild_from_replay(
                live.iter().map(|l| (l.seq, l.lba, l.size, l.region, l.payload_slot)),
            );
            core.own = own;
            // pipeline topology: regions restore over their scanned log
            // tails; if both hold live data, the one with the *older*
            // records is queued for flushing first — recovery must
            // preserve fill-order flushing or the watermark skip rule
            // breaks (see the module docs)
            let min_seq = |s: &crate::live::record::ScanReport| s.live.first().map(|l| l.seq);
            let (active, queue): (usize, Vec<usize>) = match (min_seq(&scans[0]), min_seq(&scans[1]))
            {
                (Some(a), Some(b)) if a < b => (1, vec![0]),
                (Some(_), Some(_)) => (0, vec![1]),
                (None, Some(_)) => (1, vec![]),
                _ => (0, vec![]),
            };
            core.pipeline.restore([scans[0].cursor, scans[1].cursor], active, &queue);
            for (r, s) in scans.iter().enumerate() {
                core.region_max_seq[r] = s.max_live_seq;
                core.next_seq = core.next_seq.max(s.max_live_seq + 1);
            }
            // recovered bytes re-enter the accounting as ingested +
            // buffered (with replay-time supersession booked), so the
            // `buffered == flushed + superseded` conservation holds
            // across the recovery drain
            core.stats.bytes_in = rec.bytes_recovered;
            core.stats.ssd_bytes_buffered = rec.bytes_recovered;
            core.stats.superseded_bytes = sectors_to_bytes(replay_superseded);
        }
        // persist the dirty mark *before* accepting traffic: new records
        // framed after this open must never hide behind a stale clean
        // flag at the next recovery. Write into the slot NOT holding the
        // recovered superblock, so a crash mid-write here still falls
        // back to it.
        sb.epoch += 1;
        sb.clean = false;
        let write_slot = match found_slot {
            Some(s) => 1 - s,
            None => 0,
        };
        sb.write_to(ssd.as_ref(), sb_base, write_slot)?;
        ssd.sync()?;
        let writer = SbWriter { last_epoch: sb.epoch, next_slot: 1 - write_slot };
        core.sb = sb;
        let shard = Self::assemble(cfg, ssd, hdd, core, writer, obs);
        // one span for the whole reopen: superblock read, log scan,
        // replay, and the dirty-mark persist (near-zero on a clean open)
        shard.book_spans(&[(Stage::Replay, t_replay, Instant::now())], None);
        Ok((shard, rec))
    }

    /// Timed wait on `cv` that surfaces a shard failure or shutdown as a
    /// typed error instead of sleeping on work that can never finish —
    /// the caller was never acknowledged, so vanishing silently would
    /// turn a shutdown into data loss the client believes was written.
    fn wait_or_err<'a>(
        &self,
        cv: &Condvar,
        core: MutexGuard<'a, ShardCore>,
    ) -> Result<MutexGuard<'a, ShardCore>, SubmitError> {
        let core = cv.wait_timeout(core, self.flush_check).unwrap().0;
        if let Some(msg) = core.failed.clone() {
            return Err(SubmitError::Failed(msg));
        }
        if core.shutdown {
            return Err(SubmitError::Shutdown);
        }
        Ok(core)
    }

    /// Ingest one sub-request with its payload. Blocks (physical
    /// backpressure) while both pipeline regions are unavailable.
    ///
    /// The core lock is held only to route, reserve, and claim; the
    /// device write itself runs unlocked, then a brief re-acquire
    /// publishes the claim — concurrent clients of one shard overlap
    /// their device writes (see the module docs).
    ///
    /// Overwrites are fully supported, across routes: the newest copy of
    /// every sector is tracked in the ownership map, stale buffered
    /// copies are superseded, and a direct write over live buffered data
    /// is absorbed into the SSD log.
    ///
    /// Returns `Err` only when the write was **not** acknowledged:
    /// shutdown arrived while it waited, or the shard failed permanently
    /// (HDD backstop). Transient device faults are retried below the
    /// ack; an SSD that still refuses a write flips the shard into
    /// sticky degraded mode and the claim re-routes direct to the HDD.
    pub fn submit(&self, sub: &SubRequest, payload: &[u8]) -> Result<(), SubmitError> {
        let size = sub.size as i64;
        debug_assert_eq!(payload.len() as u64, sub.bytes());
        // stage attribution boundaries: adjacent, non-overlapping spans
        // sharing their edge timestamps, so per-stage sums reconstruct
        // the whole submit latency (see obs::stages)
        let t_submit = Instant::now();
        // detection must see each sub-request once, not once per attempt
        let mut feed_detector = true;
        loop {
            if self.submit_attempt(sub, payload, size, t_submit, &mut feed_detector)? {
                return Ok(());
            }
            // the SSD refused the slot write: the shard degraded, and the
            // aborted claim re-enters the loop to re-route via the HDD
        }
    }

    /// One routing/claim/device/publish attempt of [`Shard::submit`]:
    /// `Ok(true)` = acknowledged; `Ok(false)` = the claim was aborted
    /// (SSD slot-write failure → degraded mode) and must be re-claimed.
    fn submit_attempt(
        &self,
        sub: &SubRequest,
        payload: &[u8],
        size: i64,
        t_submit: Instant,
        feed_detector: &mut bool,
    ) -> Result<bool, SubmitError> {
        let mut t_routed: Option<Instant> = None;

        // ---- critical section 1: route + reserve + claim ----
        let (lba, claimed) = {
            let mut core = self.core.lock().unwrap();
            // the engine is one burst per instance: the flusher exits for
            // good once a drain completes, so a later submit could buffer
            // bytes that no one would ever flush — fail loudly instead
            assert!(!core.drained, "submit after drain: the live engine is one burst per engine");
            let (lba, new_file) = core.files.lba_or_new(sub.parent.file, sub.local_offset);
            debug_assert!(lba <= i32::MAX as i64, "LBA exceeds detector i32 space");
            if new_file {
                // first touch of a file allocates its disk extent — the
                // mapping every future byte of the file depends on. It
                // must be durable before anything in the extent can be
                // acknowledged, and before any *other* client can route
                // through it, so this rare event (once per file, ever)
                // is the one place the core lock is held across device
                // I/O: superblock rewrite + sync under the lock.
                let n_files = core.files.files();
                if n_files > MAX_SB_FILES {
                    // the table must fit one superblock sector; fail the
                    // shard through the established protocol instead of
                    // poisoning the core mutex deeper in the encoder
                    return Err(self.fail_core(
                        core,
                        format!(
                            "live shard file-table limit exceeded: {n_files} files > \
                             {MAX_SB_FILES} (one superblock sector of entries)"
                        ),
                    ));
                }
                core.sb.epoch += 1;
                core.sb.clean = false;
                core.sb.files = core.files.entries();
                if !core.degraded {
                    let sb = core.sb.clone();
                    let mut last_written = self.sb_lock.lock().unwrap();
                    if let Err(e) = self.write_superblock(&mut last_written, &sb) {
                        // the mapping could not be made durable: degrade
                        // instead of failing the shard. The file table
                        // lives on in memory (and rides along with any
                        // later superblock write that succeeds), but this
                        // file's writes lose crash durability until one
                        // does — the documented degraded-mode limitation.
                        drop(last_written);
                        self.degrade(&mut core, &format!("superblock write (new file): {e}"));
                    }
                }
            }
            let claimed = loop {
                // (re)decide the route against the map as it is *now*:
                // every wait below drops the lock, so other clients'
                // claims, publishes, and flushes can shift the picture
                // between passes — including the policy route itself
                let mut route =
                    if core.degraded || !self.use_ssd || size > self.max_buffer_sectors {
                        // degraded mode routes everything direct; a
                        // sub-request larger than a region can frame
                        // (payload plus the record header sector) could
                        // never buffer either: direct to HDD
                        Route::Hdd
                    } else {
                        core.route
                    };
                // overwrite safety: a direct write overlapping a live
                // buffered extent would race the flusher for the same HDD
                // sectors. Absorb it into the SSD log instead — the claim
                // supersedes the stale copy and the flush order across
                // regions keeps last-write-wins on the HDD.
                let mut absorbed = false;
                if route == Route::Hdd && self.use_ssd && core.own.overlaps_ssd(lba, size) {
                    if !core.degraded && size <= self.max_buffer_sectors {
                        route = Route::Ssd;
                        absorbed = true;
                    } else {
                        // a valve-sized (or degraded-mode) write over
                        // buffered data cannot be absorbed: force the
                        // overlap out through the flusher and retry —
                        // never write the HDD under a live buffered copy,
                        // or a later flush would resurrect stale bytes.
                        // Only the active region needs
                        // forcing — overlaps held by a pending/flushing
                        // region drain on their own. The blocked_wait is
                        // booked *after* this pass re-confirmed the
                        // overlap, immediately before the wait it counts:
                        // a cleared overlap re-enters the loop and claims
                        // without inflating the stat.
                        if core.own.overlaps_ssd_region(lba, size, core.pipeline.active_region()) {
                            core.pipeline.enqueue_residual_flush();
                        }
                        core.stats.blocked_waits += 1;
                        self.work.notify_all();
                        core = self.wait_or_err(&self.space, core)?;
                        continue;
                    }
                }
                // a claim overlapping an *in-flight* direct write must
                // wait for it to land: with both device writes unordered,
                // the older HDD bytes could otherwise surface after this
                // claim's copy was flushed over them
                if core.own.direct_overlaps(lba, size) {
                    core = self.wait_or_err(&self.published, core)?;
                    continue;
                }
                // route decided and every wait behind us (a retry pass
                // restamps): submit→here is Route, here→lock drop is
                // Reserve
                t_routed = Some(Instant::now());
                match route {
                    Route::Hdd => {
                        core.stats.hdd_direct_bytes += payload.len() as u64;
                        // counted inside the critical section that decided
                        // the route, so the flusher's gate sees the direct
                        // traffic the moment it exists; the RAII gate
                        // restores the counter on every exit path, a
                        // failed write's unwind included. Release pairs
                        // with the gate's Acquire load in `gate_run`.
                        self.direct_inflight.fetch_add(1, Ordering::Release);
                        let gate = DirectGate { shard: self };
                        let ticket = core.own.claim_direct(lba, size);
                        break Claimed::Direct { dest: lba as u64 * SECTOR_BYTES, ticket, gate };
                    }
                    Route::Ssd => {
                        // the log slot covers the record frame: one
                        // header sector plus the payload
                        let outcome = core.pipeline.buffer(
                            sub.parent.file,
                            sub.local_offset as i64,
                            size + HEADER_SECTORS,
                        );
                        let (region, ssd_offset, filled) = match outcome {
                            BufferOutcome::Buffered { region, ssd_offset } => {
                                (region, ssd_offset, false)
                            }
                            BufferOutcome::BufferedAndFull { region, ssd_offset, .. } => {
                                (region, ssd_offset, true)
                            }
                            BufferOutcome::Blocked => {
                                // "the system waits until a region becomes
                                // empty" — closed-loop backpressure
                                core.stats.blocked_waits += 1;
                                self.work.notify_all();
                                core = self.wait_or_err(&self.space, core)?;
                                continue;
                            }
                        };
                        // reserve in the same lock hold as the slot: the
                        // map never lags the pipeline, and the claim's
                        // order — like the record sequence assigned here,
                        // which recovery replays in — is fixed even
                        // though the bytes land later. The map tracks the
                        // payload slot (past the header sector).
                        let seq = core.next_seq;
                        core.next_seq += 1;
                        core.region_max_seq[region] = core.region_max_seq[region].max(seq);
                        let (stale, ticket) =
                            core.own.reserve(lba, size, region, ssd_offset + HEADER_SECTORS);
                        core.pending_slots[region] += 1;
                        core.stats.superseded_bytes += sectors_to_bytes(stale);
                        core.stats.ssd_bytes_buffered += payload.len() as u64;
                        if absorbed {
                            core.stats.rerouted_writes += 1;
                        }
                        if filled {
                            self.work.notify_all(); // a region is ready to flush
                        }
                        break Claimed::Slot { region, ssd_offset, ticket, seq, absorbed };
                    }
                }
            };
            // server-side detection feeds on the post-striping disk
            // address — once per sub-request, not once per attempt
            if *feed_detector {
                *feed_detector = false;
                if let Some(stream) =
                    core.grouper.push_parts(sub.parent.app, lba as i32, sub.size)
                {
                    let det = core.detector.detect(&stream.reqs);
                    core.account_stream(&det);
                    // array-aware ingest bias: when this shard's log
                    // stands out as the array's hot spot, a *new* stream
                    // the policy would buffer starts direct-to-HDD
                    // instead — the fullest log stops attracting load
                    // while it drains. Only the route decided here for
                    // the next stream window is overridden; streams
                    // already assigned keep their stable placement.
                    if core.route == Route::Ssd && !core.degraded {
                        if let Some(co) = &self.coordinator {
                            if co.is_hot(self.shard_id, HOT_BIAS_MARGIN) {
                                core.route = Route::Hdd;
                                core.stats.biased_streams += 1;
                            }
                        }
                    }
                    // a route change can unpause the traffic-aware flusher
                    self.work.notify_all();
                }
            }
            (lba, claimed)
        };
        let t_routed = t_routed.expect("claim loop stamps the route boundary before breaking");
        let t_reserved = Instant::now();

        // ---- device write, no lock held: the claim's bytes are enqueued
        // on the per-device submission queue and this thread parks on a
        // completion token while the worker pool drives the device —
        // concurrent clients pile up *queue depth* instead of blocked
        // threads. Both routes end in a group-commit barrier covering
        // the batch's completion ticket before the publish — the write
        // is covered by a *completed* device sync, usually one shared
        // with other in-flight publishers: an acknowledged write is a
        // durable write, which is exactly the set recovery promises to
        // restore ----
        match claimed {
            Claimed::Direct { dest, ticket, gate } => {
                // SAFETY: this thread parks on the batch's token inside
                // `queue_write`, so `payload` outlives the request
                let batch = vec![unsafe { IoReq::borrowed(dest, payload) }];
                let (t, wrote) = self.queue_write(&self.hdd_q, &self.hdd, batch);
                // ---- critical section 2: completion-publish ----
                {
                    let mut core = self.core.lock().unwrap();
                    core.own.finish_direct(ticket);
                    if let Err(e) = wrote {
                        // the HDD is the backstop device: a write it
                        // still refuses after the queue's transient
                        // retries has nowhere left to go
                        return Err(self.fail_core(core, format!("hdd backend write: {e}")));
                    }
                    core.stats.bytes_in += payload.len() as u64;
                }
                // readers and writers waiting out this in-flight direct
                // write key off publishes
                self.published.notify_all();
                // the gate decrements `direct_inflight` (and may reopen
                // the traffic-aware flusher) — after the publish, so the
                // flusher never sees the count drop before the claim
                // resolved
                drop(gate);
                self.book_submit(Stage::HddWrite, t_submit, t_routed, t_reserved, t);
                Ok(true)
            }
            Claimed::Slot { region, ssd_offset, ticket, seq, absorbed } => {
                let base = region as u64 * self.half_sectors as u64 * SECTOR_BYTES;
                let header = RecordHeader {
                    shard: self.shard_id,
                    region: region as u32,
                    size,
                    lba,
                    seq,
                    pos: ssd_offset,
                }
                .encode(payload);
                // the header sector and the payload are byte-adjacent in
                // the log, so the queue worker coalesces the batch into
                // ONE vectored device write.
                // SAFETY: this thread parks on the batch's token inside
                // `queue_write`, so both buffers outlive their requests
                let batch = unsafe {
                    vec![
                        IoReq::borrowed(base + ssd_offset as u64 * SECTOR_BYTES, &header),
                        IoReq::borrowed(
                            base + (ssd_offset + HEADER_SECTORS) as u64 * SECTOR_BYTES,
                            payload,
                        ),
                    ]
                };
                let (t, wrote) = self.queue_write(&self.ssd_q, &self.ssd, batch);
                // ---- critical section 2: completion-publish ----
                if let Err(e) = wrote {
                    // the SSD refused the slot write even after the
                    // queue's transient retries: abort the reservation
                    // (its claim-time bookings roll back with it), flip
                    // into sticky degraded mode, and re-claim via the
                    // direct HDD route — re-entering the claim loop
                    // keeps the overlap rules exact on the new route
                    {
                        let mut core = self.core.lock().unwrap();
                        core.pending_slots[region] -= 1;
                        core.own.abort(ticket, lba, size);
                        core.stats.ssd_bytes_buffered -= payload.len() as u64;
                        if absorbed {
                            core.stats.rerouted_writes -= 1;
                        }
                        self.degrade(&mut core, &format!("ssd backend write: {e}"));
                    }
                    // a blocked writer may now route direct; the flusher
                    // may be waiting on this region's reserved slots
                    self.space.notify_all();
                    self.published.notify_all();
                    self.work.notify_all();
                    return Ok(false);
                }
                {
                    let mut core = self.core.lock().unwrap();
                    core.pending_slots[region] -= 1;
                    core.own.publish(ticket, lba, size);
                    // feed the recovery rewind guard: these log sectors
                    // now hold a durable, acknowledged record
                    core.pipeline.mark_published(region, ssd_offset + HEADER_SECTORS + size);
                    core.stats.bytes_in += payload.len() as u64;
                }
                // readers waiting on published ranges, writers waiting
                // out an overlap, and a flusher waiting for its region's
                // reserved slots all key off publishes
                self.published.notify_all();
                self.work.notify_all();
                self.book_submit(Stage::SsdWrite, t_submit, t_routed, t_reserved, t);
                Ok(true)
            }
        }
    }

    /// Enqueue one batch on `q`, park on its completion token, then wait
    /// out a durability barrier covering the batch's ticket exactly.
    /// Returns the stage boundaries (enqueued, device-start, device-done,
    /// barrier-done) and the combined write+barrier outcome.
    fn queue_write(
        &self,
        q: &IoQueue,
        dev: &GroupSync,
        batch: Vec<IoReq>,
    ) -> ([Instant; 4], io::Result<()>) {
        let token = q.submit(batch);
        let t_enqueued = Instant::now();
        let done = token.wait();
        let t_dev = Instant::now();
        let (t_started, wrote) = match done {
            // the worker's start stamp can race a hair ahead of
            // `t_enqueued` (it may pop the batch before `submit`
            // returns); clamp so the queue_wait span stays non-negative
            Ok(c) => {
                let t_started = c.started.max(t_enqueued);
                if c.retry_us > 0 {
                    // transient faults were absorbed below this token:
                    // attribute the retried device dwell so fault storms
                    // show up in the latency breakdown
                    let t_end = t_started + Duration::from_micros(c.retry_us);
                    self.book_spans(&[(Stage::FaultRetry, t_started, t_end)], None);
                }
                (t_started, dev.barrier_for(c.ticket))
            }
            Err(e) => (t_enqueued, Err(e)),
        };
        let t_barrier = Instant::now();
        ([t_enqueued, t_started, t_dev, t_barrier], wrote)
    }

    /// Fold one acknowledged write's stage decomposition: route/reserve
    /// from [`Shard::submit`]'s critical section, the queue and device
    /// boundaries from [`Shard::queue_write`]. The spans are adjacent and
    /// share their edge timestamps, so their sums reconstruct the whole
    /// submit latency. The group-commit layer already emits
    /// `barrier_wait` trace events, so only its histogram is fed here.
    fn book_submit(
        &self,
        dev: Stage,
        t_submit: Instant,
        t_routed: Instant,
        t_reserved: Instant,
        t: [Instant; 4],
    ) {
        let [t_enqueued, t_started, t_dev, t_barrier] = t;
        let t_published = Instant::now();
        self.book_spans(
            &[
                (Stage::Route, t_submit, t_routed),
                (Stage::Reserve, t_routed, t_reserved),
                (Stage::IoSubmit, t_reserved, t_enqueued),
                (Stage::QueueWait, t_enqueued, t_started),
                (dev, t_started, t_dev),
                (Stage::BarrierWait, t_dev, t_barrier),
                (Stage::Publish, t_barrier, t_published),
                (Stage::Submit, t_submit, t_published),
            ],
            Some(Stage::BarrierWait),
        );
    }

    /// Record a failure, release the core lock, wake every waiter, and
    /// hand the (first) cause back as a typed error — no panic, no mutex
    /// poisoning; every other thread surfaces the same cause.
    fn fail_core(&self, mut core: MutexGuard<'_, ShardCore>, msg: String) -> SubmitError {
        let msg = core.failed.get_or_insert(msg).clone();
        drop(core);
        self.space.notify_all();
        self.work.notify_all();
        self.published.notify_all();
        SubmitError::Failed(msg)
    }

    /// Flip the shard into sticky degraded mode: every new write routes
    /// direct to the HDD from here on, while the flusher keeps draining
    /// what was already buffered. The flag is persisted into the
    /// superblock best-effort — the SSD that just failed may refuse this
    /// write too, in which case a recovered shard simply re-degrades on
    /// its next SSD failure. Idempotent; called with the core lock held
    /// (the first-touch precedent for holding it across device I/O).
    fn degrade(&self, core: &mut ShardCore, cause: &str) {
        if core.degraded {
            return;
        }
        eprintln!("shard {}: degraded, new writes route direct to HDD: {cause}", self.shard_id);
        core.degraded = true;
        core.stats.degraded = true;
        core.sb.epoch += 1;
        core.sb.clean = false;
        core.sb.degraded = true;
        core.sb.files = core.files.entries();
        let sb = core.sb.clone();
        let mut last_written = self.sb_lock.lock().unwrap();
        let _ = self.write_superblock(&mut last_written, &sb);
    }

    /// Read back `buf.len()` bytes the shard's HDD holds for
    /// `(file, local_offset)` — verification path. Unlike [`Shard::read`]
    /// this deliberately ignores buffered copies; only meaningful after a
    /// drain. A file the shard has never written reads as zeros — the
    /// lookup never creates an extent (a read-minted entry would not be
    /// persisted, and the file's later first write would skip the
    /// superblock first-touch and be orphaned at recovery).
    pub fn read_hdd(&self, file: u32, local_offset: i32, buf: &mut [u8]) -> Result<(), ReadError> {
        let Some(lba) = self.core.lock().unwrap().files.lookup(file, local_offset) else {
            buf.fill(0);
            return Ok(());
        };
        // no lock across the device read; transients retried inline
        let (result, retries) = retry_transient(&RetryPolicy::io_default(), || {
            self.hdd.read_at(lba as u64 * SECTOR_BYTES, buf)
        });
        if retries > 0 {
            // Relaxed: stats counter, folded into ShardStats by stats()
            self.read_retries.fetch_add(retries as u64, Ordering::Relaxed);
        }
        result.map_err(|e| ReadError::Device(format!("hdd backend read: {e}")))
    }

    /// Read `buf.len()` bytes for `(file, local_offset)` from wherever
    /// the newest copy lives — SSD log or HDD — resolved per segment
    /// through the ownership map. Works mid-burst, before any drain.
    ///
    /// The range is resolved (and its regions pinned) under the core
    /// lock, but the device reads happen with **no lock held**: readers
    /// never serialize against ingest or the flusher. The pins keep a
    /// concurrently-completing flush from recycling the very log slots
    /// being read (`flusher_loop` waits them out before `flush_done`).
    /// If part of the range is claimed by a write whose device bytes are
    /// still in flight, the read first waits for that claim to publish —
    /// a pending claim has no readable copy anywhere.
    pub fn read(&self, file: u32, local_offset: i32, buf: &mut [u8]) -> Result<(), ReadError> {
        let sector = SECTOR_BYTES as usize;
        debug_assert_eq!(buf.len() % sector, 0, "reads are sector-aligned");
        let sectors = (buf.len() / sector) as i64;
        if sectors == 0 {
            return Ok(());
        }
        let t_read = Instant::now();
        let (lba, segs, pinned) = {
            let mut core = self.core.lock().unwrap();
            // never-written files read as zeros without minting an extent
            // (see `read_hdd` on why reads must not touch the table)
            let Some(lba) = core.files.lookup(file, local_offset) else {
                drop(core);
                buf.fill(0);
                return Ok(());
            };
            loop {
                if let Some(msg) = core.failed.clone() {
                    return Err(ReadError::Failed(msg));
                }
                if core.shutdown {
                    return Err(ReadError::Shutdown);
                }
                if !core.own.pending_overlaps(lba, sectors) {
                    break;
                }
                core = self.published.wait_timeout(core, self.flush_check).unwrap().0;
            }
            let segs = core.own.resolve(lba, sectors);
            let mut pinned = [false; REGIONS];
            for (_, _, tier) in &segs {
                if let Tier::Ssd { region, .. } = tier {
                    pinned[*region] = true;
                }
            }
            for (r, p) in pinned.iter().enumerate() {
                if *p {
                    // pinned while still holding the core lock: the
                    // flusher checks pins under the same lock after
                    // emptying the region's map entries, so a pin taken
                    // here is never missed. Release pairs with the
                    // flusher's Acquire load in its settle wait.
                    self.read_pins[r].fetch_add(1, Ordering::Release);
                }
            }
            (lba, segs, pinned)
        };
        let t_resolved = Instant::now();
        let mut result = Ok(());
        for (seg_lba, seg_size, tier) in segs {
            let dst = (seg_lba - lba) as usize * sector;
            let len = seg_size as usize * sector;
            let slice = &mut buf[dst..dst + len];
            let (r, retries) = match tier {
                Tier::Hdd => retry_transient(&RetryPolicy::io_default(), || {
                    self.hdd.read_at(seg_lba as u64 * SECTOR_BYTES, slice)
                }),
                Tier::Ssd { region, ssd_offset } => {
                    let base = region as u64 * self.half_sectors as u64 * SECTOR_BYTES;
                    retry_transient(&RetryPolicy::io_default(), || {
                        self.ssd.read_at(base + ssd_offset as u64 * SECTOR_BYTES, slice)
                    })
                }
            };
            if retries > 0 {
                // Relaxed: stats counter, folded into ShardStats
                self.read_retries.fetch_add(retries as u64, Ordering::Relaxed);
            }
            result = r;
            if result.is_err() {
                break;
            }
        }
        // unpin before surfacing any error: a flusher waiting out our
        // pins must not hang on a reader that is about to error out
        // (Release: the flusher's Acquire sees our finished transfers)
        for (r, p) in pinned.iter().enumerate() {
            if *p && self.read_pins[r].fetch_sub(1, Ordering::Release) == 1 {
                self.work.notify_all();
            }
        }
        self.book_spans(
            &[(Stage::ReadResolve, t_read, t_resolved), (Stage::ReadDevice, t_resolved, Instant::now())],
            None,
        );
        result.map_err(|e| ReadError::Device(format!("shard backend read: {e}")))
    }

    pub fn stats(&self) -> ShardStats {
        let mut stats = self.core.lock().unwrap().stats.clone();
        // the group-commit sequencers keep their own lock-free counters;
        // fold them into the snapshot so `sync_barriers / syncs` is the
        // shard's observed batching factor
        stats.syncs = self.ssd.syncs() + self.hdd.syncs();
        stats.sync_barriers = self.ssd.barriers() + self.hdd.barriers();
        // achieved queue depth, folded across both device queues
        let mut q = self.ssd_q.stats();
        q.merge(&self.hdd_q.stats());
        stats.io_reqs = q.reqs;
        stats.io_device_writes = q.device_writes;
        stats.io_depth_high_water = q.depth_high_water;
        stats.io_mean_depth = q.mean_depth();
        // fault absorption, folded from every retrying layer: the queue
        // workers, the group-commit syncs, and the inline read paths
        // (Relaxed: stats read, no synchronization implied)
        let read_retries = self.read_retries.load(Ordering::Relaxed);
        stats.io_retries =
            q.retries + self.ssd.sync_retries() + self.hdd.sync_retries() + read_retries;
        stats.transient_faults = q.transient_faults
            + self.ssd.sync_transient_faults()
            + self.hdd.sync_transient_faults()
            + read_retries;
        stats
    }

    /// Background flusher: runs on its own thread until shutdown, or until
    /// the shard is drained clean.
    pub(crate) fn flusher_loop(&self) {
        loop {
            // ---- acquire the next region to flush (or exit) ----
            let (region, queued_sectors, occupancy) = {
                let mut core = self.core.lock().unwrap();
                let region = loop {
                    if core.shutdown || core.failed.is_some() {
                        return;
                    }
                    if core.drained
                        && core.pipeline.flushing_region().is_none()
                        && core.pipeline.flush_pending.is_empty()
                    {
                        core.pipeline.enqueue_residual_flush();
                    }
                    if let Some(r) = core.pipeline.next_flush() {
                        break r;
                    }
                    if core.drained && !core.pipeline.dirty() {
                        self.space.notify_all();
                        return;
                    }
                    core = self.work.wait_timeout(core, self.flush_check).unwrap().0;
                };
                // reserve→publish: wait for the region's in-flight
                // reserved slots to publish before snapshotting. The
                // region stopped accepting appends when it was queued, so
                // the count only falls — and the extent set this cycle
                // works from can only shrink (supersession) from here.
                while core.pending_slots[region] > 0 {
                    if core.shutdown || core.failed.is_some() {
                        return;
                    }
                    core = self.published.wait_timeout(core, self.flush_check).unwrap().0;
                }
                // the region is taken up now: everything surviving in it
                // is queued-for-flush. Whatever vanishes between this
                // snapshot and the copy-run snapshot below was
                // superseded *while queued* — the superseded_at_flush
                // numerator.
                let queued_sectors = core.own.region_heat(region, Duration::ZERO).0;
                // ---- hot/cold deferral: while the queued data is
                // predominantly hot (recently rewritten), hold the copy
                // runs back so the next rewrite generation supersedes in
                // the buffer instead of costing HDD copies. Strictly
                // bounded: the age window caps the wait, and drain,
                // blocked ingest, or high occupancy end it immediately —
                // nothing is ever skipped, only delayed, so recovery and
                // drain semantics are untouched. ----
                if self.hot_defer_window > Duration::ZERO {
                    let t_defer = Instant::now();
                    let blocked0 = core.stats.blocked_waits;
                    let mut counted = false;
                    loop {
                        if core.shutdown || core.failed.is_some() {
                            return;
                        }
                        // a drain flushes everything now; a blocked
                        // writer or a filling log needs the region back
                        if core.drained
                            || core.stats.blocked_waits > blocked0
                            || self.occupancy(&core) >= DEFER_OCCUPANCY_CEILING
                        {
                            break;
                        }
                        let elapsed = t_defer.elapsed();
                        if elapsed >= self.hot_defer_window {
                            break;
                        }
                        let (total, hot) = core.own.region_heat(region, self.hot_defer_window);
                        // flush once the region is mostly cold (or fully
                        // superseded — releasing it is then free space)
                        if total == 0 || hot * 2 < total {
                            break;
                        }
                        if !counted {
                            // count each deferring cycle once, before its
                            // first wait (observable while deferring)
                            counted = true;
                            core.stats.hot_defers += 1;
                        }
                        let slice = self.flush_check.min(self.hot_defer_window - elapsed);
                        core = self.work.wait_timeout(core, slice).unwrap().0;
                    }
                }
                (region, queued_sectors, self.occupancy(&core))
            };

            // ---- flush-token acquire, no lock held: at most the
            // coordinator's budget of shards run copy runs against the
            // shared HDD tier at once. Short acquire slices keep the
            // shutdown check live; a timed-out slice keeps the waiter's
            // seniority, so the loop must abandon the request on exit.
            // The wait is booked on every acquisition (zero-length when
            // uncontended) so coordinated runs always trace the stage. ----
            let t_token = Instant::now();
            let token: Option<FlushToken> = match &self.coordinator {
                Some(co) => loop {
                    if let Some(t) = co.acquire(self.shard_id, occupancy, self.flush_check) {
                        break Some(t);
                    }
                    let core = self.core.lock().unwrap();
                    if core.shutdown || core.failed.is_some() {
                        drop(core);
                        co.abandon(self.shard_id);
                        return;
                    }
                },
                None => None,
            };
            let t_granted = Instant::now();
            if self.coordinator.is_some() {
                self.book_spans(&[(Stage::FlushTokenWait, t_token, t_granted)], None);
            }

            // ---- copy-run snapshot ----
            let runs = {
                let mut core = self.core.lock().unwrap();
                if core.shutdown || core.failed.is_some() {
                    return; // the token (if any) releases by RAII
                }
                if self.coordinator.is_some() {
                    core.stats.flush_token_waits += 1;
                    core.stats.flush_token_wait_us +=
                        t_granted.duration_since(t_token).as_micros() as u64;
                }
                let region_base = region as u64 * self.half_sectors as u64 * SECTOR_BYTES;
                // reset the region's append metadata; what actually gets
                // copied comes from the ownership map: its extents for
                // this region are exactly the *newest* copies living in
                // the log, ascending by LBA (sequential HDD order) and
                // already clipped of every superseded range — stale-flush
                // suppression by construction
                core.pipeline.reset_flushing();
                core.stats.flushes += 1;
                let remaining = core.own.region_heat(region, Duration::ZERO).0;
                core.stats.queued_for_flush_bytes += sectors_to_bytes(queued_sectors);
                core.stats.superseded_at_flush_bytes +=
                    sectors_to_bytes(queued_sectors - remaining);
                let runs = copy_runs(core.own.region_extents(region), region_base, CHUNK_BYTES);
                core.stats.flush_runs += runs.len() as u64;
                runs
            };

            // ---- gate + copy, no lock held: one gate check per
            // coalesced run, gathered from the log with cheap SSD reads;
            // up to `flush_window` *disjoint* runs are enqueued on the
            // HDD submission queue as ONE batch, completing under one
            // covering ticket. Byte-adjacent runs are the sub-runs of an
            // extent split at `CHUNK_BYTES` — they are submitted in
            // separate batches, or the queue's vectored coalescing would
            // recombine them into one oversized device write and defeat
            // the cap the split exists to enforce. ----
            let mut run_us = 0u64;
            let mut max_ticket = 0u64;
            let mut batch: Vec<IoReq> = Vec::with_capacity(self.flush_window);
            let mut t_batch: Option<Instant> = None;
            let mut batch_end = 0u64;
            let mut runs = runs.into_iter().peekable();
            while let Some(run) = runs.next() {
                if !self.gate_run() {
                    return; // shutdown while paused
                }
                let t_run = Instant::now();
                let mut buf = vec![0u8; run.len];
                let mut pos = 0usize;
                let mut read = Ok(());
                for &(ssd_byte, len) in &run.segs {
                    let (r, retries) = retry_transient(&RetryPolicy::io_default(), || {
                        self.ssd.read_at(ssd_byte, &mut buf[pos..pos + len])
                    });
                    if retries > 0 {
                        // Relaxed: stats counter, folded into ShardStats
                        self.read_retries.fetch_add(retries as u64, Ordering::Relaxed);
                    }
                    read = r;
                    if read.is_err() {
                        break;
                    }
                    pos += len;
                }
                if let Err(e) = read {
                    self.fail(format!("flusher: ssd backend read: {e}"));
                    return;
                }
                // chunk-cap boundary: this run continues the previous
                // one byte-for-byte, so keep them in separate device
                // submissions (see the block comment above)
                if !batch.is_empty()
                    && batch_end == run.hdd_byte
                    && !self.submit_flush_batch(&mut batch, &mut t_batch, &mut run_us, &mut max_ticket)
                {
                    return;
                }
                t_batch.get_or_insert(t_run);
                batch_end = run.hdd_byte + run.len as u64;
                batch.push(IoReq::owned(run.hdd_byte, buf.into_boxed_slice()));
                if (batch.len() >= self.flush_window || runs.peek().is_none())
                    && !self.submit_flush_batch(&mut batch, &mut t_batch, &mut run_us, &mut max_ticket)
                {
                    return;
                }
            }

            // ---- durability + watermark: the flushed bytes must be
            // durable on the HDD, and the advanced watermark durable on
            // the SSD, *before* the region's map entries are released
            // and its log slots recycled. Ordering matters twice over:
            // a crash after release-without-watermark would replay this
            // region's records over newer direct writes (release opens
            // the range to direct routing — resurrection), and a
            // watermark without the HDD sync could skip records whose
            // flushed copy never became durable. A group-commit barrier
            // covering the highest batch ticket gives exactly that — on
            // return, a device sync that started after the copy runs
            // landed has *completed* (often one shared with concurrent
            // direct-route publishers). With no runs at all (everything
            // superseded), ticket 0 is vacuously covered ----
            if let Err(e) = self.hdd.barrier_for(max_ticket) {
                self.fail(format!("flusher: hdd sync: {e}"));
                return;
            }
            // the HDD-bandwidth token covers exactly the copy runs plus
            // their covering barrier; the superblock write and the
            // settle phase below are SSD-side and lock-side work — no
            // reason to keep a peer shard off the HDD for them
            drop(token);
            let sb = {
                let mut core = self.core.lock().unwrap();
                core.sb.epoch += 1;
                core.sb.clean = false;
                let max_seq = core.region_max_seq[region];
                core.sb.watermark[region] = core.sb.watermark[region].max(max_seq);
                core.sb.last_seq = core.next_seq - 1;
                core.sb.files = core.files.entries();
                core.sb.clone()
            };
            {
                // a newer epoch already durable implies this watermark is
                // too (later snapshots carry every earlier mutation), so
                // a skipped write still satisfies the ordering above
                let mut last_written = self.sb_lock.lock().unwrap();
                if let Err(e) = self.write_superblock(&mut last_written, &sb) {
                    drop(last_written);
                    self.fail(format!("flusher: superblock write: {e}"));
                    return;
                }
            }

            // ---- complete: settle the surviving extents (their newest
            // copy is the HDD one now), wait out readers still pinning
            // the region, free it, wake blocked ingest ----
            let occ_after = {
                let mut core = self.core.lock().unwrap();
                core.stats.flush_run_us += run_us;
                core.region_max_seq[region] = 0;
                // account flushed bytes from the map at completion, not
                // from what the copy loop moved: an extent superseded
                // *mid-copy* was already booked into superseded_bytes by
                // its claim, so counting the (now stale) copy too would
                // double-book it — `buffered == flushed + superseded`
                // must stay exact
                let settled = core.own.release_region(region);
                core.stats.flushed_bytes += sectors_to_bytes(settled);
                // with the map holding nothing for this region, no *new*
                // reader can resolve into its log; wait out the readers
                // that already did before the slots are recycled
                // (Acquire: pairs with the readers' Release unpin, so a
                // zero count means their transfers are fully done)
                while self.read_pins[region].load(Ordering::Acquire) > 0 {
                    if core.shutdown || core.failed.is_some() {
                        return;
                    }
                    core = self.work.wait_timeout(core, self.flush_check).unwrap().0;
                }
                core.pipeline.flush_done();
                self.occupancy(&core)
            };
            if let Some(co) = &self.coordinator {
                // refresh the load map the moment occupancy drops, so
                // the ingest bias and grant priority track reality
                // between this shard's acquires
                co.report_occupancy(self.shard_id, occ_after);
            }
            self.space.notify_all();
        }
    }

    /// Submit the flusher's pending batch (if any) and park on its
    /// completion. Books one `FlushRun` span per batch. Returns `false`
    /// after recording a fatal HDD failure — the flush cycle must stop.
    fn submit_flush_batch(
        &self,
        batch: &mut Vec<IoReq>,
        t_batch: &mut Option<Instant>,
        run_us: &mut u64,
        max_ticket: &mut u64,
    ) -> bool {
        if batch.is_empty() {
            return true;
        }
        let t0 = t_batch.take().expect("batch start stamped with its first run");
        match self.hdd_q.submit(std::mem::take(batch)).wait() {
            Ok(c) => {
                *max_ticket = (*max_ticket).max(c.ticket);
                let t_done = Instant::now();
                *run_us += t_done.duration_since(t0).as_micros() as u64;
                self.book_spans(&[(Stage::FlushRun, t0, t_done)], None);
                true
            }
            Err(e) => {
                self.fail(format!("flusher: hdd backend write: {e}"));
                false
            }
        }
    }

    /// Traffic-aware pause gate, re-evaluated per coalesced copy run like
    /// the DES flusher re-evaluates per extent. Returns false only on
    /// shutdown or shard failure.
    fn gate_run(&self) -> bool {
        let mut core = self.core.lock().unwrap();
        let mut paused_at: Option<Instant> = None;
        loop {
            if core.shutdown || core.failed.is_some() {
                return false;
            }
            let pct = core.policy.current_percentage().unwrap_or(1.0);
            // Acquire: pairs with the direct writers' Release increments
            // and the gate's Release decrement, so "no direct traffic"
            // here means those writes have fully landed
            let direct = self.direct_inflight.load(Ordering::Acquire) > 0;
            if self.strategy.allow_flush(pct, direct, core.drained) {
                break;
            }
            if paused_at.is_none() {
                paused_at = Some(Instant::now());
                core.stats.flush_pauses += 1;
            }
            core = self.work.wait_timeout(core, self.flush_check).unwrap().0;
        }
        if let Some(t0) = paused_at {
            let t_resumed = Instant::now();
            core.stats.flush_pause_us += t_resumed.duration_since(t0).as_micros() as u64;
            drop(core);
            self.book_spans(&[(Stage::FlushPause, t0, t_resumed)], None);
        }
        true
    }

    /// All producers have finished: flush any partial detection stream and
    /// queue the residual region.
    pub(crate) fn begin_drain(&self) {
        {
            let mut core = self.core.lock().unwrap();
            core.drained = true;
            if let Some(stream) = core.grouper.flush_partial() {
                let det = core.detector.detect(&stream.reqs);
                core.account_stream(&det);
            }
            core.pipeline.enqueue_residual_flush();
        }
        self.work.notify_all();
    }

    /// Record a fatal flusher error and wake every waiter so it surfaces
    /// in a caller thread instead of hanging the engine.
    fn fail(&self, msg: String) {
        self.core.lock().unwrap().failed.get_or_insert(msg);
        self.space.notify_all();
        self.work.notify_all();
        self.published.notify_all();
    }

    /// Block until every buffered byte has reached the HDD backend —
    /// or until the shard fails, in which case the buffered data can
    /// never drain and the caller surfaces the cause through reads and
    /// stats instead of hanging here forever.
    pub(crate) fn wait_drained(&self) {
        let mut core = self.core.lock().unwrap();
        while core.pipeline.dirty() {
            if core.failed.is_some() {
                return;
            }
            core = self.space.wait_timeout(core, self.flush_check).unwrap().0;
        }
    }

    /// Flush both backends to durable storage. A failing SSD sync
    /// degrades the shard (its syncs no longer mean anything); a failing
    /// HDD sync is a backstop failure and marks the shard failed.
    pub(crate) fn sync(&self) {
        let degraded = self.core.lock().unwrap().degraded;
        if !degraded {
            if let Err(e) = self.ssd.sync() {
                let mut core = self.core.lock().unwrap();
                self.degrade(&mut core, &format!("ssd sync: {e}"));
            }
        }
        if let Err(e) = self.hdd.sync() {
            self.fail(format!("hdd sync: {e}"));
        }
    }

    /// After a full drain: persist a **clean** superblock (watermarks at
    /// the last sequence, clean flag set), so the next
    /// [`Shard::recover`] short-circuits without scanning the logs.
    /// Orderly-shutdown only — a crash leaves the dirty superblock, and
    /// recovery scans.
    pub(crate) fn finalize_clean(&self) {
        let sb = {
            let mut core = self.core.lock().unwrap();
            if core.failed.is_some() || core.pipeline.dirty() {
                // an unfinished drain must leave the dirty superblock in
                // place so the next open scans the logs
                return;
            }
            let last = core.next_seq - 1;
            core.sb.epoch += 1;
            core.sb.clean = true;
            core.sb.last_seq = last;
            core.sb.watermark = [last, last];
            core.sb.files = core.files.entries();
            core.sb.clone()
        };
        let mut last_written = self.sb_lock.lock().unwrap();
        // best-effort: a refused clean mark leaves the dirty superblock,
        // and the next open simply scans instead of short-circuiting
        let _ = self.write_superblock(&mut last_written, &sb);
    }

    pub(crate) fn request_shutdown(&self) {
        self.core.lock().unwrap().shutdown = true;
        self.work.notify_all();
        self.space.notify_all();
        self.published.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::live::backend::{MemBackend, SyntheticLatency};
    use crate::live::payload;
    use crate::types::Request;

    fn cfg(system: SystemKind, capacity_sectors: i64) -> ShardConfig {
        ShardConfig {
            system,
            shard_id: 0,
            ssd_capacity_sectors: capacity_sectors,
            stream_len: 1024, // no detection flips mid-test
            pause_below: 0.45,
            history: 64,
            flush_check: Duration::from_millis(1),
            seek: SeekModel::default(),
            group_commit: true,
            group_commit_window: Duration::ZERO,
            io_workers: 4,
            io_depth: 64,
            hot_defer_window: Duration::ZERO,
        }
    }

    fn mem_shard(system: SystemKind, capacity_sectors: i64) -> Shard {
        Shard::new(
            &cfg(system, capacity_sectors),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        )
    }

    fn sub(file: u32, offset: i32, size: i32) -> SubRequest {
        SubRequest {
            node: 0,
            local_offset: offset,
            size,
            parent: Request { app: 0, proc_id: 0, file, offset, size },
        }
    }

    fn gen_payload(file: u32, offset: i32, size: i32, gen: u64) -> Vec<u8> {
        let mut buf = vec![0u8; (size as u64 * SECTOR_BYTES) as usize];
        payload::fill_gen(file, offset as i64, gen, &mut buf);
        buf
    }

    #[test]
    fn shutdown_while_blocked_surfaces_a_typed_rejection() {
        // no flusher thread: both regions fill and stay unavailable.
        // Each region (129 sectors) holds exactly one framed 128-sector
        // record (1 header sector + payload).
        let shard = Arc::new(mem_shard(SystemKind::OrangeFsBB, 258));
        shard.submit(&sub(1, 0, 128), &gen_payload(1, 0, 128, 1)).unwrap(); // fills region 0
        shard.submit(&sub(1, 128, 128), &gen_payload(1, 128, 128, 1)).unwrap(); // fills region 1
        let worker = Arc::clone(&shard);
        let handle = std::thread::spawn(move || {
            // both regions full, nobody flushing: blocks, then shutdown
            // arrives — silently returning Ok here would be data loss
            // the caller was never told about
            worker.submit(&sub(1, 256, 128), &gen_payload(1, 256, 128, 1))
        });
        std::thread::sleep(Duration::from_millis(20));
        shard.request_shutdown();
        assert_eq!(
            handle.join().expect("no panic on the rejection path"),
            Err(SubmitError::Shutdown),
            "a write dropped by shutdown must surface as a typed rejection"
        );
    }

    #[test]
    fn read_racing_shutdown_surfaces_a_typed_rejection() {
        // a read waiting out an in-flight (reserved, unpublished) claim
        // when shutdown arrives must get a typed error, not panic. The
        // claim is held in flight deterministically: the SSD stalls its
        // device writes behind a gate.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let shard = Arc::new(Shard::new(
            &cfg(SystemKind::OrangeFsBB, 4096),
            Box::new(StallingBackend {
                inner: MemBackend::new(SyntheticLatency::ZERO),
                gate: Arc::clone(&gate),
            }),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        ));
        // first touch while the gate is open: the file's superblock
        // write must not stall under the core lock
        shard.submit(&sub(1, 0, 8), &gen_payload(1, 0, 8, 1)).unwrap();
        *gate.0.lock().unwrap() = true; // arm: the next claim stays pending
        let writer = Arc::clone(&shard);
        let write = std::thread::spawn(move || {
            writer.submit(&sub(1, 100, 8), &gen_payload(1, 100, 8, 1))
        });
        // the claim books its bytes at reserve time: wait until it holds
        let t0 = Instant::now();
        while shard.stats().ssd_bytes_buffered < 16 * SECTOR_BYTES {
            assert!(t0.elapsed() < Duration::from_secs(10), "claim never reserved");
            std::thread::sleep(Duration::from_millis(1));
        }
        let reader = Arc::clone(&shard);
        let read = std::thread::spawn(move || {
            let mut buf = vec![0u8; 8 * SECTOR_BYTES as usize];
            reader.read(1, 100, &mut buf)
        });
        std::thread::sleep(Duration::from_millis(20));
        shard.request_shutdown();
        assert_eq!(read.join().expect("no panic"), Err(ReadError::Shutdown));
        // release the stalled device write: the claim publishes and the
        // writer acks normally — shutdown never drops delivered bytes
        {
            let (armed, cv) = &*gate;
            *armed.lock().unwrap() = false;
            cv.notify_all();
        }
        assert_eq!(write.join().expect("no panic"), Ok(()));
    }

    /// Backend whose writes always fail — drives the publish error paths.
    struct FailingBackend;

    impl Backend for FailingBackend {
        fn write_at(&self, _offset: u64, _data: &[u8]) -> std::io::Result<()> {
            Err(std::io::Error::other("injected write failure"))
        }

        fn read_at(&self, _offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
            buf.fill(0);
            Ok(())
        }

        fn bytes_written(&self) -> u64 {
            0
        }

        fn sync(&self) -> std::io::Result<()> {
            Ok(())
        }

        fn kind(&self) -> &'static str {
            "failing"
        }
    }

    #[test]
    fn failed_direct_write_restores_the_inflight_counter() {
        // OrangeFs routes straight to the HDD; the write fails and the
        // submit surfaces a typed failure through `fail_core`. The RAII
        // gate must still restore `direct_inflight` on the error return
        // — before it, the counter stayed elevated forever and the
        // traffic-aware gate (`direct > 0`) never reopened for other
        // threads of a still-draining engine.
        let shard = Arc::new(Shard::new(
            &cfg(SystemKind::OrangeFs, 4096),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(FailingBackend),
        ));
        let worker = Arc::clone(&shard);
        let handle =
            std::thread::spawn(move || worker.submit(&sub(1, 0, 8), &gen_payload(1, 0, 8, 1)));
        let result = handle.join().expect("no panic on the failure path");
        assert!(
            matches!(result, Err(SubmitError::Failed(_))),
            "a failed direct write must surface a typed failure, got {result:?}"
        );
        assert_eq!(
            shard.direct_inflight.load(Ordering::Acquire),
            0,
            "the direct-inflight counter must be restored on the error path"
        );
    }

    #[test]
    fn ssd_write_failure_degrades_the_shard_and_reroutes_to_hdd() {
        // the SSD dies for every log write (the superblock region past
        // the region logs is spared, so the first-touch mapping and the
        // degraded flag still persist); the HDD stays healthy. A write
        // that would buffer must abort its claim, flip the shard into
        // degraded mode, re-route direct to the HDD, and still ack.
        use crate::live::fault::FaultSpec;
        let c = cfg(SystemKind::OrangeFsBB, 4096);
        let log_bytes = 4096 * SECTOR_BYTES; // both region logs
        let spec =
            FaultSpec::parse(&format!("ssd:dead:max_off={log_bytes}")).expect("valid spec");
        let shard = Shard::new(
            &c,
            spec.wrap_ssd(Box::new(MemBackend::new(SyntheticLatency::ZERO)), 7),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        );
        shard.submit(&sub(1, 0, 64), &gen_payload(1, 0, 64, 1)).expect("degraded ack via HDD");
        let stats = shard.stats();
        assert!(stats.degraded, "the shard must report sticky degraded mode");
        assert_eq!(stats.ssd_bytes_buffered, 0, "the aborted claim rolls its booking back");
        assert_eq!(stats.hdd_direct_bytes, 64 * SECTOR_BYTES, "re-routed direct to the HDD");
        // the re-routed bytes are immediately readable (resolved to HDD)
        let mut got = vec![0u8; 64 * SECTOR_BYTES as usize];
        shard.read(1, 0, &mut got).expect("degraded read");
        assert_eq!(got, gen_payload(1, 0, 64, 1));
        // later writes skip the SSD entirely — no further aborts needed
        shard.submit(&sub(1, 100, 8), &gen_payload(1, 100, 8, 1)).expect("second degraded ack");
        assert_eq!(shard.stats().hdd_direct_bytes, (64 + 8) * SECTOR_BYTES);
    }

    /// [`MemBackend`] wrapper with a slow `sync` — a real fsync cost, so
    /// concurrent barriers pile up behind the leader's device sync.
    struct SlowSync {
        inner: MemBackend,
        dwell: Duration,
    }

    impl Backend for SlowSync {
        fn write_at(&self, offset: u64, data: &[u8]) -> std::io::Result<()> {
            self.inner.write_at(offset, data)
        }

        fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
            self.inner.read_at(offset, buf)
        }

        fn bytes_written(&self) -> u64 {
            self.inner.bytes_written()
        }

        fn sync(&self) -> std::io::Result<()> {
            std::thread::sleep(self.dwell);
            self.inner.sync()
        }

        fn kind(&self) -> &'static str {
            "slowsync"
        }
    }

    #[test]
    fn concurrent_publishers_share_sync_barriers() {
        // 8 clients publishing to one shard's SSD log where each device
        // sync dwells 10 ms: while one leader's sync runs, the other
        // publishers' barriers queue behind it and the next sync covers
        // them all — group commit must finish with fewer device syncs
        // than acknowledgments (per-record sync makes them equal by
        // construction). Same scheduler-independence idiom as the
        // high-water-mark test above: the dwell is long enough that a
        // non-batching run cannot happen by timing accident.
        let c = cfg(SystemKind::OrangeFsBB, 1 << 16);
        let shard = Arc::new(Shard::new(
            &c,
            Box::new(SlowSync {
                inner: MemBackend::new(SyntheticLatency::ZERO),
                dwell: Duration::from_millis(10),
            }),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        ));
        std::thread::scope(|s| {
            for t in 0..8u32 {
                let shard = Arc::clone(&shard);
                s.spawn(move || {
                    for k in 0..4 {
                        let off = (t as i32 * 4 + k) * 16;
                        shard.submit(&sub(1, off, 16), &gen_payload(1, off, 16, 1)).unwrap();
                    }
                });
            }
        });
        let stats = shard.stats();
        // 32 record publishes + 1 first-touch superblock barrier
        assert_eq!(stats.sync_barriers, 33, "every publish takes exactly one barrier");
        assert!(
            stats.syncs < stats.sync_barriers,
            "concurrent publishers must share syncs: {} syncs for {} barriers",
            stats.syncs,
            stats.sync_barriers
        );
        assert!(stats.writes_per_sync() > 1.0);
    }

    #[test]
    fn rewrite_of_buffered_sector_serves_and_flushes_the_newest_copy() {
        let shard = mem_shard(SystemKind::OrangeFsBB, 4096);
        let s = SECTOR_BYTES as usize;
        // first version buffers in the SSD log
        shard.submit(&sub(1, 0, 64), &gen_payload(1, 0, 64, 1)).unwrap();
        // mid-burst read returns it (SSD hit)
        let mut got = vec![0u8; 64 * s];
        shard.read(1, 0, &mut got).unwrap();
        assert_eq!(got, gen_payload(1, 0, 64, 1));
        // overwrite part of it: the newest copy wins immediately
        shard.submit(&sub(1, 16, 32), &gen_payload(1, 16, 32, 2)).unwrap();
        shard.read(1, 0, &mut got).unwrap();
        assert_eq!(got[..16 * s], gen_payload(1, 0, 64, 1)[..16 * s]);
        assert_eq!(got[16 * s..48 * s], gen_payload(1, 16, 32, 2)[..]);
        assert_eq!(got[48 * s..], gen_payload(1, 0, 64, 1)[48 * s..]);
        // drain synchronously (no flusher thread: run one loop pass by
        // hand via begin_drain + flusher_loop, which exits once clean)
        shard.begin_drain();
        shard.flusher_loop();
        let stats = shard.stats();
        assert_eq!(stats.superseded_bytes, 32 * SECTOR_BYTES, "stale copy skipped");
        assert_eq!(
            stats.flushed_bytes + stats.superseded_bytes,
            stats.ssd_bytes_buffered,
            "conservation: buffered == flushed + superseded"
        );
        // post-drain the HDD holds the merged newest content
        let mut hdd = vec![0u8; 64 * s];
        shard.read_hdd(1, 0, &mut hdd).unwrap();
        assert_eq!(hdd, got, "HDD must match the newest-copy view");
        // and the ownership map is empty: reads now come from HDD
        let mut again = vec![0u8; 64 * s];
        shard.read(1, 0, &mut again).unwrap();
        assert_eq!(again, got);
    }

    #[test]
    fn direct_write_over_buffered_extent_is_absorbed_into_the_log() {
        // the dangerous cross-route direction: data buffered in the SSD
        // log, route flips to HDD, and the same sectors are rewritten.
        // The rewrite must be absorbed into the log, not written direct —
        // otherwise the later flush would resurrect the stale copy.
        let mut c = cfg(SystemKind::SsdupPlus, 4096);
        c.stream_len = 4; // one detection window per 4 sub-requests
        let shard = Shard::new(
            &c,
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        );
        // window 1: sparse offsets -> random (pct 1.0) -> route SSD next
        for off in [0, 10_000, 50_000, 90_000] {
            shard.submit(&sub(1, off, 16), &gen_payload(1, off, 16, 1)).unwrap();
        }
        // window 2: buffered in the log (route is SSD); contiguous run ->
        // pct 0.0 -> route flips back to HDD afterwards
        for k in 0..4 {
            let off = 200_000 + k * 16;
            shard.submit(&sub(1, off, 16), &gen_payload(1, off, 16, 1)).unwrap();
        }
        let mid = shard.stats();
        assert_eq!(mid.ssd_bytes_buffered, 4 * 16 * SECTOR_BYTES, "window 2 buffered");
        assert_eq!(mid.rerouted_writes, 0);
        // route is HDD now; rewrite a buffered extent -> must be absorbed
        shard.submit(&sub(1, 200_016, 16), &gen_payload(1, 200_016, 16, 2)).unwrap();
        let after = shard.stats();
        assert_eq!(after.rerouted_writes, 1, "cross-route rewrite absorbed into the log");
        assert_eq!(after.superseded_bytes, 16 * SECTOR_BYTES, "stale buffered copy superseded");
        assert_eq!(after.hdd_direct_bytes, mid.hdd_direct_bytes, "no direct write raced the flusher");
        // the newest copy is served mid-burst…
        let s = SECTOR_BYTES as usize;
        let mut got = vec![0u8; 16 * s];
        shard.read(1, 200_016, &mut got).unwrap();
        assert_eq!(got, gen_payload(1, 200_016, 16, 2));
        // …and survives the drain byte-exactly
        shard.begin_drain();
        shard.flusher_loop();
        let mut hdd = vec![0u8; 16 * s];
        shard.read_hdd(1, 200_016, &mut hdd).unwrap();
        assert_eq!(hdd, gen_payload(1, 200_016, 16, 2), "flusher must not resurrect the stale copy");
        let end = shard.stats();
        assert_eq!(
            end.flushed_bytes + end.superseded_bytes,
            end.ssd_bytes_buffered,
            "conservation: buffered == flushed + superseded"
        );
    }

    /// [`MemBackend`] wrapper recording the high-water mark of
    /// concurrently in-flight `write_at` calls — a scheduler-independent
    /// proof that device writes overlap (no wall-clock assertions).
    struct ConcurrencyProbe {
        inner: MemBackend,
        in_flight: AtomicU64,
        high_water: Arc<AtomicU64>,
    }

    impl Backend for ConcurrencyProbe {
        fn write_at(&self, offset: u64, data: &[u8]) -> std::io::Result<()> {
            let now = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            self.high_water.fetch_max(now, Ordering::SeqCst);
            let result = self.inner.write_at(offset, data);
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            result
        }

        fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
            self.inner.read_at(offset, buf)
        }

        fn bytes_written(&self) -> u64 {
            self.inner.bytes_written()
        }

        fn sync(&self) -> std::io::Result<()> {
            self.inner.sync()
        }

        fn kind(&self) -> &'static str {
            "probe"
        }
    }

    #[test]
    fn concurrent_clients_overlap_their_device_writes_on_one_shard() {
        // the tentpole property: device I/O happens outside the core
        // lock, so concurrent clients of one shard overlap their device
        // writes. Proven by a concurrency high-water mark on the SSD
        // backend, not wall-clock timing: with a 10 ms synthetic service
        // time, writes from 8 threads dwell in `write_at` long enough
        // that a lock-serialized implementation would record a high
        // water of exactly 1, while the reserve→publish path overlaps
        // them (≥2; in practice near 8).
        let c = cfg(SystemKind::OrangeFsBB, 1 << 16);
        let high_water = Arc::new(AtomicU64::new(0));
        let probe = ConcurrencyProbe {
            inner: MemBackend::new(SyntheticLatency { per_op_us: 10_000, us_per_mib: 0, max_inflight: 0 }),
            in_flight: AtomicU64::new(0),
            high_water: Arc::clone(&high_water),
        };
        let shard = Arc::new(Shard::new(
            &c,
            Box::new(probe),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        ));
        std::thread::scope(|s| {
            for t in 0..8u32 {
                let shard = Arc::clone(&shard);
                s.spawn(move || {
                    let off = t as i32 * 64;
                    shard.submit(&sub(1, off, 64), &gen_payload(1, off, 64, 1)).unwrap();
                });
            }
        });
        assert!(
            high_water.load(Ordering::SeqCst) >= 2,
            "device writes must overlap; a serialized shard records a high water of 1"
        );
        // all eight claims published and readable
        let s_bytes = SECTOR_BYTES as usize;
        let mut got = vec![0u8; 8 * 64 * s_bytes];
        shard.read(1, 0, &mut got).unwrap();
        let mut expect = vec![0u8; 8 * 64 * s_bytes];
        payload::fill_gen(1, 0, 1, &mut expect);
        assert_eq!(got, expect);
        let st = shard.stats();
        assert_eq!(st.ssd_bytes_buffered, got.len() as u64);
        // every record is a header+payload pair, byte-adjacent in the
        // log: the queue coalesces each pair into ONE device write
        assert_eq!(st.io_reqs, 16, "8 records x (header + payload)");
        assert_eq!(st.io_device_writes, 8, "header+payload coalesce into one vectored write");
        // every batch enqueues 2 requests, so the sampled depth at any
        // enqueue is at least 2 — and the high water is scheduler-proof
        assert!(st.io_depth_high_water >= 2, "high water {}", st.io_depth_high_water);
        assert!(st.io_mean_depth >= 2.0, "mean depth {}", st.io_mean_depth);
    }

    #[test]
    fn copy_runs_coalesce_lba_adjacent_extents_with_scattered_slots() {
        // three LBA-adjacent extents whose log slots are out of order:
        // one HDD write, three gathered SSD reads
        let sb = SECTOR_BYTES;
        let extents = vec![(100, 10, 20), (110, 10, 0), (120, 10, 40)];
        let runs = copy_runs(extents, 0, CHUNK_BYTES);
        assert_eq!(runs.len(), 1, "adjacent LBAs coalesce into one run");
        assert_eq!(runs[0].hdd_byte, 100 * sb);
        assert_eq!(runs[0].len, 30 * sb as usize);
        assert_eq!(
            runs[0].segs,
            vec![(20 * sb, 10 * sb as usize), (0, 10 * sb as usize), (40 * sb, 10 * sb as usize)]
        );
        // a gap breaks the run
        let runs = copy_runs(vec![(0, 4, 0), (8, 4, 4)], 0, CHUNK_BYTES);
        assert_eq!(runs.len(), 2);
        // an extent larger than the chunk splits at chunk granularity
        let big = (CHUNK_BYTES / sb as usize) as i64 + 7;
        let runs = copy_runs(vec![(0, big, 0)], 0, CHUNK_BYTES);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].len, CHUNK_BYTES);
        assert_eq!(runs[1].len, 7 * sb as usize);
        assert_eq!(runs[1].hdd_byte, CHUNK_BYTES as u64);
    }

    #[test]
    fn copy_runs_cap_boundary_arithmetic() {
        let sb = SECTOR_BYTES;
        let cap_sectors = (CHUNK_BYTES as u64 / sb) as i64;
        // an extent of exactly chunk_cap is one run — no empty trailer
        let runs = copy_runs(vec![(0, cap_sectors, 0)], 0, CHUNK_BYTES);
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].len, CHUNK_BYTES);
        assert_eq!(runs[0].segs, vec![(0, CHUNK_BYTES)]);
        // one sector over: split into [cap, 1] with exact boundaries on
        // both the HDD side and the log side
        let runs = copy_runs(vec![(0, cap_sectors + 1, 0)], 0, CHUNK_BYTES);
        assert_eq!(runs.len(), 2);
        assert_eq!((runs[0].len, runs[1].len), (CHUNK_BYTES, sb as usize));
        assert_eq!(runs[1].hdd_byte, CHUNK_BYTES as u64);
        assert_eq!(runs[1].segs, vec![(CHUNK_BYTES as u64, sb as usize)]);
        // an adjacent extent fills the run exactly to the cap, never
        // past it; the remainder starts its own run at the boundary
        let runs = copy_runs(
            vec![(0, cap_sectors - 4, 0), (cap_sectors - 4, 8, cap_sectors - 4)],
            0,
            CHUNK_BYTES,
        );
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].len, CHUNK_BYTES, "first run fills exactly to the cap");
        assert_eq!(
            runs[0].segs,
            vec![
                (0, ((cap_sectors - 4) * sb as i64) as usize),
                (((cap_sectors - 4) * sb as i64) as u64, 4 * sb as usize),
            ]
        );
        assert_eq!(runs[1].hdd_byte, CHUNK_BYTES as u64);
        assert_eq!(runs[1].len, 4 * sb as usize);
        assert_eq!(runs[1].segs, vec![(CHUNK_BYTES as u64, 4 * sb as usize)]);
    }

    #[test]
    fn oversized_extent_flushes_as_separate_capped_device_writes() {
        // five contiguous 512-sector records merge into one 2560-sector
        // extent — larger than the 2048-sector copy chunk. The flusher
        // must issue the split sub-runs as separate device submissions:
        // batched together, the queue's byte-adjacent coalescing would
        // recombine them into a single oversized HDD write, defeating
        // the cap the split exists to enforce.
        let shard = mem_shard(SystemKind::OrangeFsBB, 8192);
        for k in 0..5 {
            let off = k * 512;
            shard.submit(&sub(1, off, 512), &gen_payload(1, off, 512, 1)).unwrap();
        }
        shard.begin_drain();
        shard.flusher_loop();
        let stats = shard.stats();
        assert_eq!(stats.flush_runs, 2, "2560 sectors split at the 2048-sector chunk cap");
        // OrangeFsBB routes nothing direct, so the HDD queue carries
        // exactly the flusher's copy runs
        let hdd = shard.hdd_q.stats();
        assert_eq!(hdd.reqs, 2, "one request per copy run");
        assert_eq!(hdd.device_writes, 2, "sub-runs of an over-cap extent must not recombine");
        let mut got = vec![0u8; 2560 * SECTOR_BYTES as usize];
        shard.read_hdd(1, 0, &mut got).unwrap();
        assert_eq!(got, gen_payload(1, 0, 2560, 1), "split flush must stay byte-exact");
    }

    #[test]
    fn hot_deferral_concentrates_supersession_in_the_buffer() {
        // each region holds exactly four 17-sector records; the defer
        // window is effectively unbounded so only the test's own events
        // (supersession emptying the region, then the drain) end it
        let mut c = cfg(SystemKind::OrangeFsBB, 136);
        c.hot_defer_window = Duration::from_secs(3600);
        let shard = Arc::new(Shard::new(
            &c,
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        ));
        // fill region 0 with two extents and their immediate rewrites:
        // every surviving extent is hot (heat 1) when the region queues
        shard.submit(&sub(1, 0, 16), &gen_payload(1, 0, 16, 1)).unwrap();
        shard.submit(&sub(1, 16, 16), &gen_payload(1, 16, 16, 1)).unwrap();
        shard.submit(&sub(1, 0, 16), &gen_payload(1, 0, 16, 2)).unwrap();
        shard.submit(&sub(1, 16, 16), &gen_payload(1, 16, 16, 2)).unwrap();
        let flusher = Arc::clone(&shard);
        let handle = std::thread::spawn(move || flusher.flusher_loop());
        // the flusher takes region 0 up (32 queued sectors, all hot)
        // and defers instead of copying
        let t0 = Instant::now();
        let deadline = Duration::from_secs(10);
        while shard.stats().hot_defers == 0 {
            assert!(t0.elapsed() < deadline, "flusher never deferred the hot region");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(shard.stats().flushed_bytes, 0, "deferral held the copy runs back");
        // rewrite the queued extents while the flusher waits: the new
        // copies land in region 1 and supersede the queued ones in the
        // buffer — the HDD never sees generation 2
        shard.submit(&sub(1, 0, 16), &gen_payload(1, 0, 16, 3)).unwrap();
        shard.submit(&sub(1, 16, 16), &gen_payload(1, 16, 16, 3)).unwrap();
        shard.begin_drain();
        handle.join().unwrap();
        let stats = shard.stats();
        assert_eq!(
            stats.superseded_at_flush_bytes,
            32 * SECTOR_BYTES,
            "both queued extents superseded while the flusher deferred"
        );
        // region 0 queued 32 sectors; region 1's drain flush queued the
        // 32 replacement sectors (none of which superseded in queue)
        assert_eq!(stats.queued_for_flush_bytes, 64 * SECTOR_BYTES);
        assert!((stats.superseded_at_flush() - 0.5).abs() < 1e-9);
        assert!(stats.hot_defers >= 1);
        assert_eq!(stats.flush_token_waits, 0, "uncoordinated shard takes no tokens");
        assert_eq!(
            stats.flushed_bytes + stats.superseded_bytes,
            stats.ssd_bytes_buffered,
            "conservation: buffered == flushed + superseded"
        );
        // the drain settles generation 3 byte-exactly
        let mut hdd = vec![0u8; 32 * SECTOR_BYTES as usize];
        shard.read_hdd(1, 0, &mut hdd).unwrap();
        assert_eq!(hdd, gen_payload(1, 0, 32, 3));
    }

    #[test]
    fn coordinated_flush_books_token_stats_and_stage() {
        let co = Arc::new(FlushCoordinator::new(1, 1));
        let shard = Shard::new(
            &cfg(SystemKind::OrangeFsBB, 4096),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
        )
        .with_coordinator(Arc::clone(&co));
        shard.submit(&sub(1, 0, 64), &gen_payload(1, 0, 64, 1)).unwrap();
        shard.begin_drain();
        shard.flusher_loop();
        let stats = shard.stats();
        assert_eq!(stats.flush_token_waits, 1, "one token per flush cycle");
        assert_eq!(co.holder_count(), 0, "token released after the covering barrier");
        assert_eq!(co.beyond_budget_grants(), 0);
        // the wait is booked even when the grant was immediate, so a
        // coordinated run always carries the stage
        let lat = shard.stage_latency();
        assert_eq!(lat.get(Stage::FlushTokenWait).count(), 1);
        // the settle phase refreshed the load map: drained log = cold
        assert_eq!(co.occupancy_of(0), 0.0);
        let mut hdd = vec![0u8; 64 * SECTOR_BYTES as usize];
        shard.read_hdd(1, 0, &mut hdd).unwrap();
        assert_eq!(hdd, gen_payload(1, 0, 64, 1));
    }

    #[test]
    fn recover_replays_a_dirty_log_and_preserves_rewrites() {
        use crate::live::backend::MemStore;
        // build a shard over shared stores, buffer data (including a
        // rewrite), then abandon it without any drain — the crash
        let ssd_store = MemStore::new(false);
        let hdd_store = MemStore::new(false);
        let c = cfg(SystemKind::OrangeFsBB, 4096);
        {
            let shard = Shard::new(
                &c,
                Box::new(MemBackend::over(Arc::clone(&ssd_store), SyntheticLatency::ZERO)),
                Box::new(MemBackend::over(Arc::clone(&hdd_store), SyntheticLatency::ZERO)),
            );
            shard.submit(&sub(1, 0, 64), &gen_payload(1, 0, 64, 1)).unwrap();
            shard.submit(&sub(1, 16, 32), &gen_payload(1, 16, 32, 2)).unwrap(); // rewrite
            shard.submit(&sub(2, 0, 8), &gen_payload(2, 0, 8, 1)).unwrap(); // second file
            // no drain, no shutdown: the shard is simply dropped
        }
        let (shard, rec) = Shard::recover(
            &c,
            Box::new(MemBackend::over(Arc::clone(&ssd_store), SyntheticLatency::ZERO)),
            Box::new(MemBackend::over(Arc::clone(&hdd_store), SyntheticLatency::ZERO)),
        )
        .expect("recover");
        assert!(!rec.clean);
        assert_eq!(rec.records_replayed, 3);
        assert_eq!(rec.torn_discarded, 2, "one hunted zero stretch per region log");
        assert_eq!(rec.orphaned, 0);
        assert_eq!(rec.files_restored, 2, "file table came back from the superblock");
        assert_eq!(rec.bytes_recovered, (64 + 32 + 8) * SECTOR_BYTES);
        // the recovered view serves the newest copies mid-burst…
        let s = SECTOR_BYTES as usize;
        let mut got = vec![0u8; 64 * s];
        shard.read(1, 0, &mut got).unwrap();
        assert_eq!(got[..16 * s], gen_payload(1, 0, 64, 1)[..16 * s]);
        assert_eq!(got[16 * s..48 * s], gen_payload(1, 16, 32, 2)[..]);
        assert_eq!(got[48 * s..], gen_payload(1, 0, 64, 1)[48 * s..]);
        let mut f2 = vec![0u8; 8 * s];
        shard.read(2, 0, &mut f2).unwrap();
        assert_eq!(f2, gen_payload(2, 0, 8, 1));
        // …and they drain byte-exactly through the normal flush path,
        // with conservation intact (recovered bytes count as buffered,
        // the replay-superseded rewrite as superseded)
        shard.begin_drain();
        shard.flusher_loop();
        let mut hdd = vec![0u8; 64 * s];
        shard.read_hdd(1, 0, &mut hdd).unwrap();
        assert_eq!(hdd, got, "recovered data must settle byte-exactly");
        let st = shard.stats();
        assert_eq!(st.superseded_bytes, 32 * SECTOR_BYTES, "replay supersession booked");
        assert_eq!(st.flushed_bytes + st.superseded_bytes, st.ssd_bytes_buffered);
    }

    #[test]
    fn clean_shutdown_recovers_without_scanning() {
        use crate::live::backend::MemStore;
        let ssd_store = MemStore::new(false);
        let hdd_store = MemStore::new(false);
        let c = cfg(SystemKind::OrangeFsBB, 4096);
        {
            let shard = Shard::new(
                &c,
                Box::new(MemBackend::over(Arc::clone(&ssd_store), SyntheticLatency::ZERO)),
                Box::new(MemBackend::over(Arc::clone(&hdd_store), SyntheticLatency::ZERO)),
            );
            shard.submit(&sub(1, 0, 64), &gen_payload(1, 0, 64, 1)).unwrap();
            shard.begin_drain();
            shard.flusher_loop(); // drain to HDD
            shard.sync();
            shard.finalize_clean();
        }
        let (shard, rec) = Shard::recover(
            &c,
            Box::new(MemBackend::over(Arc::clone(&ssd_store), SyntheticLatency::ZERO)),
            Box::new(MemBackend::over(Arc::clone(&hdd_store), SyntheticLatency::ZERO)),
        )
        .expect("recover");
        assert!(rec.clean);
        assert_eq!(rec.sectors_scanned, 0, "clean reopen must not scan the log");
        assert_eq!(rec.records_replayed, 0);
        assert_eq!(rec.files_restored, 1);
        // the drained data reads back from the HDD through the restored
        // file table
        let mut got = vec![0u8; 64 * SECTOR_BYTES as usize];
        shard.read(1, 0, &mut got).unwrap();
        assert_eq!(got, gen_payload(1, 0, 64, 1));
        // and new writes work: their sequences resume past the old ones
        shard.submit(&sub(1, 100, 8), &gen_payload(1, 100, 8, 3)).unwrap();
        let mut more = vec![0u8; 8 * SECTOR_BYTES as usize];
        shard.read(1, 100, &mut more).unwrap();
        assert_eq!(more, gen_payload(1, 100, 8, 3));
    }

    #[test]
    fn recovery_after_clean_reopen_sees_new_writes() {
        use crate::live::backend::MemStore;
        // clean shutdown, reopen, write WITHOUT another shutdown, crash:
        // the dirty mark written at reopen must force a scan that finds
        // the new records — a stale clean flag here would lose them
        let ssd_store = MemStore::new(false);
        let hdd_store = MemStore::new(false);
        let c = cfg(SystemKind::OrangeFsBB, 4096);
        {
            let shard = Shard::new(
                &c,
                Box::new(MemBackend::over(Arc::clone(&ssd_store), SyntheticLatency::ZERO)),
                Box::new(MemBackend::over(Arc::clone(&hdd_store), SyntheticLatency::ZERO)),
            );
            shard.submit(&sub(1, 0, 16), &gen_payload(1, 0, 16, 1)).unwrap();
            shard.begin_drain();
            shard.flusher_loop();
            shard.sync();
            shard.finalize_clean();
        }
        {
            let (shard, rec) = Shard::recover(
                &c,
                Box::new(MemBackend::over(Arc::clone(&ssd_store), SyntheticLatency::ZERO)),
                Box::new(MemBackend::over(Arc::clone(&hdd_store), SyntheticLatency::ZERO)),
            )
            .expect("first recover");
            assert!(rec.clean);
            shard.submit(&sub(1, 50, 8), &gen_payload(1, 50, 8, 2)).unwrap();
            // crash again: drop without shutdown
        }
        let (shard, rec) = Shard::recover(
            &c,
            Box::new(MemBackend::over(Arc::clone(&ssd_store), SyntheticLatency::ZERO)),
            Box::new(MemBackend::over(Arc::clone(&hdd_store), SyntheticLatency::ZERO)),
        )
        .expect("second recover");
        assert!(!rec.clean, "the reopen marked the superblock dirty");
        assert_eq!(rec.records_replayed, 1, "the post-reopen write survives");
        let mut got = vec![0u8; 8 * SECTOR_BYTES as usize];
        shard.read(1, 50, &mut got).unwrap();
        assert_eq!(got, gen_payload(1, 50, 8, 2));
        // the pre-shutdown data is still on the HDD
        let mut old = vec![0u8; 16 * SECTOR_BYTES as usize];
        shard.read(1, 0, &mut old).unwrap();
        assert_eq!(old, gen_payload(1, 0, 16, 1));
    }

    #[test]
    fn flusher_coalescing_survives_fragmentation_byte_exactly() {
        // buffer a contiguous range, then punch rewrites into it so the
        // region's extents fragment; the drain must still produce the
        // newest merged contents, with fewer copy runs than extents
        let shard = mem_shard(SystemKind::OrangeFsBB, 8192);
        shard.submit(&sub(1, 0, 256), &gen_payload(1, 0, 256, 1)).unwrap();
        for k in 0..8 {
            let off = k * 32 + 8;
            shard.submit(&sub(1, off, 8), &gen_payload(1, off, 8, 2)).unwrap();
        }
        let s = SECTOR_BYTES as usize;
        let mut expect = vec![0u8; 256 * s];
        payload::fill_gen(1, 0, 1, &mut expect);
        for k in 0..8usize {
            let off = k * 32 + 8;
            let mut v2 = vec![0u8; 8 * s];
            payload::fill_gen(1, off as i64, 2, &mut v2);
            expect[off * s..(off + 8) * s].copy_from_slice(&v2);
        }
        shard.begin_drain();
        shard.flusher_loop();
        let mut hdd = vec![0u8; 256 * s];
        shard.read_hdd(1, 0, &mut hdd).unwrap();
        assert_eq!(hdd, expect, "fragmented flush must merge to the newest view");
        let stats = shard.stats();
        // 256 sectors of LBA-contiguous newest data: the whole region
        // flushes as ONE coalesced run even though the map holds 17
        // fragments (8 rewrites split the original into 9 + 8 pieces)
        assert_eq!(stats.flush_runs, 1, "adjacent extents coalesce into one HDD write");
        assert_eq!(
            stats.flushed_bytes + stats.superseded_bytes,
            stats.ssd_bytes_buffered,
            "conservation: buffered == flushed + superseded"
        );
    }

    #[test]
    fn empty_shard_stats_keep_every_ratio_finite() {
        // a shard that never saw a request (or a report over zero
        // shards) must answer 0.0 from every derived ratio — never NaN
        // or infinity from a zero denominator
        let stats = ShardStats::default();
        assert_eq!(stats.mean_percentage(), 0.0);
        assert_eq!(stats.writes_per_sync(), 0.0);
        assert_eq!(stats.flush_duty_cycle(), 0.0);
        assert_eq!(stats.superseded_at_flush(), 0.0);
        assert!(stats.mean_percentage().is_finite());
        assert!(stats.writes_per_sync().is_finite());
        assert!(stats.flush_duty_cycle().is_finite());
        assert!(stats.superseded_at_flush().is_finite());
        assert_eq!(ssd_ratio(&[]), 0.0);
        assert_eq!(ssd_ratio(&[stats]), 0.0);
        // a freshly constructed shard reports the same zeros
        let shard = mem_shard(SystemKind::SsdupPlus, 4096);
        let live = shard.stats();
        assert_eq!(live.mean_percentage(), 0.0);
        assert_eq!(live.writes_per_sync(), 0.0);
        assert_eq!(live.flush_duty_cycle(), 0.0);
        assert_eq!(live.superseded_at_flush(), 0.0);
    }

    /// [`MemBackend`] wrapper whose writes block on a shared gate while
    /// it is armed — holds a direct HDD write in flight for as long as
    /// the test wants, so the traffic-aware pause is driven
    /// deterministically instead of raced against wall-clock timing.
    struct StallingBackend {
        inner: MemBackend,
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl Backend for StallingBackend {
        fn write_at(&self, offset: u64, data: &[u8]) -> std::io::Result<()> {
            let (armed, cv) = &*self.gate;
            let mut held = armed.lock().unwrap();
            while *held {
                held = cv.wait(held).unwrap();
            }
            drop(held);
            self.inner.write_at(offset, data)
        }

        fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
            self.inner.read_at(offset, buf)
        }

        fn bytes_written(&self) -> u64 {
            self.inner.bytes_written()
        }

        fn sync(&self) -> std::io::Result<()> {
            self.inner.sync()
        }

        fn kind(&self) -> &'static str {
            "stalling"
        }
    }

    #[test]
    fn traffic_gate_pause_books_both_sides_of_the_duty_cycle() {
        // each region holds exactly four 16-sector records (16 payload +
        // 1 header sectors each): 2 * 4 * 17 = 136
        let mut c = cfg(SystemKind::SsdupPlus, 136);
        c.stream_len = 4;
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let shard = Arc::new(Shard::new(
            &c,
            Box::new(MemBackend::new(SyntheticLatency::ZERO)),
            Box::new(StallingBackend {
                inner: MemBackend::new(SyntheticLatency::ZERO),
                gate: Arc::clone(&gate),
            }),
        ));
        // window 1: sparse -> pct 1.0 -> route flips to SSD. These four
        // go direct to the (not yet armed) HDD.
        for off in [0, 10_000, 50_000, 90_000] {
            shard.submit(&sub(1, off, 16), &gen_payload(1, off, 16, 1)).unwrap();
        }
        // window 2: contiguous and SSD-routed — fills region 0 exactly,
        // and detects as pct 0.0 (< pause_below), flipping the route
        // back to HDD afterwards
        for k in 0..4 {
            let off = 500_000 + k * 16;
            shard.submit(&sub(1, off, 16), &gen_payload(1, off, 16, 1)).unwrap();
        }
        // rewrite of a buffered extent: absorbed into the log, lands in
        // region 1, and thereby queues the full region 0 for the flusher
        shard.submit(&sub(1, 500_016, 16), &gen_payload(1, 500_016, 16, 2)).unwrap();
        assert_eq!(shard.stats().rerouted_writes, 1, "rewrite absorbed into the log");
        // arm the gate, then hold one direct HDD write in flight
        *gate.0.lock().unwrap() = true;
        std::thread::scope(|s| {
            let writer = Arc::clone(&shard);
            s.spawn(move || {
                writer.submit(&sub(2, 0, 16), &gen_payload(2, 0, 16, 1)).unwrap();
            });
            let t0 = Instant::now();
            let deadline = Duration::from_secs(10);
            while shard.direct_inflight.load(Ordering::Acquire) == 0 {
                assert!(t0.elapsed() < deadline, "direct write never reached the device");
                std::thread::sleep(Duration::from_millis(1));
            }
            // last stream pct 0.0 < pause_below, a direct write in
            // flight, not drained: the flusher must pause before
            // touching region 0
            let flusher = Arc::clone(&shard);
            s.spawn(move || flusher.flusher_loop());
            while shard.stats().flush_pauses == 0 {
                assert!(t0.elapsed() < deadline, "flusher never paused");
                std::thread::sleep(Duration::from_millis(1));
            }
            // let the pause accrue measurable wall time, then release
            std::thread::sleep(Duration::from_millis(5));
            {
                let (armed, cv) = &*gate;
                *armed.lock().unwrap() = false;
                cv.notify_all();
            }
            while shard.stats().flush_run_us == 0 {
                assert!(t0.elapsed() < deadline, "flusher never resumed after the release");
                std::thread::sleep(Duration::from_millis(1));
            }
            // drain region 1 so the flusher loop exits and the scope
            // can join
            shard.begin_drain();
        });
        let stats = shard.stats();
        assert!(stats.flush_pauses >= 1, "the gate must have paused at least once");
        assert!(stats.flush_pause_us > 0, "paused wall time must be booked");
        assert!(stats.flush_run_us > 0, "copy wall time must be booked");
        let duty = stats.flush_duty_cycle();
        assert!(
            duty > 0.0 && duty < 1.0,
            "duty cycle must reflect both sides of the gate: {duty}"
        );
        // both sides are also attributed as latency stages
        let lat = shard.stage_latency();
        assert!(lat.get(Stage::FlushPause).count() >= 1);
        assert!(lat.get(Stage::FlushRun).count() >= 1);
    }
}
