//! One live burst-buffer shard: the real-time analogue of the simulator's
//! per-I/O-node server.
//!
//! A shard owns a detector + routing policy + two-region pipeline plus an
//! SSD/HDD backend pair, and splits work across two lock domains:
//!
//! * the **core** mutex guards all coordination state (pipeline metadata,
//!   stream grouper, policy, file table, stats). Ingest holds it while
//!   routing, appending to the SSD log, and feeding the detector — a
//!   shard's ingest is serial by design (the scaling unit is the shard);
//! * the **device** mutexes (`ssd`, `hdd`) guard the backends alone, so
//!   the background flusher moves region bytes SSD→HDD *without* the core
//!   lock — buffering and flushing overlap, which is the whole point of
//!   the paper's two-region pipeline (§2.4).
//!
//! Lock order is always core → device; the flusher takes devices only.
//! Backpressure is physical: a write that finds both regions unavailable
//! blocks its client on a condvar until the flusher frees a region —
//! the paper's "the system waits until a region becomes empty".

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::buffer::{BufferOutcome, FlushStrategy, Pipeline};
use crate::detector::native::NativeDetector;
use crate::detector::stream::StreamGrouper;
use crate::device::SeekModel;
use crate::fs::{FileTable, SubRequest};
use crate::live::backend::Backend;
use crate::redirector::{AdaptivePolicy, AlwaysHdd, AlwaysSsd, RoutePolicy, WatermarkPolicy};
use crate::server::config::SystemKind;
use crate::types::{Route, SECTOR_BYTES};

/// Per-shard configuration (the engine derives one from its `LiveConfig`).
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    pub system: SystemKind,
    /// whole-SSD budget in sectors; each pipeline region gets half
    pub ssd_capacity_sectors: i64,
    pub stream_len: usize,
    pub pause_below: f32,
    pub history: usize,
    /// re-check interval for paused flushes and condvar waits
    pub flush_check: Duration,
    pub seek: SeekModel,
}

/// Counters a shard accumulates; snapshot via [`Shard::stats`].
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub bytes_in: u64,
    pub ssd_bytes_buffered: u64,
    pub hdd_direct_bytes: u64,
    pub flushed_bytes: u64,
    pub streams: u64,
    pub flushes: u64,
    pub flush_pauses: u64,
    pub flush_pause_us: u64,
    pub blocked_waits: u64,
    pub pct_sum: f64,
}

impl ShardStats {
    /// Mean random percentage over this shard's completed streams.
    pub fn mean_percentage(&self) -> f64 {
        if self.streams == 0 {
            0.0
        } else {
            self.pct_sum / self.streams as f64
        }
    }
}

/// Fraction of ingested bytes that went through the SSD buffer, over a
/// set of shard stats (shared by the engine and the load-gen report).
pub fn ssd_ratio(stats: &[ShardStats]) -> f64 {
    let total: u64 = stats.iter().map(|s| s.bytes_in).sum();
    let ssd: u64 = stats.iter().map(|s| s.ssd_bytes_buffered).sum();
    if total == 0 {
        0.0
    } else {
        ssd as f64 / total as f64
    }
}

/// Everything guarded by the core mutex.
struct ShardCore {
    files: FileTable,
    grouper: StreamGrouper,
    detector: NativeDetector,
    policy: Box<dyn RoutePolicy + Send>,
    route: Route,
    pipeline: Pipeline,
    drained: bool,
    shutdown: bool,
    /// set by the flusher on a backend I/O error, with the cause; waiters
    /// surface it instead of polling a pipeline that can never drain
    failed: Option<String>,
    stats: ShardStats,
}

pub struct Shard {
    core: Mutex<ShardCore>,
    ssd: Mutex<Box<dyn Backend>>,
    hdd: Mutex<Box<dyn Backend>>,
    /// signalled when the flusher frees a region (blocked ingest, drain)
    space: Condvar,
    /// signalled when flush work appears or the pause gate may open
    work: Condvar,
    /// direct-to-HDD writes currently in flight (traffic-aware gate input)
    direct_inflight: AtomicU64,
    strategy: FlushStrategy,
    half_sectors: i64,
    use_ssd: bool,
    flush_check: Duration,
}

fn policy_for(system: SystemKind, history: usize) -> Box<dyn RoutePolicy + Send> {
    match system {
        SystemKind::OrangeFs => Box::new(AlwaysHdd),
        SystemKind::OrangeFsBB => Box::new(AlwaysSsd),
        SystemKind::Ssdup => Box::<WatermarkPolicy>::default(),
        SystemKind::SsdupPlus => Box::new(AdaptivePolicy::new(history)),
    }
}

impl Shard {
    pub fn new(cfg: &ShardConfig, ssd: Box<dyn Backend>, hdd: Box<dyn Backend>) -> Self {
        let policy = policy_for(cfg.system, cfg.history);
        let route = policy.initial_route();
        let strategy = match cfg.system {
            SystemKind::SsdupPlus => FlushStrategy::TrafficAware { pause_below: cfg.pause_below },
            _ => FlushStrategy::Immediate,
        };
        Shard {
            core: Mutex::new(ShardCore {
                files: FileTable::new(),
                grouper: StreamGrouper::new(cfg.stream_len),
                detector: NativeDetector::new(cfg.seek),
                policy,
                route,
                pipeline: Pipeline::new(cfg.ssd_capacity_sectors),
                drained: false,
                shutdown: false,
                failed: None,
                stats: ShardStats::default(),
            }),
            ssd: Mutex::new(ssd),
            hdd: Mutex::new(hdd),
            space: Condvar::new(),
            work: Condvar::new(),
            direct_inflight: AtomicU64::new(0),
            strategy,
            half_sectors: cfg.ssd_capacity_sectors / 2,
            use_ssd: cfg.system.uses_ssd(),
            flush_check: cfg.flush_check,
        }
    }

    /// Ingest one sub-request with its payload. Blocks (physical
    /// backpressure) while both pipeline regions are unavailable.
    pub fn submit(&self, sub: &SubRequest, payload: &[u8]) {
        let size = sub.size as i64;
        debug_assert_eq!(payload.len() as u64, sub.bytes());
        let mut direct_dest: Option<u64> = None;
        {
            let mut core = self.core.lock().unwrap();
            let lba = core.files.lba(sub.parent.file, sub.local_offset);
            debug_assert!(lba <= i32::MAX as i64, "LBA exceeds detector i32 space");
            core.stats.bytes_in += payload.len() as u64;
            // a sub-request larger than a region could never buffer:
            // route it directly to HDD (safety valve)
            let route = if !self.use_ssd || size > self.half_sectors {
                Route::Hdd
            } else {
                core.route
            };
            match route {
                Route::Hdd => {
                    core.stats.hdd_direct_bytes += payload.len() as u64;
                    // counted under the core lock so the flusher's gate
                    // sees the direct traffic the moment it is decided
                    self.direct_inflight.fetch_add(1, Ordering::SeqCst);
                    direct_dest = Some(lba as u64 * SECTOR_BYTES);
                }
                Route::Ssd => loop {
                    match core.pipeline.buffer(sub.parent.file, sub.local_offset as i64, size) {
                        BufferOutcome::Buffered { region, ssd_offset } => {
                            if let Err(e) = self.write_ssd(region, ssd_offset, payload) {
                                self.fail_and_panic(core, format!("ssd backend write: {e}"));
                            }
                            core.stats.ssd_bytes_buffered += payload.len() as u64;
                            break;
                        }
                        BufferOutcome::BufferedAndFull { region, ssd_offset, .. } => {
                            if let Err(e) = self.write_ssd(region, ssd_offset, payload) {
                                self.fail_and_panic(core, format!("ssd backend write: {e}"));
                            }
                            core.stats.ssd_bytes_buffered += payload.len() as u64;
                            self.work.notify_all(); // a region is ready to flush
                            break;
                        }
                        BufferOutcome::Blocked => {
                            // "the system waits until a region becomes
                            // empty" — closed-loop backpressure
                            core.stats.blocked_waits += 1;
                            self.work.notify_all();
                            core = self.space.wait_timeout(core, self.flush_check).unwrap().0;
                            if let Some(msg) = core.failed.clone() {
                                drop(core); // release before panicking: no poisoning
                                panic!("shard failed while blocked on a region: {msg}");
                            }
                            if core.shutdown {
                                return;
                            }
                        }
                    }
                },
            }
            // server-side detection feeds on the post-striping disk address
            if let Some(stream) = core.grouper.push_parts(sub.parent.app, lba as i32, sub.size) {
                let det = core.detector.detect(&stream.reqs);
                core.stats.streams += 1;
                core.stats.pct_sum += det.percentage as f64;
                core.route = core.policy.on_stream(&det);
                // a route change can unpause the traffic-aware flusher
                self.work.notify_all();
            }
        }
        if let Some(dest) = direct_dest {
            let wrote = self.hdd.lock().unwrap().write_at(dest, payload);
            if self.direct_inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
                // direct traffic ebbed: the traffic-aware gate may open
                self.work.notify_all();
            }
            if let Err(e) = wrote {
                // no lock is held here, so the panic poisons nothing
                self.fail(format!("hdd backend write: {e}"));
                panic!("shard hdd write failed: {e}");
            }
        }
    }

    /// Append `payload` into the SSD log at the pipeline-assigned slot.
    /// Called with the core lock held (core → device order), which is what
    /// guarantees the flusher's `drain_flushing` only ever sees regions
    /// whose bytes are fully on the backend.
    fn write_ssd(&self, region: usize, ssd_offset: i64, payload: &[u8]) -> std::io::Result<()> {
        let base = region as u64 * self.half_sectors as u64 * SECTOR_BYTES;
        let mut ssd = self.ssd.lock().unwrap();
        ssd.write_at(base + ssd_offset as u64 * SECTOR_BYTES, payload)
    }

    /// Record a failure, release the core lock, wake all waiters, and
    /// panic in the calling thread — without poisoning any mutex.
    fn fail_and_panic(&self, mut core: std::sync::MutexGuard<'_, ShardCore>, msg: String) -> ! {
        core.failed.get_or_insert(msg.clone());
        drop(core);
        self.space.notify_all();
        self.work.notify_all();
        panic!("shard failed: {msg}");
    }

    /// Read back `buf.len()` bytes the shard's HDD holds for
    /// `(file, local_offset)` — verification path.
    pub fn read_hdd(&self, file: u32, local_offset: i32, buf: &mut [u8]) {
        let lba = self.core.lock().unwrap().files.lba(file, local_offset);
        let read = self.hdd.lock().unwrap().read_at(lba as u64 * SECTOR_BYTES, buf);
        // result is inspected after the guard dropped: no poisoning
        read.expect("hdd backend read");
    }

    pub fn stats(&self) -> ShardStats {
        self.core.lock().unwrap().stats.clone()
    }

    /// Background flusher: runs on its own thread until shutdown, or until
    /// the shard is drained clean.
    pub(crate) fn flusher_loop(&self) {
        // reused bounded copy buffer: one allocation for the thread's life
        let mut chunk = vec![0u8; 1 << 20];
        loop {
            // ---- acquire the next region to flush (or exit) ----
            let resolved: Vec<(u64, u64, usize)> = {
                let mut core = self.core.lock().unwrap();
                let region = loop {
                    if core.shutdown || core.failed.is_some() {
                        return;
                    }
                    if core.drained
                        && core.pipeline.flushing_region().is_none()
                        && core.pipeline.flush_pending.is_empty()
                    {
                        core.pipeline.enqueue_residual_flush();
                    }
                    if let Some(r) = core.pipeline.next_flush() {
                        break r;
                    }
                    if core.drained && !core.pipeline.dirty() {
                        self.space.notify_all();
                        return;
                    }
                    core = self.work.wait_timeout(core, self.flush_check).unwrap().0;
                };
                let region_base = region as u64 * self.half_sectors as u64 * SECTOR_BYTES;
                let extents = core.pipeline.drain_flushing();
                core.stats.flushes += 1;
                // resolve byte addresses now: the FileTable lives in core
                extents
                    .iter()
                    .map(|e| {
                        let lba = core.files.lba(e.file, e.orig_offset as i32);
                        (
                            region_base + e.ssd_offset as u64 * SECTOR_BYTES,
                            lba as u64 * SECTOR_BYTES,
                            (e.size as u64 * SECTOR_BYTES) as usize,
                        )
                    })
                    .collect()
            };

            // ---- gate + copy, without the core lock ----
            let mut moved = 0u64;
            for (ssd_byte, hdd_byte, len) in resolved {
                if !self.gate_extent() {
                    return; // shutdown while paused
                }
                let mut done = 0usize;
                while done < len {
                    let take = chunk.len().min(len - done);
                    let read =
                        self.ssd.lock().unwrap().read_at(ssd_byte + done as u64, &mut chunk[..take]);
                    if let Err(e) = read {
                        self.fail(format!("flusher: ssd backend read: {e}"));
                        return;
                    }
                    let write =
                        self.hdd.lock().unwrap().write_at(hdd_byte + done as u64, &chunk[..take]);
                    if let Err(e) = write {
                        self.fail(format!("flusher: hdd backend write: {e}"));
                        return;
                    }
                    done += take;
                }
                moved += len as u64;
            }

            // ---- complete: free the region, wake blocked ingest ----
            {
                let mut core = self.core.lock().unwrap();
                core.pipeline.flush_done();
                core.stats.flushed_bytes += moved;
            }
            self.space.notify_all();
        }
    }

    /// Traffic-aware pause gate, re-evaluated per flush extent like the
    /// DES flusher. Returns false only on shutdown or shard failure.
    fn gate_extent(&self) -> bool {
        let mut core = self.core.lock().unwrap();
        let mut paused_at: Option<Instant> = None;
        loop {
            if core.shutdown || core.failed.is_some() {
                return false;
            }
            let pct = core.policy.current_percentage().unwrap_or(1.0);
            let direct = self.direct_inflight.load(Ordering::SeqCst) > 0;
            if self.strategy.allow_flush(pct, direct, core.drained) {
                break;
            }
            if paused_at.is_none() {
                paused_at = Some(Instant::now());
                core.stats.flush_pauses += 1;
            }
            core = self.work.wait_timeout(core, self.flush_check).unwrap().0;
        }
        if let Some(t0) = paused_at {
            core.stats.flush_pause_us += t0.elapsed().as_micros() as u64;
        }
        true
    }

    /// All producers have finished: flush any partial detection stream and
    /// queue the residual region.
    pub(crate) fn begin_drain(&self) {
        {
            let mut core = self.core.lock().unwrap();
            core.drained = true;
            if let Some(stream) = core.grouper.flush_partial() {
                let det = core.detector.detect(&stream.reqs);
                core.stats.streams += 1;
                core.stats.pct_sum += det.percentage as f64;
                core.route = core.policy.on_stream(&det);
            }
            core.pipeline.enqueue_residual_flush();
        }
        self.work.notify_all();
    }

    /// Record a fatal flusher error and wake every waiter so it surfaces
    /// in a caller thread instead of hanging the engine.
    fn fail(&self, msg: String) {
        self.core.lock().unwrap().failed.get_or_insert(msg);
        self.space.notify_all();
        self.work.notify_all();
    }

    /// Block until every buffered byte has reached the HDD backend.
    /// Panics (in the caller's thread) if the flusher hit a backend I/O
    /// error — buffered data can then never drain.
    pub(crate) fn wait_drained(&self) {
        let mut core = self.core.lock().unwrap();
        while core.pipeline.dirty() {
            if let Some(msg) = core.failed.clone() {
                drop(core); // release before panicking: no poisoning
                panic!("shard failed before drain completed: {msg}");
            }
            core = self.space.wait_timeout(core, self.flush_check).unwrap().0;
        }
    }

    /// Flush both backends to durable storage.
    pub(crate) fn sync(&self) {
        let ssd = self.ssd.lock().unwrap().sync();
        ssd.expect("ssd sync");
        let hdd = self.hdd.lock().unwrap().sync();
        hdd.expect("hdd sync");
    }

    pub(crate) fn request_shutdown(&self) {
        self.core.lock().unwrap().shutdown = true;
        self.work.notify_all();
        self.space.notify_all();
    }
}
